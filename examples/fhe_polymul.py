"""FHE-flavoured demo: RLWE ciphertext-style polynomial products, batched
across banks (PIM) / across the batch axis (TPU).

The paper's target workload: polynomial multiplication in
Z_q[X]/(X^N + 1) via eq. (1), with bank-level parallelism — "FHE
applications can naturally run multiple NTT functions using multiple
banks" (§VI-A).

The demo now goes one level up the FHE stack as well: a real RNS-CKKS
ciphertext multiply (`repro.he.RlweCtMulOp`) compiled to a multi-tower
gang plan — one residue tower per bank — with the per-tower timing
breakdown the row-centric mapping produces.

    PYTHONPATH=src python examples/fhe_polymul.py --n 4096 --batch 8 --towers 4
"""
import argparse
import time

import numpy as np

import repro.he as he
from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.kernels import ops
from repro.pimsys import PimSession, PolymulOp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8, help="independent products (banks)")
    ap.add_argument("--nb", type=int, default=4, help="atom buffers per bank")
    ap.add_argument("--towers", type=int, default=4,
                    help="RNS towers for the ciphertext multiply")
    args = ap.parse_args()
    q = mm.DEFAULT_Q
    ctx = ntt.make_context(q, args.n)
    rng = np.random.default_rng(0)
    a = rng.integers(0, q, (args.batch, args.n)).astype(np.uint32)
    b = rng.integers(0, q, (args.batch, args.n)).astype(np.uint32)

    # -- PIM path: one product per bank; latency = single bank (parallel) --
    sess = PimSession(PimConfig(num_buffers=args.nb))
    r = sess.run(sess.compile(PolymulOp(args.n)), a[0], b[0], ctx=ctx)
    out0, timing = r.value, r.timing
    expect0 = ntt.polymul_negacyclic_np(a[0], b[0], ctx)
    assert np.array_equal(out0, expect0)
    print(f"[pim] polymul N={args.n}, Nb={args.nb}: {timing.us:.1f} us/bank, "
          f"{args.batch} banks in parallel -> {timing.us:.1f} us total "
          f"({timing.stats['act']} activations/bank, "
          f"phases={ {k: round(v / 1e3, 1) for k, v in timing.phase_ns.items()} } us)")

    # -- HE path: one RNS-CKKS ciphertext multiply, tower-per-bank --------
    he_sess = PimSession(PimConfig(num_channels=2, num_banks=4,
                                   param_cache_entries=16))
    plan = he_sess.compile(he.RlweCtMulOp(n=args.n, towers=args.towers))
    basis = he.basis_for(plan.op)
    ct_a, ct_b = he.random_ct(basis, 1), he.random_ct(basis, 2)
    rh = he_sess.run(plan, ct_a, ct_b)
    assert np.array_equal(rh.value, he.ct_mul_reference(basis, ct_a, ct_b))
    th = rh.timing
    print(f"[he] ct_mul N={args.n}, L={args.towers} towers on {th.banks} "
          f"banks: {th.latency_ns / 1e3:.1f} us "
          f"(x{th.speedup:.2f} vs one bank, eff {th.efficiency:.2f})")
    print(f"[he]   phases: "
          f"{ {k: round(v / 1e3, 1) for k, v in th.phase_ns.items()} } us")
    per_tower = "  ".join(
        f"t{i}@{done / 1e3:.1f}us" for i, done in enumerate(th.tower_done_ns))
    print(f"[he]   per-tower completion: {per_tower}")

    # -- TPU path: batch over the VPU, same math --------------------------
    t0 = time.perf_counter()
    got = np.asarray(ops.polymul_ntt(a, b, ctx))
    dt = time.perf_counter() - t0
    for i in range(args.batch):
        assert np.array_equal(got[i], ntt.polymul_negacyclic_np(a[i], b[i], ctx))
    print(f"[tpu] batch={args.batch} polymul == oracle "
          f"({dt:.2f}s interpret-mode wall time, not indicative of TPU)")
    print("fhe_polymul OK")


if __name__ == "__main__":
    main()
