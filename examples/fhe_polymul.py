"""FHE-flavoured demo: RLWE ciphertext-style polynomial products, batched
across banks (PIM) / across the batch axis (TPU).

The paper's target workload: polynomial multiplication in
Z_q[X]/(X^N + 1) via eq. (1), with bank-level parallelism — "FHE
applications can naturally run multiple NTT functions using multiple
banks" (§VI-A).

    PYTHONPATH=src python examples/fhe_polymul.py --n 4096 --batch 8
"""
import argparse
import time

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.kernels import ops
from repro.pimsys import PimSession, PolymulOp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8, help="independent products (banks)")
    ap.add_argument("--nb", type=int, default=4, help="atom buffers per bank")
    args = ap.parse_args()
    q = mm.DEFAULT_Q
    ctx = ntt.make_context(q, args.n)
    rng = np.random.default_rng(0)
    a = rng.integers(0, q, (args.batch, args.n)).astype(np.uint32)
    b = rng.integers(0, q, (args.batch, args.n)).astype(np.uint32)

    # -- PIM path: one product per bank; latency = single bank (parallel) --
    sess = PimSession(PimConfig(num_buffers=args.nb))
    r = sess.run(sess.compile(PolymulOp(args.n)), a[0], b[0], ctx=ctx)
    out0, timing = r.value, r.timing
    expect0 = ntt.polymul_negacyclic_np(a[0], b[0], ctx)
    assert np.array_equal(out0, expect0)
    print(f"[pim] polymul N={args.n}, Nb={args.nb}: {timing.us:.1f} us/bank, "
          f"{args.batch} banks in parallel -> {timing.us:.1f} us total "
          f"({timing.stats['act']} activations/bank, "
          f"phases={ {k: round(v / 1e3, 1) for k, v in timing.phase_ns.items()} } us)")

    # -- TPU path: batch over the VPU, same math --------------------------
    t0 = time.perf_counter()
    got = np.asarray(ops.polymul_ntt(a, b, ctx))
    dt = time.perf_counter() - t0
    for i in range(args.batch):
        assert np.array_equal(got[i], ntt.polymul_negacyclic_np(a[i], b[i], ctx))
    print(f"[tpu] batch={args.batch} polymul == oracle "
          f"({dt:.2f}s interpret-mode wall time, not indicative of TPU)")
    print("fhe_polymul OK")


if __name__ == "__main__":
    main()
