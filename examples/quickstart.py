"""Quickstart: one NTT, three ways.

  1. reference (numpy oracle)
  2. NTT-PIM functional + cycle-level simulation (the paper's system)
  3. TPU Pallas kernel (row-centric mapping, interpret mode on CPU)

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.kernels.ntt import ntt_pallas
from repro.pimsys import NttOp, PimSession

N = 2048
Q = mm.DEFAULT_Q


def main():
    rng = np.random.default_rng(0)
    ctx = ntt.make_context(Q, N)
    poly = rng.integers(0, Q, N).astype(np.uint32)

    # 1. reference
    ref = ntt.ntt_forward_np(poly, ctx)

    # 2. PIM: compile once, then one run gives functional output + timing
    sess = PimSession(PimConfig(num_buffers=4))
    plan = sess.compile(NttOp(N, forward=True))
    r = sess.run(plan, poly, ctx=ctx)
    assert np.array_equal(r.value, ref), "PIM functional mismatch!"
    print(f"[pim] N={N}: {len(plan.commands)} DRAM commands, "
          f"{r.timing.us:.2f} us simulated on one HBM2E bank "
          f"({r.timing.stats['act']} row activations, Nb=4), "
          f"energy ~{r.timing.energy_nj():.1f} nJ")

    # 3. TPU kernel (batched = bank-level parallelism)
    batch = np.stack([poly] * 8)
    got_tpu = np.asarray(ntt_pallas(batch, ctx, forward=True))
    assert np.array_equal(got_tpu[0], ref), "Pallas kernel mismatch!"
    print(f"[tpu] N={N} x batch=8: Pallas row-centric kernel == oracle "
          f"(interpret mode; lowers to TPU via the same code path)")

    # polynomial multiplication (the FHE use-case, eq. 1)
    b = rng.integers(0, Q, N).astype(np.uint32)
    prod = np.asarray(__import__("repro.kernels.ops", fromlist=["polymul_ntt"])
                      .polymul_ntt(poly, b, ctx))
    school = ntt.schoolbook_negacyclic(poly, b, Q)
    assert np.array_equal(prod, school)
    print(f"[fhe] negacyclic polymul via NTT == schoolbook ({N} coeffs)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
