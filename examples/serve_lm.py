"""Serve a small LM with batched requests: prefill + lock-step decode with
KV caches (the decode_32k / long_500k dry-run cells lower this same step).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m  # O(1) state
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    res = serve(args.arch, args.batch, args.prompt_len, args.gen, reduced=True)
    print(f"[serve] {args.arch} (reduced): batch={args.batch} "
          f"prefill={res['prefill_s']:.2f}s decode={res['decode_s']:.2f}s "
          f"-> {res['tok_per_s']:.1f} tok/s")
    print("[serve] first request tokens:", res["generated"][0].tolist())


if __name__ == "__main__":
    main()
