"""End-to-end training driver: ~100M-param dense LM, synthetic data,
checkpoint/resume, fault tolerance — the full framework path on CPU.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(~100M params; shrink with --small for a fast demo.)
"""
import argparse
import dataclasses


from repro.configs.base import ModelConfig
from repro.launch.train import train

LM_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    num_layers=16,
    d_model=640,
    num_heads=8,
    num_kv_heads=4,
    head_dim=80,
    d_ff=2560,
    vocab_size=16384,
    qk_norm=True,
    remat=False,
)

LM_SMALL = dataclasses.replace(
    LM_100M, name="lm-small", num_layers=4, d_model=256, d_ff=1024, vocab_size=2048
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = LM_SMALL if args.small else LM_100M

    import jax
    import numpy as np
    from repro.models.transformer import init_params

    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    print(f"[example] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")
    train(
        cfg,  # pass the ModelConfig directly
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        reduced=False,
        ckpt_every=50,
        log_every=10,
    )


if __name__ == "__main__":
    main()
