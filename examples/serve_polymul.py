"""Serve batched RLWE polynomial products on a PIM device, end to end.

Demonstrates the full `repro.pimsys` stack — through the async
`DeviceService` API — for the ROADMAP's serving question: open-loop
Poisson traffic of polymul requests dispatched onto a channels x banks
device under a QoS policy, with a functional spot-check that the command
stream being timed also computes the right polynomial product.

Compile once, submit futures, resolve in simulated time::

    sess = PimSession(cfg, policy="rr")
    plan = sess.compile(PolymulOp(n))      # mapper + twiddle params, ONCE
    r = sess.run(plan, a, b)               # functional + single-bank timing
    svc = sess.service(ServicePolicy(weight_latency=8.0,
                                     batch_window_us=10.0))
    futs = svc.submit_poisson(plan, count=64, rate_per_us=0.1, seed=0)
    urgent = svc.submit(plan, qos="latency", deadline_us=200.0)
    for fut in svc.as_completed([*futs, urgent]):
        fut.result()                       # ServedRequest, simulated us

Every downstream submit replays the frozen plan: zero mapper or
twiddle-parameter regeneration (the paper's precomputed (w0, r_w)
streams, amortized across the whole serving session).  Throughput-class
requests with the same plan coalesce into gang issues inside the
batching window; latency-class requests jump the queue via weighted
priority aging and are never batched.

    PYTHONPATH=src python examples/serve_polymul.py \
        --n 1024 --channels 2 --banks 4 --jobs 64 --rate 0.1

Prints per-class latency percentiles (p50/p95/p99), throughput, deadline
attainment, queue delay, bus utilization and device energy, then a
closed-loop batch for comparison, and writes an optional command trace
(--trace out.trace) that `repro.pimsys.trace.replay_trace` reproduces
bit-for-bit.
"""
import argparse

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.pimsys import STATUS_COMPLETED, PimSession, PolymulOp, ServicePolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="polynomial degree")
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--banks", type=int, default=4, help="banks per channel")
    ap.add_argument("--nb", type=int, default=4, help="atom buffers per bank")
    ap.add_argument("--jobs", type=int, default=64, help="requests to inject")
    ap.add_argument("--rate", type=float, default=0.1, help="arrivals per us (open loop)")
    ap.add_argument("--latency-frac", type=float, default=0.25,
                    help="fraction of requests in the latency QoS class")
    ap.add_argument("--deadline-us", type=float, default=None,
                    help="SLO deadline for latency-class requests")
    ap.add_argument("--batch-window-us", type=float, default=10.0,
                    help="plan-coalescing window (0 disables batching)")
    ap.add_argument("--policy", choices=("rr", "ready"), default="rr")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, help="write the compiled command trace here")
    args = ap.parse_args()

    cfg = PimConfig(num_buffers=args.nb, num_channels=args.channels,
                    num_banks=args.banks)
    sess = PimSession(cfg, policy=args.policy)
    print(f"device: {sess.topo.describe()}, Nb={args.nb}, policy={args.policy}")

    # -- compile ONCE: every submission below replays this frozen plan ----
    plan = sess.compile(PolymulOp(args.n))
    print(f"compiled plan: {len(plan.commands)} commands, "
          f"{len(plan.twiddle_params)} CU-op twiddle-parameter programs, "
          f"rows a@{plan.placement['row_a']} b@{plan.placement['row_b']}")

    # -- functional spot-check: the same commands we are about to time
    #    actually compute a * b in Z_q[X]/(X^N + 1) ----------------------
    q = mm.DEFAULT_Q
    ctx = ntt.make_context(q, args.n)
    rng = np.random.default_rng(args.seed)
    a = rng.integers(0, q, args.n).astype(np.uint32)
    b = rng.integers(0, q, args.n).astype(np.uint32)
    single = sess.run(plan, a, b, ctx=ctx)
    assert np.array_equal(single.value, ntt.polymul_negacyclic_np(a, b, ctx))
    print(f"functional check OK; single-bank polymul latency {single.timing.us:.1f} us")

    # -- open-loop serving: futures over the QoS-aware device service -----
    svc = sess.service(ServicePolicy(
        weight_latency=8.0, batch_window_us=args.batch_window_us))
    futs = svc.submit_mixed_poisson(plan, args.jobs, args.rate,
                                    latency_frac=args.latency_frac,
                                    deadline_us=args.deadline_us,
                                    seed_throughput=args.seed,
                                    seed_latency=args.seed + 1)
    first = next(iter(svc.as_completed(futs))).result()
    res = svc.result()
    offered = args.rate * 1e3
    print(f"[open loop] {res.completed}/{res.submitted} jobs @ {args.rate}/us "
          f"(offered {offered:.0f} jobs/ms), seed={res.seed}, "
          f"{res.batches} gang issues coalescing {res.coalesced} jobs")
    print(f"  first completion: {first.qos} job #{first.index} at "
          f"{first.done_us:.1f} us (latency {first.latency_us:.1f} us)")
    # the per-class report comes from ONE summary() call — with a window
    # it also carries the tumbling-window SLO timeline per class
    win_us = max(args.deadline_us or 0.0, 50.0)
    summ = res.summary(window_us=win_us)
    for cls, block in summ["per_class"].items():
        slo = ("n/a" if args.deadline_us is None or cls != "latency"
               else f"{block['deadline_attainment']:.0%}")
        print(f"  {cls:10s} p50={block['p50']:.1f}  p95={block['p95']:.1f}  "
              f"p99={block['p99']:.1f} us  "
              f"tput={block['throughput_jobs_per_ms']:.1f} jobs/ms  "
              f"slo={slo}")
        if args.deadline_us is not None and block["deadline_attainment_windows"]:
            windows = block["deadline_attainment_windows"]
            timeline = " ".join(
                f"{t:.0f}us:{v:.0%}" for t, v in windows[:8])
            more = f" (+{len(windows) - 8} windows)" if len(windows) > 8 else ""
            print(f"  {'':10s} attainment/{win_us:.0f}us: {timeline}{more}")
    print(f"  throughput {res.throughput_jobs_per_ms:.1f} jobs/ms, "
          f"mean queue delay "
          f"{res.queue_delay_ns[res.status == STATUS_COMPLETED].mean() / 1e3:.1f} us")
    util = ", ".join(
        f"ch{ch}={res.stats.bus_utilization(ch):.2f}" for ch in res.stats.channels())
    print(f"  bus utilization: {util}")
    per_job = res.stats.energy_nj() / res.completed if res.completed else 0.0
    print(f"  device energy {res.stats.energy_nj() / 1e3:.1f} uJ "
          f"({per_job:.0f} nJ/job)")

    # -- closed-loop batch for comparison (neutral FIFO policy, so the
    #    number is the plain batch baseline, not the QoS/batching one) --
    svc_fifo = sess.service()
    for _ in range(args.jobs):
        svc_fifo.submit(plan)
    res_cl = svc_fifo.result()
    print(f"[closed loop] batch={args.jobs}: makespan {res_cl.makespan_ns / 1e3:.1f} us, "
          f"throughput {res_cl.throughput_jobs_per_ms:.1f} jobs/ms, "
          f"p99 {res_cl.latency_percentiles_us()['p99']:.1f} us")

    if args.trace:
        # one batch wave of the compiled plan, bank-placed like the
        # scheduler's first dispatch round
        streams = {}
        for flat in range(min(args.jobs, sess.topo.total_banks)):
            addr = sess.topo.address_of(flat)
            streams[(addr.channel, sess.topo.local_id(addr))] = list(plan.commands)
        from repro.pimsys import dump_trace

        dump_trace(streams, args.trace)
        print(f"wrote command trace for one batch wave to {args.trace}")

    print(f"plan cache: {sess.plan_misses} compile(s), {sess.plan_hits} hit(s)")
    print("serve_polymul OK")


if __name__ == "__main__":
    main()
