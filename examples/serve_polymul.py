"""Serve batched RLWE polynomial products on a PIM device, end to end.

Demonstrates the full `repro.pimsys` stack for the ROADMAP's serving
question: open-loop Poisson traffic of `PolymulJob` requests scheduled
onto a channels x banks device, with a functional spot-check that the
command streams being timed also compute the right polynomial product.

    PYTHONPATH=src python examples/serve_polymul.py \
        --n 1024 --channels 2 --banks 4 --jobs 64 --rate 0.1

Prints latency percentiles (p50/p95/p99), throughput, queue delay, bus
utilization and device energy, then a closed-loop batch for comparison,
and writes an optional command trace (--trace out.trace) that
`repro.pimsys.trace.replay_trace` reproduces bit-for-bit.
"""
import argparse

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.core.polymul import pim_polymul, polymul_commands
from repro.pimsys import (
    DeviceTopology,
    PolymulJob,
    RequestScheduler,
    dump_trace,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="polynomial degree")
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--banks", type=int, default=4, help="banks per channel")
    ap.add_argument("--nb", type=int, default=4, help="atom buffers per bank")
    ap.add_argument("--jobs", type=int, default=64, help="requests to inject")
    ap.add_argument("--rate", type=float, default=0.1, help="arrivals per us (open loop)")
    ap.add_argument("--policy", choices=("rr", "ready"), default="rr")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, help="write the per-bank command trace here")
    args = ap.parse_args()

    cfg = PimConfig(num_buffers=args.nb, num_channels=args.channels,
                    num_banks=args.banks)
    topo = DeviceTopology.from_config(cfg)
    print(f"device: {topo.describe()}, Nb={args.nb}, policy={args.policy}")

    # -- functional spot-check: the same commands we are about to time
    #    actually compute a * b in Z_q[X]/(X^N + 1) ----------------------
    q = mm.DEFAULT_Q
    ctx = ntt.make_context(q, args.n)
    rng = np.random.default_rng(args.seed)
    a = rng.integers(0, q, args.n).astype(np.uint32)
    b = rng.integers(0, q, args.n).astype(np.uint32)
    out, single = pim_polymul(a, b, ctx, cfg)
    assert np.array_equal(out, ntt.polymul_negacyclic_np(a, b, ctx))
    print(f"functional check OK; single-bank polymul latency {single.us:.1f} us")

    # -- open-loop serving ------------------------------------------------
    sched = RequestScheduler(cfg, topo, policy=args.policy)
    jobs = [PolymulJob(args.n)] * args.jobs
    res = sched.run_open_loop(jobs, rate_per_us=args.rate, seed=args.seed)
    p = res.latency_percentiles_us()
    offered = args.rate * 1e3
    print(f"[open loop] {res.completed}/{res.submitted} jobs @ {args.rate}/us "
          f"(offered {offered:.0f} jobs/ms)")
    print(f"  latency  p50={p['p50']:.1f}  p95={p['p95']:.1f}  "
          f"p99={p['p99']:.1f} us")
    print(f"  throughput {res.throughput_jobs_per_ms:.1f} jobs/ms, "
          f"mean queue delay {res.queue_delay_ns.mean() / 1e3:.1f} us")
    util = ", ".join(
        f"ch{ch}={res.stats.bus_utilization(ch):.2f}" for ch in res.stats.channels())
    print(f"  bus utilization: {util}")
    per_job = res.stats.energy_nj() / res.completed if res.completed else 0.0
    print(f"  device energy {res.stats.energy_nj() / 1e3:.1f} uJ "
          f"({per_job:.0f} nJ/job)")

    # -- closed-loop batch for comparison ---------------------------------
    res_cl = sched.run_closed_loop(jobs)
    print(f"[closed loop] batch={args.jobs}: makespan {res_cl.makespan_ns / 1e3:.1f} us, "
          f"throughput {res_cl.throughput_jobs_per_ms:.1f} jobs/ms, "
          f"p99 {res_cl.latency_percentiles_us()['p99']:.1f} us")

    if args.trace:
        streams = {}
        cmds = polymul_commands(cfg, args.n)[0]
        for flat in range(min(args.jobs, topo.total_banks)):
            addr = topo.address_of(flat)
            streams[(addr.channel, topo.local_id(addr))] = cmds
        dump_trace(streams, args.trace)
        print(f"wrote command trace for one batch wave to {args.trace}")

    print("serve_polymul OK")


if __name__ == "__main__":
    main()
