"""Serve RNS-CKKS ciphertext-op traffic on a PIM device, end to end.

The `repro.he` subsystem through the async `DeviceService` API: a mixed
open-loop stream of ciphertext multiplies, keyswitches and rescales —
each compiled ONCE into a frozen multi-tower gang plan — dispatched
onto a channels x banks device under per-op-class SLOs:

  * `ct_mul`      — throughput class, no deadline (bulk evaluation)
  * `keyswitch`   — latency class, tight deadline (interactive layer
                    boundary: the op on the critical path of every
                    multiplicative level)
  * `rescale`     — latency class, looser deadline (cheap but ordered)

Each op class gets its own Poisson arrival process; the scheduler gang-
issues every request onto the op's reserved banks by replaying the
plan's primed latency resolver (no per-request simulation), and the
summary reports per-class percentiles + deadline attainment — the
serving answer for "can one PIM device sustain interactive HE?".

    PYTHONPATH=src python examples/serve_ckks.py \
        --n 1024 --towers 4 --channels 2 --banks 4 --jobs 48 --rate 0.002

A functional spot-check first runs one ciphertext multiply with real
residue data through the same plan and verifies it against the big-int
CRT reference.
"""
import argparse

import numpy as np

import repro.he as he
from repro.core.pim_config import PimConfig
from repro.pimsys import PimSession, ServicePolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="polynomial degree")
    ap.add_argument("--towers", type=int, default=4, help="RNS towers (L)")
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--banks", type=int, default=4, help="banks per channel")
    ap.add_argument("--jobs", type=int, default=48,
                    help="ct_mul requests; keyswitch/rescale get half each")
    ap.add_argument("--rate", type=float, default=0.002,
                    help="ct_mul arrivals per us (open loop)")
    ap.add_argument("--ks-deadline-us", type=float, default=400.0,
                    help="SLO deadline for keyswitch requests")
    ap.add_argument("--rs-deadline-us", type=float, default=800.0,
                    help="SLO deadline for rescale requests")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = PimConfig(num_channels=args.channels, num_banks=args.banks,
                    param_cache_entries=16)
    sess = PimSession(cfg)
    print(f"device: {sess.topo.describe()}, L={args.towers} towers")

    # -- compile ONCE per op class: frozen multi-tower gang plans ---------
    mul = sess.compile(he.RlweCtMulOp(n=args.n, towers=args.towers))
    ks = sess.compile(he.KeySwitchOp(n=args.n, towers=args.towers))
    rs = sess.compile(he.RescaleOp(n=args.n, towers=args.towers))
    for name, plan in (("ct_mul", mul), ("keyswitch", ks), ("rescale", rs)):
        print(f"compiled {name}: towers={plan.placement['towers']} -> "
              f"banks={plan.placement['banks']}, "
              f"{plan.placement['rows']} rows/bank")

    # -- functional spot-check: the timed plan computes the right thing --
    basis = he.basis_for(mul.op)
    a, b = he.random_ct(basis, args.seed), he.random_ct(basis, args.seed + 1)
    r = sess.run(mul, a, b)
    assert np.array_equal(r.value, he.ct_mul_reference(basis, a, b))
    t = r.timing
    print(f"functional check OK; ct_mul {t.latency_ns / 1e3:.1f} us on "
          f"{t.banks} banks (x{t.speedup:.2f} vs one bank, "
          f"eff {t.efficiency:.2f})")

    # -- open-loop serving with per-op-class SLOs -------------------------
    svc = sess.service(ServicePolicy(weight_latency=8.0))
    futs = list(svc.submit_poisson(mul, args.jobs, args.rate,
                                   seed=args.seed))
    futs += [f for f in svc.submit_poisson(
        ks, max(1, args.jobs // 2), args.rate / 2, qos="latency",
        deadline_us=args.ks_deadline_us, seed=args.seed + 1)]
    futs += [f for f in svc.submit_poisson(
        rs, max(1, args.jobs // 2), args.rate / 2, qos="latency",
        deadline_us=args.rs_deadline_us, seed=args.seed + 2)]
    done = [f.result() for f in svc.as_completed(futs)]
    res = svc.result()

    # -- per-op-class report (the SLO view) -------------------------------
    by_op = {"ct_mul": [], "keyswitch": [], "rescale": []}
    job_to_op = {mul.job(): "ct_mul", ks.job(): "keyswitch",
                 rs.job(): "rescale"}
    for rec in done:
        by_op[job_to_op[rec.job]].append(rec)
    print(f"[open loop] {res.completed}/{res.submitted} completed, "
          f"{res.batches} gang issues coalescing {res.coalesced}")
    for name, recs in by_op.items():
        lats = sorted(r2.latency_us for r2 in recs if r2.ok)
        if not lats:
            continue
        pct = lambda p: lats[min(len(lats) - 1, int(p * len(lats)))]
        met = [r2.met_deadline for r2 in recs if r2.met_deadline is not None]
        slo = f"{sum(met) / len(met):.0%}" if met else "n/a"
        print(f"  {name:10s} {len(recs):3d} reqs  p50={pct(0.50):.1f}  "
              f"p95={pct(0.95):.1f}  p99={pct(0.99):.1f} us  slo={slo}")
    util = ", ".join(f"ch{ch}={res.stats.bus_utilization(ch):.2f}"
                     for ch in res.stats.channels())
    print(f"  bus utilization: {util}")
    print(f"plan cache: {sess.plan_misses} compile(s), {sess.plan_hits} hit(s)")
    print("serve_ckks OK")


if __name__ == "__main__":
    main()
