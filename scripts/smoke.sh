#!/usr/bin/env bash
# Fast regression smoke: tier-1 subset + device-level benchmark + serving
# example, each under a wall-clock timeout so simulator runtime
# regressions fail loudly.
#
#   ./scripts/smoke.sh            # defaults: 300s tests, 120s benchmark
#   SMOKE_TEST_TIMEOUT=600 ./scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
TEST_TIMEOUT="${SMOKE_TEST_TIMEOUT:-300}"
BENCH_TIMEOUT="${SMOKE_BENCH_TIMEOUT:-120}"

echo "== smoke: fast tier-1 subset (-m 'not slow', ${TEST_TIMEOUT}s budget) =="
timeout "${TEST_TIMEOUT}" python -m pytest -q -m "not slow" \
    tests/test_core_ntt.py tests/test_pim_sim.py tests/test_pimsys.py \
    tests/test_engine.py tests/test_engine_props.py \
    tests/test_sharded.py tests/test_sharded_props.py \
    tests/test_session.py tests/test_session_props.py \
    tests/test_service.py tests/test_service_props.py \
    tests/test_fastpath_props.py

echo "== smoke: device benchmark + perf-regression gate (${BENCH_TIMEOUT}s budget) =="
# full quick sweep (base + sharded + param-cache) to a staging file,
# gate >10% latency regressions against the committed baseline, then
# refresh the committed JSON — a perf change must arrive as a diff,
# never as a silent drift
timeout "${BENCH_TIMEOUT}" python -m benchmarks.multibank --quick --all \
    --json BENCH_multibank.json.new
python scripts/perf_check.py BENCH_multibank.json.new BENCH_multibank.json \
    --tol 0.10
mv BENCH_multibank.json.new BENCH_multibank.json

echo "== smoke: HE ciphertext-op sweep + perf gate (${BENCH_TIMEOUT}s budget) =="
# RNS-CKKS ops (repro.he) through the session gang path: differential
# tests first (bit-exact vs the big-int CRT oracles), then the quick
# towers x N x banks sweep gated against the committed baseline —
# the eff columns (>= 0.7 at banks = towers for ct_mul) gate absolutely
# via --eff-tol, and the keyswitch telemetry trace must span base_extend
timeout "${TEST_TIMEOUT}" python -m pytest -q tests/test_he.py tests/test_he_props.py
timeout "${BENCH_TIMEOUT}" python -m benchmarks.he_ops --quick \
    --json BENCH_he.json.new
python scripts/perf_check.py BENCH_he.json.new BENCH_he.json --tol 0.10
mv BENCH_he.json.new BENCH_he.json
mkdir -p artifacts
timeout "${BENCH_TIMEOUT}" python -m benchmarks.he_ops --quick \
    --trace-out artifacts/trace_he.json
python scripts/validate_trace.py artifacts/trace_he.json

echo "== smoke: NttBackend differential + TPU lane gate (${BENCH_TIMEOUT}s budget) =="
# the three-lane {reference, pim-sim, pallas} differential must hold
# bit-exactly (tests/test_backend.py runs even without hypothesis/jax),
# then the tpu_ntt harness regenerates its gated artifact the same way
# the device sweeps do
timeout "${TEST_TIMEOUT}" python -m pytest -q tests/test_backend.py
timeout "${BENCH_TIMEOUT}" python -m benchmarks.tpu_ntt --quick \
    --json BENCH_tpu.json.new
python scripts/perf_check.py BENCH_tpu.json.new BENCH_tpu.json --tol 0.10
mv BENCH_tpu.json.new BENCH_tpu.json

echo "== smoke: serving sweep + p99 perf gate (${BENCH_TIMEOUT}s budget) =="
# rate x QoS mix x batching window over the DeviceService futures path;
# the gate fails on >10% regression of latency-class p99 or
# throughput-class us/job vs the committed baseline, then refreshes it
timeout "${BENCH_TIMEOUT}" python -m benchmarks.serving --quick \
    --json BENCH_serving.json.new
python scripts/perf_check.py BENCH_serving.json.new BENCH_serving.json \
    --tol 0.10
mv BENCH_serving.json.new BENCH_serving.json

echo "== smoke: fastpath serving sweep + perf gate (${BENCH_TIMEOUT}s budget) =="
# 30k requests through ServicePolicy(backend="fastpath") with every
# dispatch's profile differentially verified against the interpreted
# engine, plus the interpreted calibration prefix for the sim-rate
# annotation; deterministic simulated-time points gate vs the baseline
timeout "${BENCH_TIMEOUT}" python -m benchmarks.serving --quick-full \
    --json BENCH_fastpath.json.new
python scripts/perf_check.py BENCH_fastpath.json.new BENCH_fastpath.json \
    --tol 0.10
mv BENCH_fastpath.json.new BENCH_fastpath.json

echo "== smoke: engine commands/s microbenchmark (${BENCH_TIMEOUT}s budget) =="
# floor well below the ~2x-optimized rate but above the seed's ~100k
# cmd/s, so a hot-loop regression fails loudly even on a noisy runner.
# telemetry defaults OFF here — the floor doubles as the zero-overhead-
# when-off gate for the telemetry layer (within ~2% of the committed
# 120k floor by construction of the single is-None guard per command)
timeout "${BENCH_TIMEOUT}" python -m benchmarks.engine_speed --repeat 2 \
    --min-rate 120000

echo "== smoke: telemetry traces (record, validate, report; ${BENCH_TIMEOUT}s budget) =="
# record the acceptance workload (16-bank N=4096 sharded) + one serving
# policy point with telemetry on, schema-validate both Chrome traces,
# and gate the per-request latency attribution at >= 95%
mkdir -p artifacts
timeout "${BENCH_TIMEOUT}" python -m benchmarks.multibank \
    --trace-out artifacts/trace_multibank.json
python scripts/validate_trace.py artifacts/trace_multibank.json
python scripts/report_telemetry.py artifacts/trace_multibank.json
timeout "${BENCH_TIMEOUT}" python -m benchmarks.serving --quick \
    --trace-out artifacts/trace_serving.json
python scripts/validate_trace.py artifacts/trace_serving.json
python scripts/report_telemetry.py artifacts/trace_serving.json \
    --min-attributed 0.95

echo "== smoke: serve_polymul example over the session API (${BENCH_TIMEOUT}s budget) =="
timeout "${BENCH_TIMEOUT}" python examples/serve_polymul.py \
    --n 512 --channels 2 --banks 2 --jobs 16 --rate 0.05

echo "== smoke: legacy shims emit exactly one DeprecationWarning =="
# the canonical assertion lives in tests/test_session.py; rerun just it so
# a shim regression fails this named leg loudly even if someone trims the
# pytest selection above
timeout 60 python -m pytest -q tests/test_session.py -k "legacy_shim_warns or session_api_emits_no_warnings"

echo "smoke OK"
