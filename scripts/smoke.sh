#!/usr/bin/env bash
# Fast regression smoke: tier-1 subset + device-level benchmark, each under
# a wall-clock timeout so simulator runtime regressions fail loudly.
#
#   ./scripts/smoke.sh            # defaults: 300s tests, 120s benchmark
#   SMOKE_TEST_TIMEOUT=600 ./scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
TEST_TIMEOUT="${SMOKE_TEST_TIMEOUT:-300}"
BENCH_TIMEOUT="${SMOKE_BENCH_TIMEOUT:-120}"

echo "== smoke: fast tier-1 subset (-m 'not slow', ${TEST_TIMEOUT}s budget) =="
timeout "${TEST_TIMEOUT}" python -m pytest -q -m "not slow" \
    tests/test_core_ntt.py tests/test_pim_sim.py tests/test_pimsys.py \
    tests/test_sharded.py tests/test_sharded_props.py

echo "== smoke: device-level benchmark (--quick, ${BENCH_TIMEOUT}s budget) =="
timeout "${BENCH_TIMEOUT}" python -m benchmarks.multibank --quick

echo "== smoke: sharded-NTT benchmark (--sharded --quick, ${BENCH_TIMEOUT}s budget) =="
timeout "${BENCH_TIMEOUT}" python -m benchmarks.multibank --sharded --quick

echo "smoke OK"
