"""Generate `tests/golden/engine_goldens.json` — frozen cycle counts.

The hierarchical resource engine (`repro.pimsys.engine`) must keep the
default-config timing model bit-identical to the seed simulator: with
`param_cache_entries=0` and one rank per channel, command lists and
cycle counts may not move.  This script records exact latencies (ns as
Python float repr, which JSON round-trips losslessly) for a matrix of
single-bank, multibank, sharded, and scheduler workloads; the regression
test `tests/test_engine.py::test_golden_cycles_bit_identical` replays the
matrix and asserts equality.

Regenerating this file is a DELIBERATE act (a conscious timing-model
change), never a side effect of a refactor:

    PYTHONPATH=src python scripts/gen_engine_goldens.py
"""
import json
import os
import warnings

import numpy as np


def build() -> dict:
    from repro.core.mapping import RowCentricMapper
    from repro.core.pim_config import PimConfig
    from repro.core.pimsim import BankTimer, analytic_multibank_bound
    from repro.pimsys import (
        ChannelController,
        NttJob,
        PolymulJob,
        RequestScheduler,
        ShardedNttPlan,
    )

    out: dict = {"single": [], "multibank": [], "sharded": [], "scheduler": []}

    # single bank: the paper's own simulator surface
    for n in (256, 1024, 4096):
        for nb in (1, 2, 4, 6):
            for forward in (False, True):
                cfg = PimConfig(num_buffers=nb)
                cmds = RowCentricMapper(cfg, n, forward=forward).commands()
                r = BankTimer(cfg).simulate(cmds)
                out["single"].append({
                    "n": n, "nb": nb, "forward": forward,
                    "commands": len(cmds), "ns": r.ns,
                    "stats": dict(sorted(r.stats.items())),
                })

    # multibank: shared-bus contention through the channel controller
    for n, nb in ((1024, 2), (1024, 4), (4096, 2)):
        cfg = PimConfig(num_buffers=nb)
        cmds = RowCentricMapper(cfg, n).commands()
        for banks in (2, 4, 8, 16):
            for policy in ("rr", "ready"):
                ctrl = ChannelController(cfg, policy=policy)
                for i in range(banks):
                    ctrl.enqueue(ctrl.add_bank(), cmds, job_id=i)
                ctrl.drain()
                out["multibank"].append({
                    "n": n, "nb": nb, "banks": banks, "policy": policy,
                    "latency_ns": ctrl.makespan_ns,
                    "bus_busy_ns": ctrl.bus_busy_ns,
                    "analytic_ns": analytic_multibank_bound(n, banks, cfg),
                })

    # sharded: four-step split incl. the exchange phase
    sharded_cases = [
        (PimConfig(num_buffers=2, num_channels=2, num_banks=2), 256, 4),
        (PimConfig(num_buffers=4, num_channels=1, num_banks=2), 512, 2),
        (PimConfig(num_buffers=2, num_channels=2, num_banks=4), 4096, 8),
    ]
    for cfg, n, banks in sharded_cases:
        for forward in (False, True):
            r = ShardedNttPlan(cfg, n, banks, forward=forward).simulate(
                baseline=False)
            out["sharded"].append({
                "n": n, "banks": banks, "forward": forward,
                "nb": cfg.num_buffers, "channels": cfg.num_channels,
                "banks_per_rank": cfg.num_banks,
                "latency_ns": r.latency_ns,
                "local_ns": r.local_ns,
                "exchange_ns": r.exchange_ns,
                "xfer_atoms": r.xfer_atoms,
                "xfer_hops": r.xfer_hops,
            })

    # scheduler: closed- and open-loop completion times
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    jobs = [NttJob(512), PolymulJob(256), NttJob(1024), NttJob(512),
            PolymulJob(512), NttJob(256)]
    closed = RequestScheduler(cfg).run_closed_loop(jobs)
    open_ = RequestScheduler(cfg).run_open_loop(jobs, rate_per_us=0.1, seed=3)
    out["scheduler"].append({
        "closed_done_ns": [float(x) for x in closed.done_ns],
        "closed_makespan_ns": closed.makespan_ns,
        "open_done_ns": [float(x) for x in open_.done_ns],
        "open_makespan_ns": open_.makespan_ns,
    })
    return out


def main():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        data = build()
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "tests", "golden", "engine_goldens.json")
    path = os.path.normpath(path)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    n = sum(len(v) for v in data.values())
    print(f"wrote {n} golden records to {path}")


if __name__ == "__main__":
    main()
