"""Perf-regression gate over benchmark sweep JSONs.

Compares a freshly generated sweep against the committed baseline,
point by point (matched on the `name` column): any point whose
`us_per_call` latency regresses more than `--tol` (default 10%) fails
the check.  Points present only on one side are reported but never
fail — new sweeps (e.g. a just-added `--param-cache` column) should not
require a baseline to exist first.  The simulator is deterministic, so
a regression here is a timing-model or scheduling change, not noise.

Gated artifacts: `BENCH_multibank.json` (device sweeps, `us_per_call`
is a latency), `BENCH_serving.json` (serving sweeps, `us_per_call`
is the latency-class p99 or the throughput-class us/job — both
lower-is-better, so the same rule gates the p99 and the service rate),
and `BENCH_tpu.json` (the NttBackend lane: analytic roofline terms and
the pim-sim modeled latency are deterministic and gate; wall-clock
rows are zero-latency annotations and do not).

Points whose parsed derived metrics carry an `eff` scaling-efficiency
column (the sharded sweeps) are additionally gated on it: a drop of
more than `--eff-tol` (default 0.05, absolute) versus the baseline
fails even when the point's latency is within `--tol` — a sharded
point can get "faster" while its one-bank baseline got faster still,
which is exactly the knee regression the latency rule cannot see.
Efficiency is higher-is-better and bounded, so the tolerance is
absolute, not fractional.  Annotation rows (us_per_call <= 0) with an
`eff` on both sides are eff-gated too.

Both artifacts carry a `schema_version` (`benchmarks.run.SCHEMA_VERSION`;
documents written before the field existed read as version 1).  Mixed
versions are refused outright — a layout change must regenerate the
committed baseline, never be silently compared across it.

Every shared point is printed (baseline, new, delta) so a failing run
shows the whole sweep's shape, not just the offender; `--write-baseline`
copies the fresh sweep over the committed baseline in place after the
check passes — the one-command regeneration path when a deliberate
timing-model change moves the numbers.

Usage (what `scripts/smoke.sh` runs):
    python scripts/perf_check.py NEW.json BENCH_multibank.json --tol 0.10
    python scripts/perf_check.py NEW.json BENCH_serving.json --tol 0.10
    python scripts/perf_check.py NEW.json BENCH_fastpath.json --tol 0.10 \
        --write-baseline   # refresh the committed baseline from NEW
"""
import argparse
import json
import shutil
import sys


def load_doc(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def load_points(path: str) -> dict:
    return {p["name"]: p for p in load_doc(path).get("points", [])}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="freshly generated sweep JSON")
    ap.add_argument("baseline", help="committed BENCH_multibank.json")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed fractional latency regression (default 0.10)")
    ap.add_argument("--eff-tol", type=float, default=0.05,
                    help="allowed absolute drop of a point's `eff` "
                         "scaling-efficiency column (default 0.05)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="on success, copy the fresh sweep over the "
                         "baseline in place (deliberate regeneration)")
    args = ap.parse_args()

    new_doc, base_doc = load_doc(args.new), load_doc(args.baseline)
    v_new = new_doc.get("schema_version", 1)
    v_base = base_doc.get("schema_version", 1)
    if v_new != v_base:
        print(f"perf_check: SCHEMA MISMATCH — {args.new} is version {v_new}, "
              f"{args.baseline} is version {v_base}; regenerate the baseline "
              "at the current schema instead of comparing across layouts",
              file=sys.stderr)
        return 2

    new = {p["name"]: p for p in new_doc.get("points", [])}
    base = {p["name"]: p for p in base_doc.get("points", [])}
    shared = sorted(set(new) & set(base))
    only_new = sorted(set(new) - set(base))
    only_base = sorted(set(base) - set(new))

    failures = []
    eff_failures = []
    worst = (0.0, None)
    print(f"perf_check: {len(shared)} shared points "
          f"({len(only_new)} new-only, {len(only_base)} baseline-only), "
          f"tol {args.tol:.0%}, eff-tol {args.eff_tol:.2f}")
    wide = max((len(n) for n in shared), default=4)
    for name in shared:
        b, n = base[name].get("us_per_call", 0.0), new[name].get("us_per_call", 0.0)
        # the eff gate is independent of the latency gate: it fires even
        # on annotation rows, and even when the latency itself improved
        be, ne = base[name].get("eff"), new[name].get("eff")
        eff_note = ""
        if isinstance(be, (int, float)) and isinstance(ne, (int, float)):
            drop = be - ne
            eff_note = f"  eff {be:.2f} -> {ne:.2f}"
            if drop > args.eff_tol:
                eff_failures.append((name, be, ne, drop))
        if b <= 0.0:
            # knee markers and other zero-latency annotation rows
            print(f"perf_check:   {name:<{wide}}  (annotation, not gated)"
                  f"{eff_note}")
            continue
        ratio = n / b - 1.0
        print(f"perf_check:   {name:<{wide}}  {b:>10.2f}us -> {n:>10.2f}us "
              f"({ratio:+.1%}){eff_note}")
        if ratio > worst[0]:
            worst = (ratio, name)
        if ratio > args.tol:
            failures.append((name, b, n, ratio))
    if worst[1] is not None:
        print(f"perf_check: worst regression {worst[0]:+.1%} at {worst[1]}")
    for name, b, n, ratio in failures:
        print(f"perf_check: REGRESSION {name}: {b:.2f}us -> {n:.2f}us "
              f"({ratio:+.1%})", file=sys.stderr)
    for name, be, ne, drop in eff_failures:
        print(f"perf_check: EFFICIENCY DROP {name}: eff {be:.2f} -> {ne:.2f} "
              f"(-{drop:.2f} > {args.eff_tol:.2f})", file=sys.stderr)
    if failures or eff_failures:
        return 1
    if args.write_baseline:
        shutil.copyfile(args.new, args.baseline)
        print(f"perf_check: baseline {args.baseline} regenerated from {args.new}")
    print("perf_check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
