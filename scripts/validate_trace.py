"""CLI wrapper over `repro.pimsys.telemetry.validate_chrome_trace`.

Structurally validates an exported Chrome trace-event JSON document
(event phases, required fields, track ids) and exits nonzero on any
violation — the smoke leg runs it on the benchmark `--trace-out`
artifacts before handing them to `report_telemetry.py`.

Usage:
    PYTHONPATH=src python scripts/validate_trace.py trace.json
"""
import argparse
import json
import sys

from repro.pimsys import validate_chrome_trace


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON to validate")
    args = ap.parse_args()

    with open(args.trace) as f:
        doc = json.load(f)
    errors = validate_chrome_trace(doc)
    n = len(doc.get("traceEvents", [])) if isinstance(doc, dict) else 0
    for e in errors:
        print(f"validate_trace: {args.trace}: {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"validate_trace: OK ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
