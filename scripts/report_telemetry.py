"""Text report over an exported telemetry trace (Chrome trace-event JSON).

Reads a trace written by `TelemetryHandle.dump()` (or the benchmark
`--trace-out` modes) and prints:

  1. per-request critical-path breakdown: for every request lifecycle in
     the trace, its named spans (queue_wait / coalesce_wait / execute),
     the total latency, and the fraction of that latency attributed to
     named spans — requests sorted by total latency, worst first
  2. top-stall attribution: device command events aggregated by command
     class, with the issue-time split into bus_wait (arbitration: bus
     grant minus rank gate), stall (rank/buffer hazards: start minus
     grant), param (parameter-load beats) and array (in-bank execution,
     the event duration), sorted by total stall
  3. summary line: request count, mean/min attribution

`--min-attributed F` (default 0) turns the report into a gate: exit
nonzero if any request attributes less than F of its latency to named
spans.  The acceptance bar for the telemetry layer is 0.95.

Works on both dialects: request-lifecycle traces (serving) have section
1; device-only traces (session runs) have section 2 only.

Usage:
    PYTHONPATH=src python -m benchmarks.serving --trace-out trace.json
    python scripts/report_telemetry.py trace.json --min-attributed 0.95
"""
import argparse
import json
import sys
from collections import defaultdict

# mirrors repro.pimsys.telemetry — the report must stay standalone
# (readable against a trace file with no repo import), so the track
# constants are restated here
PHASE_PID = 900000
REQUEST_PID = 900001


def load_events(path: str) -> list:
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):  # bare trace-event array dialect
        return doc
    return doc.get("traceEvents", [])


def request_rows(events: list) -> list:
    """Reassemble async b/e lifecycle pairs into per-request rows."""
    open_spans: dict = {}
    reqs: dict = defaultdict(lambda: {"spans": {}, "qos": "", "events": []})
    for ev in events:
        if ev.get("pid") != REQUEST_PID:
            continue
        rid = ev.get("id")
        ph = ev.get("ph")
        if ph == "b":
            open_spans[(rid, ev["name"])] = ev["ts"]
            reqs[rid]["qos"] = ev.get("args", {}).get("qos", reqs[rid]["qos"])
        elif ph == "e":
            t0 = open_spans.pop((rid, ev["name"]), None)
            if t0 is not None:
                reqs[rid]["spans"][ev["name"]] = (t0, ev["ts"])
        elif ph == "i":
            reqs[rid]["events"].append(ev["name"])
    rows = []
    for rid, r in sorted(reqs.items()):
        if not r["spans"]:
            continue  # rejected requests have only instant events
        t0 = min(a for a, _ in r["spans"].values())
        t1 = max(b for _, b in r["spans"].values())
        total = t1 - t0
        named = sum(b - a for a, b in r["spans"].values())
        rows.append({
            "rid": rid,
            "qos": r["qos"],
            "spans": {k: b - a for k, (a, b) in sorted(r["spans"].items())},
            "total_us": total,
            "attributed": (named / total) if total > 0 else 1.0,
        })
    rows.sort(key=lambda r: -r["total_us"])
    return rows


def command_stalls(events: list) -> list:
    """Aggregate X command events by class into issue-time buckets
    (all values in us, matching the trace's ts/dur unit)."""
    agg: dict = defaultdict(lambda: [0, 0.0, 0.0, 0.0, 0.0])  # n,bus,stall,param,array
    for ev in events:
        if ev.get("ph") != "X" or ev.get("pid") in (PHASE_PID, REQUEST_PID):
            continue
        a = ev.get("args", {})
        if "bus_wait_us" not in a:
            continue  # bursts and other non-command X events
        row = agg[ev["name"]]
        row[0] += 1
        row[1] += a["bus_wait_us"]
        row[2] += a.get("stall_us", 0.0)
        row[3] += a.get("param_us", 0.0)
        row[4] += ev.get("dur", 0.0)
    out = [{"cmd": k, "count": v[0], "bus_wait_us": v[1], "stall_us": v[2],
            "param_us": v[3], "array_us": v[4]} for k, v in agg.items()]
    out.sort(key=lambda r: -(r["bus_wait_us"] + r["stall_us"]))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON from --trace-out")
    ap.add_argument("--min-attributed", type=float, default=0.0, metavar="F",
                    help="fail if any request attributes < F of its latency "
                         "to named spans (acceptance bar: 0.95)")
    ap.add_argument("--top", type=int, default=10,
                    help="rows to print per section (default 10)")
    args = ap.parse_args()

    events = load_events(args.trace)
    print(f"telemetry report: {args.trace} ({len(events)} events)")

    rows = request_rows(events)
    if rows:
        print(f"\nper-request critical path ({len(rows)} requests, "
              f"worst {min(args.top, len(rows))} shown):")
        print(f"  {'rid':>5} {'qos':>10} {'total_us':>9} {'attr':>6}  spans")
        for r in rows[: args.top]:
            spans = " + ".join(f"{k}={v:.1f}us" for k, v in r["spans"].items())
            print(f"  {r['rid']:>5} {r['qos']:>10} {r['total_us']:>9.1f} "
                  f"{r['attributed']:>6.1%}  {spans}")

    stalls = command_stalls(events)
    if stalls:
        print(f"\ntop stall attribution ({len(stalls)} command classes):")
        print(f"  {'cmd':>10} {'count':>7} {'bus_wait_us':>11} {'stall_us':>9} "
              f"{'param_us':>9} {'array_us':>9}")
        for r in stalls[: args.top]:
            print(f"  {r['cmd']:>10} {r['count']:>7} "
                  f"{r['bus_wait_us']:>11.1f} {r['stall_us']:>9.1f} "
                  f"{r['param_us']:>9.1f} {r['array_us']:>9.1f}")

    if rows:
        worst = min(r["attributed"] for r in rows)
        mean = sum(r["attributed"] for r in rows) / len(rows)
        print(f"\nattribution: mean {mean:.1%}, worst {worst:.1%} "
              f"over {len(rows)} requests")
        if worst < args.min_attributed:
            print(f"report_telemetry: FAIL — worst attribution {worst:.1%} "
                  f"< required {args.min_attributed:.1%}", file=sys.stderr)
            return 1
    elif args.min_attributed > 0:
        print("report_telemetry: FAIL — no request lifecycles in trace but "
              "--min-attributed was given", file=sys.stderr)
        return 1
    print("report_telemetry: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
