"""Shared pytest configuration for the repro test suite.

Hypothesis boilerplate (importorskip + settings profile) lives in
`tests/hypo.py`; property-based modules import from there.
"""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (excluded from the smoke run via -m 'not slow')",
    )


@pytest.fixture
def small_pim_cfg():
    """A small device config the system-level tests share: Nb=2 banks of
    the paper's geometry on a 2-channel x 2-bank device — big enough to
    exercise channel-crossing exchange traffic, small enough that a full
    cycle-level simulation stays in the milliseconds."""
    from repro.core.pim_config import PimConfig

    return PimConfig(num_buffers=2, num_channels=2, num_banks=2)
