"""Shared pytest configuration for the repro test suite."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (excluded from the smoke run via -m 'not slow')",
    )
