"""Async device-service API (`repro.pimsys.service`) + policy dispatcher.

Five layers:
  1. parity: `run_service` under the default `ServicePolicy()` — and the
     deprecated `PimSession.submit` shim on top of it — is bit-identical
     to the pre-redesign FIFO `RequestScheduler` on the same arrival
     trace (arrays, makespan, device stats);
  2. QoS + admission: weighted priority aging reorders under load
     without starving anyone, bounded queue depth and the token bucket
     reject/shed per class, and jobs are conserved
     (admitted + rejected == submitted);
  3. batching: same-plan throughput arrivals coalesce into gang issues
     with ZERO mapper regeneration, never change the completion count,
     never touch latency-class requests, and at saturation improve
     throughput-class jobs/ms while latency-class p99 stays within 10%
     of the unbatched FIFO baseline (the acceptance sweep in miniature);
  4. futures: lazy resolution, `gather` / `as_completed` in simulated
     time, rejected requests resolve (not raise), epoch isolation;
  5. SLO + seed accounting: deadline attainment per class, and the
     arrival seed recorded in `SchedulerResult.summary()` reproduces
     runs byte-for-byte.

The hypothesis twin lives in `test_service_props.py`.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import mapping
from repro.core.pim_config import PimConfig
from repro.pimsys import (
    DeviceService,
    NttJob,
    NttOp,
    PimSession,
    PolymulJob,
    PolymulOp,
    RequestScheduler,
    ServicePolicy,
    STATUS_REJECTED,
    ServiceRequest,
    ShardedNttJob,
    ShardedNttOp,
)


def quiet_submit(sess, *a, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return sess.submit(*a, **kw)


def poisson_arrivals(rate_per_us, count, seed):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1e3 / rate_per_us, size=count)).tolist()


def mixed_requests(cfg, job, count, rate_per_us, seed, latency_frac=0.25,
                   deadline_ns=None):
    rng = np.random.default_rng(seed + 1)
    arr = poisson_arrivals(rate_per_us, count, seed)
    return [
        ServiceRequest(t, job,
                       qos="latency" if rng.random() < latency_frac
                       else "throughput",
                       deadline_ns=deadline_ns)
        for t in arr
    ]


def assert_results_identical(a, b):
    assert a.makespan_ns == b.makespan_ns
    assert np.array_equal(a.arrivals_ns, b.arrivals_ns)
    assert np.array_equal(a.dispatch_ns, b.dispatch_ns)
    assert np.array_equal(a.done_ns, b.done_ns)
    assert a.stats.device_counts() == b.stats.device_counts()
    for ch in a.stats.channels():
        assert a.stats.bus_busy_ns(ch) == b.stats.bus_busy_ns(ch)


# ---------------------------------------------------------------------------
# 1. default-policy parity with the pre-redesign FIFO loop
# ---------------------------------------------------------------------------


def test_default_policy_closed_loop_parity(small_pim_cfg):
    jobs = ([PolymulJob(512)] * 5 + [NttJob(512)] * 4
            + [ShardedNttJob(512, banks=2)] * 2)
    ref = RequestScheduler(small_pim_cfg).run_closed_loop(jobs)
    got = RequestScheduler(small_pim_cfg).run_service(
        [ServiceRequest(0.0, j) for j in jobs])
    assert_results_identical(ref, got)
    assert got.completed == got.submitted == len(jobs)
    assert got.rejected == 0 and got.batches == 0


def test_default_policy_open_loop_parity(small_pim_cfg):
    jobs = [PolymulJob(512)] * 16
    ref = RequestScheduler(small_pim_cfg).run_open_loop(
        jobs, rate_per_us=0.2, seed=11)
    arr = poisson_arrivals(0.2, 16, 11)
    got = RequestScheduler(small_pim_cfg).run_service(
        [ServiceRequest(t, j) for t, j in zip(arr, jobs)], seed=11)
    assert_results_identical(ref, got)
    assert got.seed == 11


def test_equal_weights_are_fifo_even_with_mixed_classes(small_pim_cfg):
    """The FIFO anchor is the POLICY, not the class labels: equal
    weights dispatch a mixed-class trace in arrival order."""
    reqs = mixed_requests(small_pim_cfg, PolymulJob(256), 24, 0.5, seed=2)
    ref = RequestScheduler(small_pim_cfg).run_closed_loop(
        [r.job for r in sorted(reqs, key=lambda r: r.arrival_ns)])
    # closed-loop ref is a different trace; compare instead against the
    # same trace with classes erased
    plain = [ServiceRequest(r.arrival_ns, r.job) for r in reqs]
    got_mixed = RequestScheduler(small_pim_cfg).run_service(reqs)
    got_plain = RequestScheduler(small_pim_cfg).run_service(plain)
    assert_results_identical(got_plain, got_mixed)
    assert ref.completed == got_mixed.completed  # same job population


def test_session_submit_shim_parity_and_single_warning(small_pim_cfg):
    ref = RequestScheduler(small_pim_cfg).run_open_loop(
        [PolymulJob(512)] * 10, rate_per_us=0.1, seed=3)
    sess = PimSession(small_pim_cfg)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = sess.submit(sess.compile(PolymulOp(512)), count=10,
                          rate_per_us=0.1, seed=3).timing
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1 and "PimSession.submit" in str(dep[0].message)
    assert_results_identical(ref, got)


# ---------------------------------------------------------------------------
# 2. QoS weighting + admission control
# ---------------------------------------------------------------------------


def overload_requests(cfg, seed=4, count=40, rate=1.2):
    return mixed_requests(cfg, PolymulJob(256), count, rate, seed)


def test_qos_weighting_reorders_without_starvation(small_pim_cfg):
    reqs = overload_requests(small_pim_cfg)
    fifo = RequestScheduler(small_pim_cfg).run_service(reqs)
    qos = RequestScheduler(small_pim_cfg).run_service(
        reqs, policy=ServicePolicy(weight_latency=8.0))
    # everyone still completes (aging prevents starvation) ...
    assert qos.completed == len(reqs)
    # ... but the latency class jumps the queue
    assert (qos.latency_percentiles_us(qos="latency")["p99"]
            < fifo.latency_percentiles_us(qos="latency")["p99"])
    # and the cost lands on the throughput class, not on lost work
    assert qos.class_throughput_jobs_per_ms("throughput") > 0


def test_queue_depth_admission_bounds_and_reports(small_pim_cfg):
    reqs = overload_requests(small_pim_cfg, count=50, rate=2.0)
    pol = ServicePolicy(max_queue_depth=4)
    res = RequestScheduler(small_pim_cfg).run_service(reqs, policy=pol)
    assert res.rejected > 0
    assert res.completed + res.rejected == res.submitted == len(reqs)
    assert all(reason == "queue_full" for (_, reason) in res.rejected_by)
    # per-class reporting reaches both the result and the stats registry
    by_class = {c: n for (c, _), n in res.rejected_by.items()}
    for cls, n in by_class.items():
        assert res.stats.service_counts(cls)["rejected_queue_full"] == n
        assert res.summary()["per_class"][cls]["rejected"] == n


def test_token_bucket_sheds_at_rate(small_pim_cfg):
    reqs = overload_requests(small_pim_cfg, count=50, rate=2.0)
    pol = ServicePolicy(bucket_rate_per_us=0.2, bucket_burst=2)
    res = RequestScheduler(small_pim_cfg).run_service(reqs, policy=pol)
    assert res.rejected > 0
    assert all(reason == "rate_limited" for (_, reason) in res.rejected_by)
    assert res.completed + res.rejected == len(reqs)
    # shed requests never touched the device: admitted jobs' command
    # counts match a run of only the admitted population
    assert res.completed < len(reqs)


def test_rejected_rows_carry_no_timings(small_pim_cfg):
    reqs = overload_requests(small_pim_cfg, count=30, rate=3.0)
    res = RequestScheduler(small_pim_cfg).run_service(
        reqs, policy=ServicePolicy(max_queue_depth=2))
    rej = res.status == STATUS_REJECTED
    assert rej.any()
    assert np.isnan(res.dispatch_ns[rej]).all()
    assert np.isnan(res.done_ns[rej]).all()
    # percentiles and means only aggregate completed rows
    assert np.isfinite(res.latency_percentiles_us()["p99"])
    assert np.isfinite(res.summary()["mean_queue_delay_us"])


def test_policy_validation():
    with pytest.raises(ValueError):
        ServicePolicy(weight_latency=0.0)
    with pytest.raises(ValueError):
        ServicePolicy(max_queue_depth=0)
    with pytest.raises(ValueError):
        ServicePolicy(bucket_rate_per_us=-1.0)
    with pytest.raises(ValueError):
        ServicePolicy(batch_window_us=-0.1)
    with pytest.raises(ValueError):
        ServicePolicy(max_batch=0)
    with pytest.raises(ValueError):
        ServiceRequest(0.0, NttJob(256), qos="bulk")


# ---------------------------------------------------------------------------
# 3. batching: coalesced gang issues
# ---------------------------------------------------------------------------


def serving_cfg():
    """A deliberately bus-bound device: many banks on one shared bus,
    parameter cache sized to the whole (w0, r_w) program working set so
    coalesced members replay warm residency traces."""
    return PimConfig(num_buffers=2, num_channels=1, num_banks=8,
                     param_cache_entries=128)


def batching_policy(window_us=10.0, max_batch=4):
    return ServicePolicy(weight_latency=8.0, batch_window_us=window_us,
                         max_batch=max_batch)


def test_batching_coalesces_and_conserves(small_pim_cfg):
    reqs = mixed_requests(small_pim_cfg, NttJob(256), 30, 1.0, seed=5)
    res = RequestScheduler(small_pim_cfg).run_service(
        reqs, policy=batching_policy())
    assert res.batches > 0 and res.coalesced > res.batches
    # batching never changes the completion count
    assert res.completed == len(reqs)
    # only throughput-class rows ride a gang
    assert res.batched is not None
    for row in np.flatnonzero(res.batched):
        assert res.qos[row] == "throughput"


def test_batched_dispatch_zero_mapper_regeneration(small_pim_cfg):
    sched = RequestScheduler(small_pim_cfg)
    reqs = mixed_requests(small_pim_cfg, NttJob(256), 20, 1.0, seed=6)
    sched.run_service(reqs, policy=batching_policy())  # warm caches
    before = mapping.mapper_generations()
    res = sched.run_service(reqs, policy=batching_policy())
    assert mapping.mapper_generations() == before, (
        "a coalesced gang issue regenerated a mapper stream")
    assert res.batches > 0


def test_batch_members_share_gate_and_bank_order():
    cfg = serving_cfg()
    # staggered saturating arrivals so every gang forms at a distinct
    # gate (at t=0 several gangs would share gate 0.0 across banks)
    reqs = [ServiceRequest(t, NttJob(256))
            for t in poisson_arrivals(2.0, 40, 13)]
    res = RequestScheduler(cfg).run_service(
        reqs, policy=batching_policy(max_batch=4))
    assert res.batches > 0
    # members of one gang share a dispatch gate and complete in order
    gates = {}
    for row in np.flatnonzero(res.batched):
        gates.setdefault(res.dispatch_ns[row], []).append(res.done_ns[row])
    assert any(len(d) > 1 for d in gates.values())
    for dones in gates.values():
        assert dones == sorted(dones)


def test_batching_warm_traces_raise_hit_rate():
    cfg = serving_cfg()
    reqs = [ServiceRequest(t, NttJob(256))
            for t in poisson_arrivals(2.0, 60, 8)]
    fifo = RequestScheduler(cfg).run_service(reqs)
    bat = RequestScheduler(cfg).run_service(reqs, policy=batching_policy())
    assert bat.batches > 0
    assert bat.stats.param_hit_rate() > fifo.stats.param_hit_rate()


def test_no_dispatch_before_arrival_with_gang_parked_banks(small_pim_cfg):
    """A gang reservation parks banks at future release times, which
    runs the ingest cutoff ahead of the real dispatch gate; coalescing
    must never gang-issue a queued mate before it arrives (queue delay
    stays non-negative for every admitted request).

    Construction: a gang + two fillers occupy every bank; a queued
    winner arrives mid-flight; a same-spec burst is placed (calibrated
    from a FIFO run of the same prefix) to arrive just AFTER the bank
    release that gates the winner but BEFORE the gang's parked release
    — the cutoff ingests the whole burst early, and without the
    arrival<=gate guard the oldest burst members would ride the
    winner's gang with negative queue delay."""
    prefix = [
        ServiceRequest(0.0, ShardedNttJob(4096, banks=2), qos="throughput"),
        ServiceRequest(0.0, NttJob(1024), qos="throughput"),
        ServiceRequest(0.0, NttJob(1024), qos="throughput"),
        ServiceRequest(20e3, NttJob(256), qos="throughput"),
    ]
    warm = RequestScheduler(small_pim_cfg).run_service(prefix)
    gate = float(warm.dispatch_ns[3])      # winner waits for a filler bank
    parked = float(warm.done_ns[0])        # the gang's parked release
    assert 20e3 < gate, "winner must be gated by an in-flight completion"
    if gate + 900 >= parked:  # pragma: no cover - config drift guard
        pytest.skip("no window between filler release and gang release")
    reqs = prefix + [
        ServiceRequest(gate + 100.0 * (j + 1), NttJob(256), qos="throughput")
        for j in range(8)
    ]
    res = RequestScheduler(small_pim_cfg).run_service(
        reqs, policy=batching_policy(window_us=0.001))
    assert res.completed == len(reqs)
    delays = res.queue_delay_ns[res.status == 1]
    assert (delays >= 0).all(), delays


def test_window_does_not_cause_spurious_queue_full(small_pim_cfg):
    """A non-matching arrival inside a gang's window closes the window
    and is admission-checked at its own dispatch turn — combining
    batch_window_us with max_queue_depth must not shed requests the
    plain depth-bounded policy would admit."""
    reqs = [
        ServiceRequest(0.0, NttJob(256), qos="throughput"),
        ServiceRequest(1e3, PolymulJob(256), qos="latency"),
        ServiceRequest(30e3, PolymulJob(256), qos="latency"),
    ]
    plain = ServicePolicy(max_queue_depth=1)
    windowed = ServicePolicy(max_queue_depth=1, batch_window_us=50.0,
                             max_batch=8)
    a = RequestScheduler(small_pim_cfg).run_service(reqs, policy=plain)
    b = RequestScheduler(small_pim_cfg).run_service(reqs, policy=windowed)
    assert a.completed == b.completed == 3


def test_submit_shim_empty_batch_parity(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    res = quiet_submit(sess, sess.compile(PolymulOp(256)), count=0).timing
    assert res.submitted == res.completed == 0
    assert res.makespan_ns == 0.0
    assert res.latency_percentiles_us() == {"p50": 0.0, "p95": 0.0, "p99": 0.0}


@pytest.mark.slow
def test_acceptance_batching_improves_saturated_throughput():
    """The acceptance criterion in miniature: at ~2x arrival saturation,
    window batching improves throughput-class jobs/ms while latency-class
    p99 stays within 10% of the unbatched FIFO baseline."""
    cfg = PimConfig(num_buffers=2, num_channels=1, num_banks=16,
                    param_cache_entries=128)
    reqs = mixed_requests(cfg, NttJob(256), 200, 4.0, seed=3)
    fifo = RequestScheduler(cfg).run_service(reqs)
    bat = RequestScheduler(cfg).run_service(
        reqs, policy=batching_policy(window_us=10.0, max_batch=4))
    assert (bat.class_throughput_jobs_per_ms("throughput")
            > fifo.class_throughput_jobs_per_ms("throughput"))
    assert (bat.latency_percentiles_us(qos="latency")["p99"]
            <= 1.10 * fifo.latency_percentiles_us(qos="latency")["p99"])


# ---------------------------------------------------------------------------
# 4. futures: laziness, composition, epochs
# ---------------------------------------------------------------------------


def test_future_resolves_lazily(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    futs = [svc.submit(plan, at_us=i * 5.0) for i in range(4)]
    assert not any(f.done() for f in futs)
    assert svc.pending() == 4
    rec = futs[2].result()  # forces the whole epoch
    assert all(f.done() for f in futs)
    assert svc.pending() == 0
    assert rec.ok and rec.latency_us > 0
    assert rec.arrival_us == pytest.approx(10.0)


def test_gather_and_as_completed_order(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(PolymulOp(256))
    futs = svc.submit_poisson(plan, 12, 0.3, seed=9)
    recs = svc.gather(futs)
    assert [r.index for r in recs] == list(range(12))  # submission order
    done_order = [f.result().done_us for f in svc.as_completed(futs)]
    assert done_order == sorted(done_order)


def test_rejected_future_resolves_with_status(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg,
                        policy=ServicePolicy(max_queue_depth=1))
    plan = svc.session.compile(PolymulOp(256))
    futs = svc.submit_poisson(plan, 20, 3.0, seed=10)
    recs = svc.gather(futs)
    rejected = [r for r in recs if not r.ok]
    assert rejected, "overload under depth=1 must shed"
    for r in rejected:
        assert r.status == "rejected"
        assert np.isnan(r.latency_us)
    # rejected futures sort after completed ones in as_completed
    tail = list(svc.as_completed(futs))[-len(rejected):]
    assert all(not f.result().ok for f in tail)


def test_shim_does_not_disturb_pending_service_futures(small_pim_cfg):
    """The deprecated submit()/run(BatchOp) shim uses its own service:
    futures pending on the user-facing service() singleton survive a
    shim call un-flushed and still resolve afterwards."""
    from repro.pimsys import BatchOp

    sess = PimSession(small_pim_cfg)
    svc = sess.service()
    fut = svc.submit(sess.compile(NttOp(256)))
    r = sess.run(sess.compile(BatchOp(PolymulOp(256), 2)))  # shim path
    assert r.timing.completed == 2
    assert not fut.done() and svc.pending() == 1
    assert fut.result().ok


def test_as_completed_orders_by_epoch_first(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    first = [svc.submit(plan, at_us=10.0)]
    svc.flush()
    second = [svc.submit(plan, at_us=0.0)]
    out = [f.result() for f in svc.as_completed(first + second)]
    # epoch timelines are independent (each restarts at t=0): epoch
    # order wins even though the later epoch's done time is smaller
    assert [r.epoch for r in out] == [0, 1]
    assert out[0].done_us > out[1].done_us


def test_retained_and_unretained_epochs_number_monotonically(small_pim_cfg):
    """flush(retain=False) must still advance the epoch counter, so
    as_completed's epoch-first ordering stays correct across mixed
    retained/unretained flushes."""
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    f1 = svc.submit(plan)
    svc.flush(retain=False)
    f2 = svc.submit(plan)
    svc.flush()
    assert (f1.result().epoch, f2.result().epoch) == (0, 1)
    assert [f.result().epoch for f in svc.as_completed([f2, f1])] == [0, 1]
    assert len(svc.results) == 1  # only the retained epoch is kept


def test_epochs_are_isolated(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    first = svc.submit(plan).result()
    second = svc.submit(plan).result()
    # a fresh epoch replays on a fresh device timeline: same outcome
    assert first.latency_us == second.latency_us
    assert len(svc.results) == 2
    with pytest.raises(RuntimeError):
        svc.flush()  # nothing pending


def test_service_validation(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    with pytest.raises(ValueError):
        svc.submit(plan, qos="best-effort")
    with pytest.raises(ValueError):
        svc.submit_poisson(plan, 0, 1.0)
    with pytest.raises(ValueError):
        svc.submit_poisson(plan, 4, -1.0)
    with pytest.raises(TypeError):
        from repro.pimsys import BatchOp

        svc.submit(svc.session.compile(BatchOp(NttOp(256), 2)))
    with pytest.raises(ValueError):
        other = PimSession(small_pim_cfg.with_(num_buffers=6))
        svc.submit(other.compile(NttOp(256)))
    with pytest.raises(ValueError):
        DeviceService(PimSession(small_pim_cfg), cfg=small_pim_cfg)
    # a misfit plan fails at SUBMIT time, leaving the epoch intact —
    # a bad submission must not orphan other pending futures at flush
    tiny = DeviceService(cfg=small_pim_cfg.with_(rows_per_bank=1))
    ok = tiny.submit(tiny.session.compile(NttOp(256)))
    with pytest.raises(ValueError):
        tiny.submit(tiny.session.compile(NttOp(1024)))
    assert tiny.pending() == 1 and ok.result().ok


def test_sharded_gang_through_service(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    fut = svc.submit(svc.session.compile(ShardedNttOp(512, 2)),
                     qos="latency", deadline_us=1e6)
    rec = fut.result()
    assert rec.ok and rec.met_deadline
    assert isinstance(rec.job, ShardedNttJob)


# ---------------------------------------------------------------------------
# 5. deadlines + seed reproducibility
# ---------------------------------------------------------------------------


def test_deadline_attainment_accounting(small_pim_cfg):
    sched = RequestScheduler(small_pim_cfg)
    # generous deadlines: everyone attains
    reqs = mixed_requests(small_pim_cfg, PolymulJob(256), 16, 0.3, seed=12,
                          deadline_ns=1e9)
    res = sched.run_service(reqs)
    assert res.deadline_attainment() == 1.0
    # impossible deadlines: nobody does, per class and overall
    tight = [ServiceRequest(r.arrival_ns, r.job, qos=r.qos, deadline_ns=1.0)
             for r in reqs]
    res2 = sched.run_service(tight)
    assert res2.deadline_attainment() == 0.0
    for cls in ("latency", "throughput"):
        assert res2.summary()["per_class"][cls]["deadline_attainment"] == 0.0
    # no deadlines at all reads as attained
    plain = [ServiceRequest(r.arrival_ns, r.job, qos=r.qos) for r in reqs]
    assert sched.run_service(plain).deadline_attainment() == 1.0


def test_future_reports_deadline(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    ok = svc.submit(plan, qos="latency", deadline_us=1e6)
    miss = svc.submit(plan, qos="latency", deadline_us=1e-3)
    assert ok.result().met_deadline is True
    assert miss.result().met_deadline is False
    none = svc.submit(plan)
    assert none.result().met_deadline is None


def test_seed_recorded_and_reproducible(small_pim_cfg):
    def run(seed):
        svc = DeviceService(cfg=small_pim_cfg)
        plan = svc.session.compile(PolymulOp(256))
        svc.submit_poisson(plan, 12, 0.4, seed=seed)
        return svc.result()

    a, b, c = run(21), run(21), run(22)
    assert a.seed == b.seed == 21 and c.seed == 22
    assert a.summary()["seed"] == 21
    # byte-for-byte reproducibility of the serialized summary
    assert json.dumps(a.summary()) == json.dumps(b.summary())
    assert json.dumps(a.summary()) != json.dumps(c.summary())
    assert np.array_equal(a.done_ns, b.done_ns)


def test_multi_seed_epoch_records_all(small_pim_cfg):
    svc = DeviceService(cfg=small_pim_cfg)
    plan = svc.session.compile(NttOp(256))
    svc.submit_poisson(plan, 4, 0.5, seed=1)
    svc.submit_poisson(plan, 4, 0.5, seed=2, start_us=200.0)
    res = svc.result()
    assert res.seed == [1, 2]
    assert res.summary()["seed"] == [1, 2]
