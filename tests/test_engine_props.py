"""Hypothesis properties of the hierarchical resource engine.

Three properties the ISSUE pins:
  (a) `param_cache_entries=0` is bit-identical — in cycle counts AND
      command lists — to the pre-refactor model: the default path, an
      explicit all-miss trace, and the session path all agree, and the
      mapper output is independent of every engine-level knob;
  (b) enabling the cache never increases latency, at any cache size, on
      single-bank, multibank, and sharded workloads (rr arbitration:
      grant order is gate-driven, so per-op charges only shrink);
  (c) with rank timing enabled, any tFAW-wide slice of a recorded ACT
      trace contains at most 4 activations per rank.

Skips as a module when hypothesis is absent (the `hypo` shim).
"""
from hypo import given, settings, st

from repro.core.mapping import RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.pimsim import PARAM_OPS, BankTimer
from repro.pimsys import (
    ChannelController,
    Device,
    DeviceTopology,
    ShardedNttPlan,
    param_beat_trace,
)

SIZES = [64, 128, 256, 512, 1024]
NBS = [1, 2, 4, 6]


# ---------------------------------------------------------------------------
# (a) entries=0 == pre-refactor, bit for bit
# ---------------------------------------------------------------------------


@given(st.sampled_from(SIZES), st.sampled_from(NBS), st.booleans())
@settings(max_examples=20)
def test_zero_cache_bit_identical_single(n, nb, forward):
    cfg = PimConfig(num_buffers=nb)
    cmds = RowCentricMapper(cfg, n, forward=forward).commands()
    # command lists are engine-agnostic: no timing knob reaches the mapper
    cfg_knobs = cfg.with_(param_cache_entries=7, tFAW=24, tRRD=4)
    assert RowCentricMapper(cfg_knobs, n, forward=forward).commands() == cmds
    ref = BankTimer(cfg).simulate(cmds)
    # an explicit all-miss trace is the same model as "no trace"
    full = cfg.param_load_cycles
    all_miss = tuple((full, 1) for c in cmds if c.__class__ in PARAM_OPS)
    r = BankTimer(cfg).simulate(cmds, all_miss)
    assert r.ns == ref.ns
    assert r.phase_ns == ref.phase_ns


@given(st.sampled_from(SIZES), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from(["rr", "ready"]))
@settings(max_examples=15)
def test_zero_cache_bit_identical_multibank(n, banks, policy):
    cfg = PimConfig(num_buffers=2)
    cmds = RowCentricMapper(cfg, n).commands()

    def run(cfg):
        ctrl = ChannelController(cfg, policy=policy)
        for i in range(banks):
            ctrl.enqueue(ctrl.add_bank(), cmds, job_id=i)
        ctrl.drain()
        return ctrl.makespan_ns

    # entries=0 IS the default model (the field only gates the trace)
    assert run(cfg.with_(param_cache_entries=0)) == run(cfg)


# ---------------------------------------------------------------------------
# (b) the cache never increases latency
# ---------------------------------------------------------------------------


@given(st.sampled_from(SIZES), st.sampled_from(NBS),
       st.sampled_from([1, 2, 8, 64]))
@settings(max_examples=20)
def test_cache_never_slower_single(n, nb, entries):
    cfg = PimConfig(num_buffers=nb)
    cmds = RowCentricMapper(cfg, n).commands()
    base = BankTimer(cfg).simulate(cmds).ns
    cfg_c = cfg.with_(param_cache_entries=entries)
    cached = BankTimer(cfg_c).simulate(
        cmds, param_beat_trace(cfg_c, n, cmds)).ns
    assert cached <= base + 1e-9


@given(st.sampled_from(SIZES), st.sampled_from([2, 4, 8, 16]),
       st.sampled_from([1, 8, 64]))
@settings(max_examples=15)
def test_cache_never_slower_multibank(n, banks, entries):
    cmds = RowCentricMapper(PimConfig(num_buffers=2), n).commands()

    def run(cfg):
        ctrl = ChannelController(cfg)
        trace = param_beat_trace(cfg, n, cmds)
        for i in range(banks):
            ctrl.enqueue(ctrl.add_bank(), cmds, job_id=i, param_trace=trace)
        ctrl.drain()
        return ctrl.makespan_ns

    assert run(PimConfig(num_buffers=2, param_cache_entries=entries)) \
        <= run(PimConfig(num_buffers=2)) + 1e-9


@given(st.sampled_from([256, 512, 1024]), st.sampled_from([2, 4]),
       st.sampled_from([1, 8]), st.booleans())
@settings(max_examples=10)
def test_cache_never_slower_sharded(n, banks, entries, forward):
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    base = ShardedNttPlan(cfg, n, banks, forward=forward).simulate(
        baseline=False).latency_ns
    cached = ShardedNttPlan(cfg.with_(param_cache_entries=entries), n, banks,
                            forward=forward).simulate(baseline=False).latency_ns
    assert cached <= base + 1e-9


# ---------------------------------------------------------------------------
# (c) the tFAW trace invariant
# ---------------------------------------------------------------------------


@given(st.sampled_from([256, 512, 1024]), st.sampled_from([2, 4, 8]),
       st.sampled_from([12, 24, 40]), st.sampled_from(["rr", "ready"]))
@settings(max_examples=12)
def test_tfaw_window_invariant(n, banks, tfaw, policy):
    cfg = PimConfig(num_buffers=2, tFAW=tfaw, tRRD=2)
    dev = Device(cfg, DeviceTopology(channels=1, banks_per_rank=banks),
                 policy=policy, record_acts=True)
    cmds = RowCentricMapper(cfg, n).commands()
    for f in range(banks):
        dev.enqueue_flat(f, cmds, job_id=f)
    dev.drain()
    acts = sorted(dev.channels[0].act_starts(0))
    faw_ns = tfaw * cfg.dram_ns
    # sliding window: the 5th ACT after any ACT starts >= tFAW later,
    # i.e. every tFAW-wide slice of the trace holds <= 4 activations
    for i in range(len(acts) - 4):
        assert acts[i + 4] >= acts[i] + faw_ns - 1e-9
