"""Shared hypothesis boilerplate for the property-based test modules.

Importing this module from a test file replaces the per-file

    pytest.importorskip("hypothesis", ...)
    from hypothesis import given, settings
    from hypothesis import strategies as st

stanza: `importorskip` raises pytest's Skipped at *import* time, so any
module doing ``from hypo import given, settings, st`` is skipped as a
whole when hypothesis is absent — identical behaviour, one copy.

It also installs the suite-wide settings profile once: no deadline
(simulator- and interpreter-heavy properties routinely blow the 200 ms
default), so individual tests only state what varies (`max_examples`).
"""
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

settings.register_profile(
    "repro",
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("repro")

__all__ = ["HealthCheck", "given", "settings", "st"]
