"""Session API (`repro.pimsys.session`) — parity, caching, and shims.

Four layers:
  1. parity: `PimSession` results are bit-identical — values, cycle
     counts, command lists — to every legacy entry point it shims
     (`simulate_ntt`, `simulate_multibank`, `simulate_ntt_sharded`,
     `pim_polymul`, `pim_ntt_sharded`, `polymul_batch`);
  2. plan cache: compile is memoized by (cfg, op) with hit/miss
     accounting, spelling variants share entries, and a repeated run()
     performs ZERO mapper/twiddle regeneration (the
     `core.mapping.mapper_generations` counter proves it);
  3. unified results: RunResult carries functional value, timing, a
     StatsRegistry snapshot, and a replayable TraceHandle;
  4. deprecation: each legacy shim emits exactly one DeprecationWarning
     per call (no cascades through nested shims).

The hypothesis twin lives in `test_session_props.py`.
"""
import warnings

import numpy as np
import pytest

from repro.core import mapping, modmath as mm, ntt
from repro.core.mapping import RowCentricMapper, twiddle_index
from repro.core.pim_config import PimConfig
from repro.core.pimsim import (
    BankTimer,
    simulate_multibank,
    simulate_ntt,
    simulate_ntt_sharded,
)
from repro.core.polymul import (
    pim_ntt_sharded,
    pim_polymul,
    polymul_batch,
    polymul_commands,
)
from repro.pimsys import (
    BatchOp,
    CompiledPlan,
    InverseNttOp,
    NttOp,
    PimSession,
    PolymulOp,
    RequestScheduler,
    PolymulJob,
    ShardedNttOp,
    dumps_trace,
)

Q = mm.DEFAULT_Q


def rand_poly(n, seed):
    return np.random.default_rng(seed).integers(0, Q, n).astype(np.uint32)


def quiet(fn, *a, **kw):
    """Call a legacy shim with its DeprecationWarning suppressed."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*a, **kw)


# ---------------------------------------------------------------------------
# 1. parity with every legacy entry point
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("forward", [False, True])
@pytest.mark.parametrize("nb", [1, 2, 4])
def test_parity_simulate_ntt(forward, nb):
    n, cfg = 1024, PimConfig(num_buffers=nb)
    sess = PimSession(cfg)
    got = sess.run(sess.compile(NttOp(n, forward=forward))).timing
    ref = quiet(simulate_ntt, n, cfg, forward=forward)
    assert got.ns == ref.ns  # exact, not approx
    assert got.stats == ref.stats
    assert got.phase_ns == ref.phase_ns


def test_parity_simulate_ntt_command_list(small_pim_cfg):
    n = 512
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(NttOp(n))
    assert list(plan.commands) == RowCentricMapper(small_pim_cfg, n).commands()


def test_parity_pim_polymul(small_pim_cfg):
    n = 512
    cfg = small_pim_cfg.with_(num_buffers=4)
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n, 1), rand_poly(n, 2)
    ref_out, ref_t = quiet(pim_polymul, a, b, ctx, cfg)
    sess = PimSession(cfg)
    plan = sess.compile(PolymulOp(n))
    r = sess.run(plan, a, b, ctx=ctx)
    assert np.array_equal(r.value, ref_out)
    assert np.array_equal(r.value, ntt.polymul_negacyclic_np(a, b, ctx))
    assert r.timing.ns == ref_t.ns
    assert r.timing.stats == ref_t.stats
    # command-LIST identity with the legacy stream builder
    assert list(plan.commands) == polymul_commands(cfg, n)[0]


@pytest.mark.parametrize("forward", [False, True])
def test_parity_pim_ntt_sharded(small_pim_cfg, forward):
    n, banks = 512, 4
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, 3)
    ref_out, ref_plan = quiet(pim_ntt_sharded, a, ctx, small_pim_cfg,
                              banks=banks, forward=forward)
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(ShardedNttOp(n, banks, forward=forward))
    r = sess.run(plan, a, ctx=ctx, time=False)
    assert np.array_equal(r.value, ref_out)
    # per-bank command streams are identical
    assert plan.sharded_plan.local_streams() == ref_plan.local_streams()
    assert plan.sharded_plan.exchange_stages() == ref_plan.exchange_stages()


def test_parity_simulate_ntt_sharded(small_pim_cfg):
    n, banks = 1024, 4
    ref = quiet(simulate_ntt_sharded, n, banks, small_pim_cfg)
    sess = PimSession(small_pim_cfg)
    got = sess.run(sess.compile(ShardedNttOp(n, banks))).timing
    for f in ("latency_ns", "local_ns", "exchange_ns", "single_ns",
              "analytic_local_ns", "exchange_bus_occupancy",
              "xfer_atoms", "xfer_hops"):
        assert getattr(got, f) == getattr(ref, f), f
    assert got.stats.device_counts() == ref.stats.device_counts()


@pytest.mark.parametrize("banks", [1, 2, 8])
def test_parity_simulate_multibank(banks):
    cfg = PimConfig(num_buffers=2)
    ref = quiet(simulate_multibank, 1024, banks, cfg)
    sess = PimSession(cfg)
    got = sess.run(sess.compile(BatchOp(NttOp(1024), banks))).timing
    assert got == ref  # full dataclass equality: every field bit-identical


def test_parity_polymul_batch(small_pim_cfg):
    ref = quiet(polymul_batch, 512, 8, small_pim_cfg)
    sess = PimSession(small_pim_cfg)
    got = sess.run(sess.compile(BatchOp(PolymulOp(512), 8))).timing
    assert got.makespan_ns == ref.makespan_ns
    assert np.array_equal(got.done_ns, ref.done_ns)
    assert np.array_equal(got.dispatch_ns, ref.dispatch_ns)
    assert got.stats.device_counts() == ref.stats.device_counts()


def test_parity_submit_open_loop(small_pim_cfg):
    """Priming the scheduler with a compiled plan changes nothing about
    the open-loop result vs the raw RequestScheduler path."""
    ref = RequestScheduler(small_pim_cfg).run_open_loop(
        [PolymulJob(512)] * 12, rate_per_us=0.1, seed=7)
    sess = PimSession(small_pim_cfg)
    got = quiet(sess.submit, sess.compile(PolymulOp(512)), count=12,
                rate_per_us=0.1, seed=7).timing
    assert got.makespan_ns == ref.makespan_ns
    assert np.array_equal(got.done_ns, ref.done_ns)
    assert np.array_equal(got.arrivals_ns, ref.arrivals_ns)


# ---------------------------------------------------------------------------
# 2. plan cache + zero-regeneration guarantees
# ---------------------------------------------------------------------------


def test_plan_cache_hit_miss_accounting(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    p1 = sess.compile(NttOp(256))
    assert (sess.plan_misses, sess.plan_hits) == (1, 0)
    p2 = sess.compile(NttOp(256))
    assert p2 is p1  # the identical frozen object, not a copy
    assert (sess.plan_misses, sess.plan_hits) == (1, 1)
    p3 = sess.compile(NttOp(512))
    assert p3 is not p1
    assert (sess.plan_misses, sess.plan_hits) == (2, 1)


def test_plan_cache_spelling_variants_share_entry(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    assert sess.compile(InverseNttOp(256)) is sess.compile(NttOp(256))
    # the forward orientation is a different plan
    assert sess.compile(NttOp(256, forward=True)) is not sess.compile(NttOp(256))


def test_second_run_zero_mapper_regeneration(small_pim_cfg):
    """The acceptance-criteria counter test: a repeated run() on a cached
    plan performs NO mapper (twiddle-stream) regeneration, for every op
    kind including timing."""
    sess = PimSession(small_pim_cfg)
    ctx = ntt.make_context(Q, 256)
    a, b = rand_poly(256, 4), rand_poly(256, 5)
    plans = {
        "ntt": (sess.compile(NttOp(256)), (a,)),
        "polymul": (sess.compile(PolymulOp(256)), (a, b)),
        "sharded": (sess.compile(ShardedNttOp(256, 4)), (a,)),
        "batch": (sess.compile(BatchOp(NttOp(256), 4)), ()),
    }
    for name, (plan, inputs) in plans.items():
        sess.run(plan, *inputs, ctx=ctx if inputs else None)  # warm run
        before = mapping.mapper_generations()
        sess.run(plan, *inputs, ctx=ctx if inputs else None)  # cached run
        assert mapping.mapper_generations() == before, (
            f"{name}: second run regenerated a mapper stream")


def test_second_submit_zero_mapper_regeneration(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(PolymulOp(256))
    quiet(sess.submit, plan, count=4)
    before = mapping.mapper_generations()
    quiet(sess.submit, plan, count=4)
    assert mapping.mapper_generations() == before


def test_twiddle_param_stream_precomputed(small_pim_cfg):
    """The plan's (w0, r_w)-equivalent parameter streams match the table
    indices the functional executor resolves per CU op."""
    from repro.core.mapping import C1, C2, BUWord

    n = 512
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(NttOp(n))
    cu_ops = [c for c in plan.commands if isinstance(c, (C1, C2, BUWord))]
    assert len(plan.twiddle_params) == len(cu_ops)
    for cmd, params in zip(cu_ops, plan.twiddle_params):
        assert params  # every CU op resolves at least one twiddle
        if isinstance(cmd, C2):
            assert params == tuple(
                twiddle_index(n, cmd.stride, base) for base in cmd.bases_u)


def test_baseline_cached_per_size(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    t1 = sess.baseline(1024)
    before = mapping.mapper_generations()
    t2 = sess.baseline(1024)
    assert t2 is t1 and mapping.mapper_generations() == before
    assert t1.ns == BankTimer(small_pim_cfg).simulate(
        RowCentricMapper(small_pim_cfg, 1024).commands()).ns


# ---------------------------------------------------------------------------
# 3. unified RunResult: stats snapshot + trace handle
# ---------------------------------------------------------------------------


def test_run_result_stats_snapshot(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    r = sess.run(sess.compile(NttOp(512)))
    assert r.value is None  # timing-only run
    assert r.stats.bank_counts(0, 0) == r.timing.stats
    assert r.stats.device_counts()["c2"] > 0


def test_run_result_trace_handle_replayable(small_pim_cfg):
    from repro.pimsys import loads_trace, replay_trace

    sess = PimSession(small_pim_cfg)
    plan = sess.compile(NttOp(256))
    r = sess.run(plan)
    text = r.trace.dumps()
    assert text == dumps_trace({(0, 0): list(plan.commands)})
    dev = replay_trace(small_pim_cfg, loads_trace(text))
    assert dev.makespan_ns == r.timing.ns  # trace replays to live timing


def test_run_result_sharded_trace_matches_plan(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(ShardedNttOp(512, 4))
    r = sess.run(plan)
    assert r.trace.dumps() == dumps_trace(plan.sharded_plan.trace_streams())


def test_scheduler_prime_rejects_misfit_and_gangs(small_pim_cfg):
    from repro.pimsys import NttJob, ShardedNttJob

    sched = RequestScheduler(small_pim_cfg.with_(rows_per_bank=4))
    with pytest.raises(ValueError):
        sched.prime(NttJob(4096), [])
    with pytest.raises(TypeError):
        RequestScheduler(small_pim_cfg).prime(ShardedNttJob(512, banks=2), [])


def test_run_validation_errors(small_pim_cfg):
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(NttOp(256))
    with pytest.raises(ValueError):  # wrong input arity
        sess.run(plan, rand_poly(256, 0), rand_poly(256, 1))
    with pytest.raises(ValueError):  # wrong length
        sess.run(plan, rand_poly(512, 0))
    with pytest.raises(ValueError):  # plan from another config
        PimSession(small_pim_cfg.with_(num_buffers=6)).run(plan)
    with pytest.raises(TypeError):  # batches batch NttOp/PolymulOp only
        sess.compile(BatchOp(ShardedNttOp(256, 2), 2))
    with pytest.raises(ValueError):
        sess.compile(BatchOp(NttOp(256), 0))
    with pytest.raises(ValueError):  # batch runs are timing-only
        sess.run(sess.compile(BatchOp(NttOp(256), 2)), rand_poly(256, 0))
    with pytest.raises(ValueError):  # polymul inputs must match the plan's n
        sess.run(sess.compile(PolymulOp(512)), rand_poly(256, 0),
                 rand_poly(256, 1))


def test_scheduler_routed_batch_has_no_static_trace(small_pim_cfg):
    """Scheduler-placed work carries no trace handle (placement is
    dynamic), and both run() and submit() report the BatchOp itself."""
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(BatchOp(PolymulOp(256), 4))
    r = sess.run(plan)
    assert r.trace is None
    assert r.op == plan.op
    assert sess.run(plan, time=False).trace is None
    assert quiet(sess.submit, plan).op == plan.op


def test_batch_time_false_skips_simulation(small_pim_cfg):
    """time=False on a batch plan validates without paying the device
    simulation: no timing, and no commands issued anywhere."""
    sess = PimSession(small_pim_cfg)
    plan = sess.compile(BatchOp(NttOp(256), 4))
    before = mapping.mapper_generations()
    r = sess.run(plan, time=False)
    assert r.timing is None and r.stats is None
    assert mapping.mapper_generations() == before
    assert set(r.trace.streams) == {(0, i) for i in range(4)}


# ---------------------------------------------------------------------------
# 4. deprecation discipline of the legacy shims
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,call", [
    ("simulate_ntt", lambda cfg, a, ctx: simulate_ntt(256, cfg)),
    ("simulate_multibank", lambda cfg, a, ctx: simulate_multibank(256, 2, cfg)),
    ("simulate_ntt_sharded", lambda cfg, a, ctx: simulate_ntt_sharded(256, 2, cfg)),
    ("pim_polymul", lambda cfg, a, ctx: pim_polymul(a, a, ctx, cfg)),
    ("pim_ntt_sharded", lambda cfg, a, ctx: pim_ntt_sharded(a, ctx, cfg, banks=2)),
    ("polymul_batch", lambda cfg, a, ctx: polymul_batch(256, 2, cfg)),
    ("PimSession.submit",
     lambda cfg, a, ctx: PimSession(cfg).submit(PolymulOp(256), count=2)),
])
def test_legacy_shim_warns_exactly_once(small_pim_cfg, name, call):
    ctx = ntt.make_context(Q, 256)
    a = rand_poly(256, 9)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        call(small_pim_cfg, a, ctx)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, f"{name}: {len(dep)} DeprecationWarnings"
    assert name in str(dep[0].message)


def test_session_api_emits_no_warnings(small_pim_cfg):
    """The supported surface — run(), run(BatchOp), and the futures
    service — is warning-free; only the deprecated shims (including
    `PimSession.submit`, tested above) warn."""
    sess = PimSession(small_pim_cfg)
    ctx = ntt.make_context(Q, 256)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sess.run(sess.compile(PolymulOp(256)), rand_poly(256, 0),
                 rand_poly(256, 1), ctx=ctx)
        sess.run(sess.compile(ShardedNttOp(256, 2)))
        sess.run(sess.compile(BatchOp(PolymulOp(256), 2)))
        svc = sess.service()
        svc.submit_poisson(sess.compile(PolymulOp(256)), 2, 0.1)
        svc.flush()
    assert [x for x in w if issubclass(x.category, DeprecationWarning)] == []
