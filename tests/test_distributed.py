"""Distribution layer: sharding rules, virtual-mesh pjit, compression.

Multi-device tests run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest
process stays single-device (per the dry-run contract)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.optim import OptConfig

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# rule engine (no devices needed)
# ---------------------------------------------------------------------------


def _fake_mesh():
    # abstract mesh over 1 device would sanitize everything; use dims of 1
    # via a real 1-device mesh only for spec CALCULATION tests we check the
    # rule fn directly instead.
    return None


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P

    class M:  # minimal mesh stub
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = shd._param_spec("['blocks'][0]['mixer']['wq']", 3, M)
    assert spec == P(None, ("data",), "model")
    spec = shd._param_spec("['embed']", 2, M)
    assert spec == P(("data",), "model")
    spec = shd._param_spec("['blocks'][0]['ffn']['wi']", 4, M)  # MoE (reps,E,D,F)
    assert spec == P(None, "model", ("data",), None)
    spec = shd._param_spec("['blocks'][0]['ln1']", 2, M)
    assert spec == P(None, None)


def test_sanitize_drops_indivisible():
    from jax.sharding import PartitionSpec as P

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    s = shd._sanitize(M, P("model", "data"), (48, 64))
    assert s == P("model", "data")  # both divisible by 16: kept
    s = shd._sanitize(M, P("model", "data"), (48, 30))
    assert s == P("model", None)  # 30 % 16 != 0: dropped
    s = shd._sanitize(M, P("model", "data"), (50, 30))
    assert s == P(None, None)


def test_dp_axes_both_meshes():
    class M2:
        axis_names = ("data", "model")

    class M3:
        axis_names = ("pod", "data", "model")

    assert shd.dp_axes(M2) == ("data",)
    assert shd.dp_axes(M3) == ("pod", "data")


# ---------------------------------------------------------------------------
# virtual-mesh integration (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pjit_train_step_small_mesh():
    """A reduced model trains one step under a 2x4 mesh with our rules, and
    the result matches the single-device step bit-for-bit in fp32."""
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.distributed import sharding as shd
        from repro.launch import steps as S
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as T
        from repro.optim import OptConfig
        from repro.data.pipeline import SyntheticStream

        cfg = get_config('qwen3-8b').reduced()
        opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
        mesh = make_host_mesh(data=2, model=4)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        init_opt = S.make_opt_init(cfg, opt_cfg)
        opt = init_opt(params)
        batch = {k: jnp.asarray(v) for k, v in SyntheticStream(cfg, 4, 32).batch_at(0).items()}

        step = S.make_train_step(cfg, opt_cfg)
        # single device reference
        p_ref, _, m_ref = step(params, opt, batch, jnp.int32(0))

        p_sh = shd.param_shardings(mesh, jax.eval_shape(lambda: params))
        o_sh = shd.opt_shardings(mesh, jax.eval_shape(lambda: opt))
        b_sh = shd.batch_shardings(mesh, jax.eval_shape(lambda: batch))
        with mesh:
            jit_step = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh, None),
                               out_shardings=(p_sh, o_sh, None))
            p_new, o_new, metrics = jit_step(params, opt, batch, jnp.int32(0))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), p_ref, p_new)
        print('MAXDIFF', max(jax.tree.leaves(d)))
        print('LOSS', float(metrics['loss']), float(m_ref['loss']))
        """
    )
    maxdiff = float(out.split("MAXDIFF")[1].split()[0])
    assert maxdiff < 5e-3, out  # bf16 reduction-order wiggle only


@pytest.mark.slow
def test_compressed_psum_small_mesh():
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.compression import compressed_psum
        from repro.launch.mesh import make_host_mesh

        kw = {}
        at = getattr(jax.sharding, 'AxisType', None)  # absent pre-0.5 jax
        if at is not None:
            kw['axis_types'] = (at.Auto,) * 2
        mesh = jax.make_mesh((2, 4), ('pod', 'data'), **kw)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)

        def f(x):
            return compressed_psum(x, 'pod')

        g = shard_map(f, mesh=mesh, in_specs=P('pod', None), out_specs=P('pod', None))
        got = g(x)  # per-pod sum of the two pod shards
        exact = x[:4] + x[4:]
        err = float(jnp.max(jnp.abs(got[:4] - exact)))
        scale = float(jnp.max(jnp.abs(x)) / 127.0)
        print('ERR', err, 'BOUND', 2 * scale)
        assert err <= 2 * scale + 1e-6
        """
    )
    assert "ERR" in out


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save under a 4-device mesh, restore under 2 devices (elastic)."""
    out = run_subprocess(
        f"""
        import jax, jax.numpy as jnp
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_host_mesh

        mgr = CheckpointManager({str(tmp_path)!r})
        mesh = make_host_mesh(data=4, model=1)
        state = {{'embed': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        sh = shd.param_shardings(mesh, jax.eval_shape(lambda: state))
        state = jax.tree.map(jax.device_put, state, sh)
        mgr.save(1, state)

        mesh2 = make_host_mesh(data=2, model=1)  # "smaller cluster"
        sh2 = shd.param_shardings(mesh2, jax.eval_shape(lambda: state))
        restored, _ = mgr.restore(1, jax.eval_shape(lambda: state), sh2)
        assert restored['embed'].sharding.mesh.shape['data'] == 2
        import numpy as np
        np.testing.assert_array_equal(np.asarray(restored['embed']).ravel(), np.arange(64))
        print('ELASTIC OK')
        """,
        devices=4,
    )
    assert "ELASTIC OK" in out


# ---------------------------------------------------------------------------
# spec coverage for every arch (abstract, no devices)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-8b", "kimi-k2-1t-a32b", "mamba2-780m", "whisper-small"])
def test_shardings_cover_every_param(arch):
    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        def __init__(self):
            pass

    cfg = get_config(arch)
    shapes = steps_lib.param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_sharded = 0
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        spec = shd._param_spec(pstr, leaf.ndim, M)
        spec = shd._sanitize(M, jax.sharding.PartitionSpec(
            *spec, *([None] * (leaf.ndim - len(spec)))), leaf.shape)
        assert len(spec) <= leaf.ndim
        if any(s is not None for s in spec):
            n_sharded += 1
    # the overwhelming majority of parameter BYTES must be sharded
    assert n_sharded >= len(flat) * 0.4, (arch, n_sharded, len(flat))
