"""PIM simulator: functional bit-exactness, timing invariants, paper claims."""
import numpy as np
import pytest

from repro.core import area, modmath as mm, ntt
from repro.core.mapping import RowCentricMapper, pim_ntt
from repro.core.pim_config import EnergyModel, PimConfig
from repro.core.pimsim import BankTimer, simulate_ntt
from repro.core.polymul import pim_polymul

Q = mm.DEFAULT_Q
RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# functional: command-stream execution == reference NTT (the paper's own
# "two-way DRAMsim3 communication to double-check ... functionality")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 256, 1024, 4096])
@pytest.mark.parametrize("nb", [1, 2, 4, 6])
def test_functional_inverse(n, nb):
    ctx = ntt.make_context(Q, n)
    a = RNG.integers(0, Q, n).astype(np.uint32)
    got, _ = pim_ntt(a, ctx, PimConfig(num_buffers=nb))
    assert np.array_equal(got, ntt.ntt_inverse_np(a, ctx))


@pytest.mark.parametrize("n", [64, 1024])
@pytest.mark.parametrize("nb", [1, 2, 5])
def test_functional_forward(n, nb):
    ctx = ntt.make_context(Q, n)
    a = RNG.integers(0, Q, n).astype(np.uint32)
    got, _ = pim_ntt(a, ctx, PimConfig(num_buffers=nb), forward=True)
    assert np.array_equal(got, ntt.ntt_forward_np(a, ctx))


@pytest.mark.parametrize("n", [256, 2048])
def test_functional_polymul(n):
    ctx = ntt.make_context(Q, n)
    a = RNG.integers(0, Q, n).astype(np.uint32)
    b = RNG.integers(0, Q, n).astype(np.uint32)
    got, timing = pim_polymul(a, b, ctx, PimConfig(num_buffers=4))
    assert np.array_equal(got, ntt.schoolbook_negacyclic(a, b, Q))
    assert timing.ns > 0


# ---------------------------------------------------------------------------
# timing invariants + the paper's headline claims
# ---------------------------------------------------------------------------


def test_more_buffers_never_slower():
    for n in [256, 1024, 8192]:
        t = [simulate_ntt(n, PimConfig(num_buffers=nb)).ns for nb in (1, 2, 4, 6, 8)]
        assert all(t[i] >= t[i + 1] - 1e-6 for i in range(len(t) - 1)), (n, t)


def test_one_aux_buffer_order_of_magnitude():
    """§VI-C: 'even just one auxiliary buffer can improve performance by an
    order of magnitude' (vs the single-buffer datapath)."""
    for n in [1024, 4096]:
        t1 = simulate_ntt(n, PimConfig(num_buffers=1)).ns
        t2 = simulate_ntt(n, PimConfig(num_buffers=2)).ns
        assert t1 / t2 > 5.0, (n, t1 / t2)


def test_multi_buffer_speedup_range():
    """§VI-C: more buffers give ~1.5-2.5x, larger N benefits more."""
    r = {}
    for n in [512, 4096, 16384]:
        t2 = simulate_ntt(n, PimConfig(num_buffers=2)).ns
        t6 = simulate_ntt(n, PimConfig(num_buffers=6)).ns
        r[n] = t2 / t6
        assert 1.3 < r[n] < 3.0, r
    assert r[16384] > r[512], r  # larger N benefits more


def test_act_count_decreases_with_buffers():
    for n in [2048, 8192]:
        acts = [simulate_ntt(n, PimConfig(num_buffers=nb)).stats["act"] for nb in (2, 4, 6)]
        assert acts[0] > acts[1] > acts[2], acts


def test_inter_row_act_bound():
    """Nb=2 inter-row regime: ~2 activations per atom-pair butterfly, and
    the idealized row-level bound 3N/(2R) per stage is respected by the
    per-row-pair activation count when buffers are scaled up."""
    cfg = PimConfig(num_buffers=2)
    n = 2048  # 8 rows -> 3 inter-row stages
    res = simulate_ntt(n, cfg)
    n_inter_stages = 3
    pairs_per_stage = n // (2 * cfg.atom_words)
    # 2 Acts per pair + small leading terms
    assert res.stats["act"] <= 2 * n_inter_stages * pairs_per_stage + 4 * (n // cfg.row_words) + 8


def test_pipelining_helps():
    for nb in (2, 4):
        cfg = PimConfig(num_buffers=nb)
        cmds = RowCentricMapper(cfg, 4096).commands()
        piped = BankTimer(cfg, pipelined=True).simulate(cmds).ns
        serial = BankTimer(cfg, pipelined=False).simulate(cmds).ns
        assert piped < serial


def test_frequency_sensitivity():
    """Fig 8: dropping CU clock 1200->300 MHz slows large-N NTT <= ~1.65x
    (DRAM latencies fixed in ns dominate)."""
    for n, bound in [(4096, 1.9), (16384, 1.9)]:
        fast = simulate_ntt(n, PimConfig(num_buffers=2, cu_clock_mhz=1200.0)).ns
        slow = simulate_ntt(n, PimConfig(num_buffers=2, cu_clock_mhz=300.0)).ns
        assert slow / fast < bound, (n, slow / fast)
        assert slow / fast > 1.05  # CU does contribute


def test_latency_grows_superlinearly():
    """Table III: latency roughly x2.4-2.7 per doubling of N (O(N log N) +
    growing inter-row fraction)."""
    prev = None
    for n in [512, 1024, 2048, 4096]:
        t = simulate_ntt(n, PimConfig(num_buffers=2)).ns
        if prev is not None:
            assert 2.0 < t / prev < 3.2, (n, t / prev)
        prev = t


def test_paper_table3_magnitude():
    """Our absolute latency should be within 2x of the paper's Table III
    (exact DRAMsim3 internals differ; the trend is the claim)."""
    paper_nb2 = {256: 3.90, 512: 14.16, 1024: 38.19, 2048: 95.84, 4096: 230.45}
    for n, p in paper_nb2.items():
        ours = simulate_ntt(n, PimConfig(num_buffers=2)).us
        assert 0.5 < ours / p < 2.0, (n, ours, p)


def test_row_conflict_assertions_hold():
    """The static schedule never reads/writes a closed row (mapper emits
    Act correctly) — would raise AssertionError otherwise."""
    for nb in (1, 2, 4, 7):
        cfg = PimConfig(num_buffers=nb)
        ctx = ntt.make_context(Q, 1024)
        a = RNG.integers(0, Q, 1024).astype(np.uint32)
        pim_ntt(a, ctx, cfg)  # FunctionalBank asserts open-row discipline
        BankTimer(cfg).simulate(RowCentricMapper(cfg, 1024).commands())


# ---------------------------------------------------------------------------
# area / energy models (Table II)
# ---------------------------------------------------------------------------


def test_area_model_fits_table2():
    a_cu, a_buf, resid = area.fit_area_model()
    assert resid < 0.001  # mm^2
    assert a_cu > 0 and a_buf > 0


def test_area_below_newton():
    """Headline: 'less than half of Newton's' overhead at Nb<=6."""
    assert area.area_overhead_pct(6) < area.newton_overhead_pct()
    assert area.area_overhead_pct(1) < 0.6


def test_energy_monotonic_in_n():
    e = [simulate_ntt(n, PimConfig(num_buffers=2)).energy_nj() for n in (256, 1024, 4096)]
    assert e[0] < e[1] < e[2]


def test_energy_decreases_with_buffers():
    """More buffers -> fewer activations -> less energy (Table III shows
    Nb=4 < Nb=2 energy)."""
    e2 = simulate_ntt(4096, PimConfig(num_buffers=2)).energy_nj()
    e4 = simulate_ntt(4096, PimConfig(num_buffers=4)).energy_nj()
    assert e4 < e2


# ---------------------------------------------------------------------------
# beyond-paper: multi-bank scaling under shared-bus contention (§VII)
# ---------------------------------------------------------------------------


def test_multibank_scaling():
    from repro.core.pimsim import simulate_multibank

    r1 = simulate_multibank(4096, 1, PimConfig(num_buffers=2))
    assert r1.speedup == pytest.approx(1.0)
    r2 = simulate_multibank(4096, 2, PimConfig(num_buffers=2))
    assert 1.5 < r2.speedup <= 2.0
    # saturation: past the bus knee, speedup stops growing linearly
    r32 = simulate_multibank(4096, 32, PimConfig(num_buffers=2))
    assert r32.efficiency < 1.0
    assert r32.speedup >= r2.speedup  # never negative returns
    # latency never below single-bank
    assert r32.latency_ns >= r1.latency_ns
