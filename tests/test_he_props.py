"""Property twin for `repro.he`: towers x N x banks sweeps.

Every drawn configuration must (a) stay bit-exact against the
big-integer CRT oracles and (b) obey the timing invariants of the
tower->bank gang model (speedup bounded by banks, single-bank baseline
burst-free, phase durations summing below the makespan's span).
"""
import numpy as np

import repro.he as he
from hypo import given, settings, st
from repro.core.pim_config import PimConfig
from repro.pimsys import PimSession

CFG = PimConfig(num_channels=2, num_banks=2, param_cache_entries=4)
SESS = PimSession(CFG)  # shared across examples: plan-cache reuse

ns = st.sampled_from([16, 32, 64])
towers = st.integers(min_value=1, max_value=5)
banks = st.integers(min_value=1, max_value=4)
seeds = st.integers(min_value=0, max_value=2 ** 16)


@settings(max_examples=25)
@given(n=ns, big_l=towers, seed=seeds)
def test_crt_roundtrip_and_ct_mul_exact(n, big_l, seed):
    basis = he.make_basis(n, big_l)
    rng = np.random.default_rng(seed)
    coeffs = [int(x) for x in rng.integers(0, basis.modulus, n)]
    assert basis.decode(basis.encode(coeffs)) == coeffs
    a, b = he.random_ct(basis, seed), he.random_ct(basis, seed + 1)
    assert np.array_equal(he.ct_mul(basis, a, b),
                          he.ct_mul_reference(basis, a, b))


@settings(max_examples=15)
@given(n=ns, big_l=towers, seed=seeds)
def test_keyswitch_and_rescale_exact(n, big_l, seed):
    basis = he.make_basis(n, big_l)
    s = he.make_secret(basis, seed)
    rlk = he.relin_key(basis, s, seed=seed + 1)
    c2 = he.random_poly(basis, seed + 2)
    assert np.array_equal(he.keyswitch(basis, c2, rlk),
                          he.keyswitch_reference(basis, c2, rlk))
    if big_l >= 2:
        ct = he.random_ct(basis, seed + 3)
        assert np.array_equal(he.rescale(basis, ct),
                              he.rescale_reference(basis, ct))


@settings(max_examples=15)
@given(n=ns, big_l=towers, b=banks, seed=seeds)
def test_device_plan_invariants(n, big_l, b, seed):
    b = min(b, CFG.num_channels * CFG.num_banks)
    op = he.RlweCtMulOp(n=n, towers=big_l, banks=b)
    plan = SESS.compile(op)
    assert SESS.compile(op) is plan  # memoized under the sweep
    basis = he.basis_for(op)
    a, c = he.random_ct(basis, seed), he.random_ct(basis, seed + 1)
    r = SESS.run(plan, a, c)
    assert np.array_equal(r.value, he.ct_mul_reference(basis, a, c))
    t = r.timing
    assert t.banks == b
    assert t.latency_ns > 0
    assert t.latency_ns <= t.single_ns + 1e-9
    # Mildly superlinear speedup is legitimate: the one-bank baseline
    # walks every tower's programs through one param LRU (capacity
    # thrash) while dedicated banks keep theirs resident.
    assert 0 < t.speedup <= 1.5 * b
    assert 0 < t.efficiency <= 1.5
    assert t.xfer_atoms == 0  # ct_mul never moves data between banks
    assert len(t.tower_done_ns) == big_l
    assert max(t.tower_done_ns) <= t.latency_ns + 1e-9
    assert set(t.phase_ns) == {"fwd", "pointwise", "inv"}
    assert all(v >= 0 for v in t.phase_ns.values())


@settings(max_examples=10)
@given(n=ns, big_l=st.integers(min_value=2, max_value=5), b=banks,
       seed=seeds)
def test_keyswitch_device_invariants(n, big_l, b, seed):
    b = min(b, CFG.num_channels * CFG.num_banks)
    op = he.KeySwitchOp(n=n, towers=big_l, banks=b)
    plan = SESS.compile(op)
    basis = he.basis_for(op)
    rlk = he.relin_key(basis, he.make_secret(basis, seed), seed=seed + 1)
    c2 = he.random_poly(basis, seed + 2)
    r = SESS.run(plan, c2, rlk)
    assert np.array_equal(r.value, he.keyswitch_reference(basis, c2, rlk))
    t = r.timing
    if b == 1 or big_l == 1:
        assert t.xfer_atoms == 0
    else:
        # each tower broadcasts one poly to every *other* reserved bank
        atoms_per_poly = max(1, n // CFG.atom_words)
        reserved = min(b, big_l)
        assert t.xfer_atoms == big_l * (reserved - 1) * atoms_per_poly
    assert t.phase_ns["base_extend"] >= 0
