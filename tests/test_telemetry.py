"""Telemetry layer (`repro.pimsys.telemetry`): zero overhead when off,
trace <-> stats reconciliation, Chrome trace export validity, request
latency attribution, and the windowed-series / reservoir primitives."""
import io
import json

import pytest

from repro.core.pim_config import PimConfig
from repro.pimsys import (
    NttOp,
    PimSession,
    Reservoir,
    ServicePolicy,
    ShardedNttOp,
    WindowedSeries,
    validate_chrome_trace,
)
from repro.pimsys.telemetry import STAT_KEY

# the acceptance workload: one N=4096 NTT four-step-sharded over 16
# banks on a 4-channel x 4-bank device
SHARDED_CFG = dict(num_buffers=4, num_channels=4, num_banks=4,
                   param_cache_entries=8)


def sharded_run(telemetry: bool):
    sess = PimSession(PimConfig(telemetry=telemetry, **SHARDED_CFG))
    return sess.run(sess.compile(ShardedNttOp(4096, banks=16)))


# ---------------------------------------------------------------------------
# on/off invariants
# ---------------------------------------------------------------------------


def test_telemetry_off_by_default_and_timing_identical():
    off = sharded_run(telemetry=False)
    on = sharded_run(telemetry=True)
    assert off.telemetry is None
    assert on.telemetry is not None
    # recording is passive: the timed run is bit-identical either way
    assert on.timing.latency_ns == off.timing.latency_ns
    assert on.timing.exchange_ns == off.timing.exchange_ns
    assert on.stats.device_counts() == off.stats.device_counts()


def test_single_bank_telemetry_phases_and_commands():
    sess = PimSession(PimConfig(num_buffers=2, telemetry=True))
    r = sess.run(sess.compile(NttOp(1024)))
    tr = r.telemetry.tracer
    assert len(tr.commands) > 0
    assert tr.phases, "Mark segments must appear as phase spans"
    # every command span is well-formed: gate <= grant <= start <= done
    for _ch, _b, _n, gate, grant, s, done, _pn, _c in tr.commands:
        assert gate <= grant <= s <= done


# ---------------------------------------------------------------------------
# reconciliation: trace totals == StatsRegistry counters (acceptance gate)
# ---------------------------------------------------------------------------


def test_sharded_trace_reconciles_with_stats():
    r = sharded_run(telemetry=True)
    totals = r.telemetry.command_totals()
    reg = r.stats
    assert totals, "16-bank run must record per-bank command events"
    for (ch, bank), t in totals.items():
        counts = reg.bank_counts(ch, bank)
        for key in STAT_KEY.values():
            assert t.get(key, 0) == counts.get(key, 0), (
                f"trace/stats mismatch at ch{ch} bank{bank} key {key}")
    # and the union covers every bank the registry saw commands on
    traced = set(totals)
    stats_banks = {
        (ch, b) for (ch, b), c in reg._bank.items()
        if any(c.get(k, 0) for k in STAT_KEY.values())}
    assert stats_banks == traced


def test_sharded_trace_exports_valid_chrome_doc():
    r = sharded_run(telemetry=True)
    doc = r.telemetry.chrome_trace()
    assert validate_chrome_trace(doc) == []
    assert doc["otherData"]["schema"] == "ntt-pim-telemetry-v1"
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in phases and "M" in phases
    # exchange stages and local passes made it onto the phase track
    names = {e["name"] for e in doc["traceEvents"] if e.get("cat") == "phase"}
    assert any(n.startswith("stride=") for n in names)
    assert "local" in names
    # round-trips through JSON text
    assert validate_chrome_trace(json.loads(r.telemetry.dumps())) == []


def test_dump_jsonl_dialect():
    r = sharded_run(telemetry=True)
    buf = io.StringIO()
    r.telemetry.dump_jsonl(buf)
    lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
    kinds = {ln["k"] for ln in lines}
    assert {"meta", "cmd", "burst", "phase"} <= kinds
    n_cmds = sum(1 for ln in lines if ln["k"] == "cmd")
    assert n_cmds == len(r.telemetry.tracer.commands)


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) == ["top level must be a JSON object"]
    assert validate_chrome_trace({}) == ["traceEvents must be a list"]
    bad = {"traceEvents": [
        {"name": "x", "ph": "Q", "pid": 0},             # bad phase
        {"name": "x", "ph": "X", "pid": 0, "ts": -1.0},  # bad ts, no dur
        {"name": "x", "ph": "b", "pid": 0, "ts": 0.0},   # async without id
    ]}
    errs = validate_chrome_trace(bad)
    assert len(errs) == 4
    assert any("ph must be" in e for e in errs)
    assert any("id" in e for e in errs)


# ---------------------------------------------------------------------------
# service path: request lifecycle spans + attribution
# ---------------------------------------------------------------------------


def serve(policy):
    sess = PimSession(PimConfig(num_buffers=2, num_channels=1, num_banks=4))
    svc = sess.service(policy)
    plan = sess.compile(NttOp(256))
    svc.submit_mixed_poisson(plan, 24, 0.2, latency_frac=0.25,
                             deadline_us=500.0)
    return svc.result()


def test_request_spans_fully_attribute_latency():
    res = serve(ServicePolicy(weight_latency=8.0, batch_window_us=10.0,
                              max_batch=4, telemetry=True))
    tel = res.telemetry
    assert tel is not None
    rows = tel.request_breakdown()
    assert len(rows) == res.completed
    for row in rows:
        # wait + execute tile the request end to end: 100% attribution,
        # comfortably over the >= 95% acceptance bar
        assert row["attributed"] == pytest.approx(1.0)
        assert row["qos"] in ("latency", "throughput")
        assert "execute" in row["spans"]
        assert ("queue_wait" in row["spans"]) or ("coalesce_wait" in row["spans"])


def test_service_telemetry_off_by_default():
    res = serve(ServicePolicy(weight_latency=8.0))
    assert res.telemetry is None


def test_service_timeseries_reach_stats_summary():
    res = serve(ServicePolicy(weight_latency=8.0, telemetry=True,
                              telemetry_window_us=20.0))
    s = res.stats.summary()
    assert "timeseries" in s
    assert any(k.startswith("queue_depth/") for k in s["timeseries"])
    assert any(k.startswith("bus_occupancy/") for k in s["timeseries"])
    for points in s["timeseries"].values():
        assert all(len(p) == 2 for p in points)


def test_rejected_requests_appear_as_instants():
    sess = PimSession(PimConfig(num_buffers=2, num_channels=1, num_banks=2))
    svc = sess.service(ServicePolicy(telemetry=True, max_queue_depth=2))
    plan = sess.compile(NttOp(256))
    svc.submit_poisson(plan, 32, 10.0)  # absurd rate: floods the queue
    res = svc.result()
    assert res.rejected > 0
    names = {name for _r, _q, name, _t in res.telemetry.tracer.request_events}
    assert any(n.startswith("rejected:") for n in names)
    doc = res.telemetry.chrome_trace()
    assert validate_chrome_trace(doc) == []
    assert any(e["ph"] == "i" for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# windowed series / reservoir primitives
# ---------------------------------------------------------------------------


def test_windowed_series_aggregations():
    mean = WindowedSeries(100.0, "mean")
    for t, v in ((10, 1.0), (20, 3.0), (150, 5.0)):
        mean.record(t, v)
    assert mean.points() == [(0.0, 2.0), (100.0, 5.0)]

    peak = WindowedSeries(100.0, "max")
    for t, v in ((10, 1.0), (20, 3.0), (110, 2.0)):
        peak.record(t, v)
    assert peak.points() == [(0.0, 3.0), (100.0, 2.0)]

    occ = WindowedSeries(100.0, "occupancy")
    occ.record_span(50.0, 250.0)  # spans three windows: 50 + 100 + 50
    assert occ.points() == [(0.0, 0.5), (100.0, 1.0), (200.0, 0.5)]
    assert occ.points_us() == [[0.0, 0.5], [0.1, 1.0], [0.2, 0.5]]

    with pytest.raises(ValueError):
        WindowedSeries(0.0)
    with pytest.raises(ValueError):
        WindowedSeries(100.0, "median")


def test_reservoir_deterministic_and_percentiles():
    a, b = Reservoir(k=64), Reservoir(k=64)
    for i in range(1000):
        a.add(float(i))
        b.add(float(i))
    assert a.values == b.values  # private deterministic stream
    assert a.n == 1000 and len(a) == 64
    full = Reservoir(k=101)
    for i in range(101):
        full.add(float(i))
    assert full.percentile(0) == 0.0
    assert full.percentile(50) == 50.0
    assert full.percentile(100) == 100.0
    assert Reservoir().percentile(99) == 0.0
