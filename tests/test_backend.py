"""Deterministic differential over the unified `NttBackend` registry.

The three lanes — reference (numpy), pim-sim (FunctionalBank +
BankTimer), pallas (jax interpret mode) — implement ONE transform
contract; these tests pin them bit-exactly against each other on fixed
grids.  Unlike `tests/test_kernels.py` (which needs hypothesis and jax
at import), this module runs everywhere: the pallas lane simply drops
out of `available_backends()` on jax-less hosts, and the smoke script
leans on that to keep the differential in the always-on tier.
"""
import numpy as np
import pytest

from repro.core import modmath as mm
from repro.kernels.backend import (
    BACKEND_NAMES,
    available_backends,
    get_backend,
)

Q = mm.DEFAULT_Q


def rand(shape, seed=42):
    return np.random.default_rng(seed).integers(0, Q, shape).astype(np.uint32)


def test_backend_registry_names_and_errors():
    assert set(BACKEND_NAMES) == {"reference", "pim-sim", "pallas"}
    with pytest.raises(ValueError, match="unknown NTT backend"):
        get_backend("fastmath")


@pytest.mark.parametrize("forward", [True, False])
@pytest.mark.parametrize("n", [256, 1024])
def test_backend_differential_bit_exact(n, forward):
    """Every available backend must agree BIT-exactly with the reference
    on the same inputs, both directions — one transform contract, not
    three similar ones."""
    ref_b = get_backend("reference")
    x = rand((2, n), seed=n + forward)
    exp = ref_b.ntt(x, forward=forward)
    ran = []
    for b in available_backends():
        got = b.ntt(x, forward=forward)
        assert got.dtype == np.uint32
        assert np.array_equal(got, exp), (b.name, n, forward)
        ran.append(b.name)
    assert "reference" in ran and "pim-sim" in ran  # always runnable


def test_backend_roundtrip_and_1d():
    x = rand(512)
    for b in available_backends():
        back = b.ntt(b.ntt(x, forward=True), forward=False)
        assert back.shape == (512,)
        assert np.array_equal(back, x), b.name


def test_backend_input_validation():
    b = get_backend("reference")
    with pytest.raises(ValueError, match="power of two"):
        b.ntt(np.zeros(100, np.uint32))
    with pytest.raises(ValueError, match="expected"):
        b.ntt(np.zeros((2, 2, 2), np.uint32))


def test_backend_modeled_latency():
    """Only the PIM lane has an architecture model; its number must be
    the session's own `NttOp` latency, cached across calls."""
    from repro.pimsys import NttOp, PimSession

    b = get_backend("pim-sim")
    ns = b.modeled_latency_ns(1024)
    sess = PimSession(b.cfg)
    assert ns == sess.run(sess.compile(NttOp(1024, forward=True))).timing.ns
    assert b.modeled_latency_ns(1024) == ns  # cache hit, same answer
    assert get_backend("reference").modeled_latency_ns(1024) is None
