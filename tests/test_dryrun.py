"""Dry-run machinery tests: HLO collective parsing and a miniature
lower+compile on a virtual 8-device mesh (subprocess, scaled-down configs;
the full 512-chip sweep runs via `python -m repro.launch.dryrun --all`)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import parse_collectives

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_parse_collectives_semantics():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar.1 = bf16[1024]{0} all-reduce(%y), replica_groups=[16,16]<=[16,16]T(1,0)
  %rs = f32[8,16]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u32[256]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = f32[32]{0} all-to-all(%v), replica_groups=[4,2]<=[8]
"""
    out = parse_collectives(hlo)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                             "collective-permute": 1, "all-to-all": 1}
    assert out["all-gather"] == 64 * 128 * 4 // 16  # operand = result / group
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 8 * 16 * 4 * 4  # operand = result * group
    assert out["collective-permute"] == 256 * 4
    assert out["all-to-all"] == 32 * 4
    assert out["total_bytes"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "collective-permute", "all-to-all")
    )


def test_parse_collectives_ignores_done():
    hlo = "  %ag-done = f32[64]{0} all-gather-done(%ag-start)\n"
    out = parse_collectives(hlo)
    assert out["counts"] == {}


@pytest.mark.slow
def test_mini_dryrun_all_kinds():
    """Lower+compile train/prefill/decode for a reduced config on a virtual
    2x4 mesh through the REAL build_lowerable path; assert flops/collectives
    are present and memory analysis is reported."""
    code = """
    import jax
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_config
    from repro.launch.dryrun import build_lowerable, cost_dict, parse_collectives
    from repro.launch.mesh import make_host_mesh

    cfg = get_config('qwen3-moe-30b-a3b').reduced()
    mesh = make_host_mesh(data=2, model=4)
    shapes = [ShapeConfig('t', 64, 8, 'train'), ShapeConfig('p', 64, 8, 'prefill'),
              ShapeConfig('d', 64, 8, 'decode')]
    for shp in shapes:
        jitted, args = build_lowerable(cfg, shp, mesh)
        with mesh:
            compiled = jitted.lower(*args).compile()
        cost = cost_dict(compiled.cost_analysis())
        coll = parse_collectives(compiled.as_text())
        mem = compiled.memory_analysis()
        assert cost.get('flops', 0) > 0, shp
        assert coll['total_bytes'] > 0, shp
        assert getattr(mem, 'peak_memory_in_bytes', 1) >= 0
        print('OK', shp.kind, f"{cost['flops']:.2e}", coll['total_bytes'])
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("OK") == 3


def test_depth_variant_math():
    from repro.launch.dryrun import _depth_variant
    from repro.configs.registry import get_config

    cfg = get_config("jamba-1.5-large-398b")
    v1 = _depth_variant(cfg, 1)
    v2 = _depth_variant(cfg, 2)
    assert v1.num_layers == len(cfg.pattern())
    assert v2.num_layers == 2 * len(cfg.pattern())
    assert not v1.scan_layers
    assert v1.pattern() == cfg.pattern()
