"""Sharded NTT (`repro.pimsys.sharded`) differential harness.

Four layers of evidence that the four-step split is right:
  1. exact functional equality: `pim_ntt_sharded` == `core.ntt`
     reference over an (n x banks x direction) grid, plus
     INTT(NTT(x)) == x round-trips entirely through the sharded path
     (the hypothesis property twin lives in `test_sharded_props.py`,
     which self-skips when hypothesis is absent);
  2. differential timing: banks=1 emits the *identical command list* as
     the unsharded `RowCentricMapper` (not just equal totals) and times
     bit-identically to `BankTimer`; runtime is monotonically
     non-increasing in banks for fixed N;
  3. golden traces: two small sharded configs are byte-stable against
     `tests/golden/` and replay to the live phase timing;
  4. the gang scheduler conserves jobs when sharded and FIFO jobs mix.
"""
import os

import numpy as np
import pytest

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.mapping import RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.pimsim import BankTimer, simulate_ntt, simulate_ntt_sharded
from repro.core.polymul import pim_ntt_sharded
from repro.pimsys import (
    DeviceTopology,
    NttJob,
    PolymulJob,
    RequestScheduler,
    ShardedNttJob,
    ShardedNttPlan,
    dumps_trace,
    loads_trace,
    replay_trace,
)

Q = mm.DEFAULT_Q
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def rand_poly(n, seed):
    return np.random.default_rng(seed).integers(0, Q, n).astype(np.uint32)


# ---------------------------------------------------------------------------
# 1. functional equality with the reference NTT (deterministic grid; the
#    hypothesis property twin is in test_sharded_props.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 256, 1024])
@pytest.mark.parametrize("banks", [2, 4, 8])
def test_sharded_inverse_matches_reference(small_pim_cfg, n, banks):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, n * 31 + banks)
    got, plan = pim_ntt_sharded(a, ctx, small_pim_cfg, banks=banks)
    assert plan.banks == banks
    assert np.array_equal(got, ntt.ntt_inverse_np(a, ctx))


@pytest.mark.parametrize("n", [64, 256, 1024])
@pytest.mark.parametrize("banks", [2, 4, 8])
def test_sharded_forward_matches_reference(small_pim_cfg, n, banks):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, n * 37 + banks)
    got, _ = pim_ntt_sharded(a, ctx, small_pim_cfg, banks=banks, forward=True)
    assert np.array_equal(got, ntt.ntt_forward_np(a, ctx))


@pytest.mark.parametrize("n,banks", [(64, 2), (256, 4), (512, 8)])
def test_sharded_roundtrip(small_pim_cfg, n, banks):
    """INTT(NTT(x)) == x with BOTH transforms on the sharded path."""
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, n + banks)
    fwd, _ = pim_ntt_sharded(a, ctx, small_pim_cfg, banks=banks, forward=True)
    back, _ = pim_ntt_sharded(fwd, ctx, small_pim_cfg, banks=banks, forward=False)
    assert np.array_equal(back, a)


@pytest.mark.parametrize("nb", [2, 4, 6])
def test_sharded_matches_unsharded_pim_ntt(small_pim_cfg, nb):
    """The sharded functional path agrees with the single-bank
    `pim_ntt` executor for every buffer count (same command semantics)."""
    from repro.core.mapping import pim_ntt

    n, banks = 512, 4
    cfg = small_pim_cfg.with_(num_buffers=nb)
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, nb)
    got, _ = pim_ntt_sharded(a, ctx, cfg, banks=banks)
    ref, _ = pim_ntt(a, ctx, cfg)
    assert np.array_equal(got, ref)


def test_sharded_polymul_identity(small_pim_cfg):
    """NTT-domain product through the sharded transforms == schoolbook."""
    n = 256
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n, 7), rand_poly(n, 8)
    ah, _ = pim_ntt_sharded(a, ctx, small_pim_cfg, banks=4, forward=True)
    bh, _ = pim_ntt_sharded(b, ctx, small_pim_cfg, banks=4, forward=True)
    prod = np.asarray(mm.np_mulmod(ah, bh, Q), np.uint32)
    got, _ = pim_ntt_sharded(prod, ctx, small_pim_cfg, banks=4)
    assert np.array_equal(got, ntt.schoolbook_negacyclic(a, b, Q))


# ---------------------------------------------------------------------------
# 2. differential timing vs the single-bank simulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("forward", [False, True])
@pytest.mark.parametrize("n", [256, 1024])
def test_banks1_command_stream_identical(small_pim_cfg, n, forward):
    """banks=1 is the unsharded mapper: command-LIST equality, and no
    exchange stages at all — the sharding machinery vanishes exactly."""
    plan = ShardedNttPlan(small_pim_cfg, n, 1, forward=forward)
    streams = plan.local_streams()
    assert len(streams) == 1
    assert streams[0] == RowCentricMapper(small_pim_cfg, n, forward=forward).commands()
    assert plan.exchange_stages() == []


def test_banks1_timing_bit_identical(small_pim_cfg):
    n = 1024
    cmds = RowCentricMapper(small_pim_cfg, n).commands()
    ref = BankTimer(small_pim_cfg).simulate(cmds)
    r = ShardedNttPlan(small_pim_cfg, n, 1).simulate()
    assert r.latency_ns == ref.ns  # exact ns, not approx
    assert r.exchange_ns == 0.0
    assert r.local_ns == ref.ns
    assert r.speedup == pytest.approx(1.0)


def test_runtime_monotone_nonincreasing_in_banks():
    """More banks never hurt a fixed-N sharded NTT on this topology."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=4)
    n = 4096
    single = simulate_ntt(n, cfg)
    prev = None
    for banks in (1, 2, 4, 8):
        r = simulate_ntt_sharded(n, banks, cfg, single=single)
        if prev is not None:
            assert r.latency_ns <= prev + 1e-6, (banks, r.latency_ns, prev)
        # sanity: never below the per-channel bus bound on the local pass
        assert r.latency_ns >= r.analytic_local_ns - 1e-6
        prev = r.latency_ns


def test_speedup_at_8_banks_exceeds_1_5x():
    """The acceptance bar: sharding N=4096 over 8 banks beats one bank
    by >1.5x (it lands well above; the bar is the regression floor)."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=4)
    r = simulate_ntt_sharded(4096, 8, cfg)
    assert r.speedup > 1.5, r.speedup
    assert r.exchange_ns > 0.0
    assert 0.0 < r.exchange_bus_occupancy <= 1.0


def test_unpipelined_sharded_never_faster(small_pim_cfg):
    """pipelined=False (Fig 6a serial engines) reaches the local passes
    and the exchange alike; it must cost time, never save it."""
    plan = ShardedNttPlan(small_pim_cfg, 1024, 4)
    fast = plan.simulate(baseline=False)
    slow = plan.simulate(baseline=False, pipelined=False)
    assert slow.latency_ns > fast.latency_ns


def test_exchange_transfer_accounting(small_pim_cfg):
    """xfer_atoms is exactly 2 bursts/atom-pair: log2(B) stages x B/2
    pairs x M/Na atoms x 2 directions; hops appear iff channels differ."""
    n, banks = 512, 4
    plan = ShardedNttPlan(small_pim_cfg, n, banks)
    r = plan.simulate(baseline=False)
    m = n // banks
    stages, pairs = 2, banks // 2
    expect = stages * pairs * (m // small_pim_cfg.atom_words) * 2
    assert r.xfer_atoms == expect
    assert 0 < r.xfer_hops <= r.xfer_atoms  # 2-channel topo: some cross
    dc = r.stats.device_counts()
    assert dc["xfer_atoms"] == expect
    assert dc["c2"] > 0 and dc["act"] > 0


def test_sharded_validation_errors(small_pim_cfg):
    with pytest.raises(ValueError):  # banks not a power of two
        ShardedNttPlan(small_pim_cfg, 256, 3)
    with pytest.raises(ValueError):  # shard below one atom
        ShardedNttPlan(small_pim_cfg, 64, 16)
    with pytest.raises(ValueError):  # exchange needs >= 2 atom buffers
        ShardedNttPlan(small_pim_cfg.with_(num_buffers=1), 256, 2)
    with pytest.raises(ValueError):  # more shards than the explicit device
        ShardedNttPlan(small_pim_cfg, 4096, 8,
                       topo=DeviceTopology.from_config(small_pim_cfg))
    with pytest.raises(ValueError):  # placement must be distinct banks
        ShardedNttPlan(small_pim_cfg, 256, 2, flat_banks=[0, 0])
    with pytest.raises(ValueError):  # shard exceeds bank row capacity
        ShardedNttPlan(small_pim_cfg.with_(rows_per_bank=4), 4096, 2)


def test_scheduler_gang_rejects_oversized_shard():
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2, rows_per_bank=4)
    with pytest.raises(ValueError):
        RequestScheduler(cfg).run_closed_loop([ShardedNttJob(4096, banks=2)])


# ---------------------------------------------------------------------------
# 3. golden-trace regression
# ---------------------------------------------------------------------------

GOLDEN_CONFIGS = {
    "sharded_n256_b4.trace": (PimConfig(num_buffers=2, num_channels=2, num_banks=2), 256, 4),
    "sharded_n512_b2.trace": (PimConfig(num_buffers=4, num_channels=1, num_banks=2), 512, 2),
}


@pytest.mark.parametrize("fname", sorted(GOLDEN_CONFIGS))
def test_golden_trace_byte_stable(fname):
    """The recorded command-level workload must never drift silently."""
    cfg, n, banks = GOLDEN_CONFIGS[fname]
    plan = ShardedNttPlan(cfg, n, banks)
    text = dumps_trace(plan.trace_streams())
    with open(os.path.join(GOLDEN_DIR, fname)) as f:
        assert f.read() == text


@pytest.mark.parametrize("fname", sorted(GOLDEN_CONFIGS))
def test_golden_trace_replay_matches_live(fname):
    """Replaying the recorded trace reproduces the live local-pass
    timing exactly (same Device arbitration path both ways)."""
    cfg, n, banks = GOLDEN_CONFIGS[fname]
    plan = ShardedNttPlan(cfg, n, banks)
    with open(os.path.join(GOLDEN_DIR, fname)) as f:
        dev = replay_trace(cfg, loads_trace(f.read()))
    live = plan.simulate(baseline=False)
    assert dev.makespan_ns == live.local_ns


# ---------------------------------------------------------------------------
# 4. gang scheduling: sharded jobs coexist with FIFO single-bank jobs
# ---------------------------------------------------------------------------


def test_scheduler_mixed_gang_and_fifo(small_pim_cfg):
    jobs = [
        NttJob(512),
        ShardedNttJob(1024, banks=4),
        PolymulJob(256),
        ShardedNttJob(512, banks=2),
        NttJob(256),
    ]
    res = RequestScheduler(small_pim_cfg).run_closed_loop(jobs)
    assert res.submitted == res.completed == len(jobs)
    assert np.all(res.done_ns > res.dispatch_ns)
    assert np.all(res.dispatch_ns >= res.arrivals_ns)
    assert res.stats.device_counts().get("xfer_atoms", 0) > 0


def test_scheduler_gang_open_loop_conservation(small_pim_cfg):
    jobs = [NttJob(256) if i % 3 else ShardedNttJob(512, banks=2)
            for i in range(15)]
    res = RequestScheduler(small_pim_cfg).run_open_loop(jobs, rate_per_us=0.1, seed=2)
    assert res.submitted == res.completed == 15
    p = res.latency_percentiles_us()
    assert p["p50"] <= p["p95"] <= p["p99"]


def test_scheduler_gang_waits_for_enough_banks():
    """A 4-bank gang on a 4-bank device must wait for ALL banks, so its
    dispatch trails the single-bank job occupying one of them."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    res = RequestScheduler(cfg).run_closed_loop(
        [NttJob(1024), ShardedNttJob(1024, banks=4)])
    # the gang's dispatch gate is the NttJob's completion
    assert res.dispatch_ns[1] == pytest.approx(res.done_ns[0])


def test_single_bank_job_not_gated_behind_gang_reservation():
    """A single-bank job must take the bank an in-flight NttJob frees
    soonest, not a gang-reserved bank parked in the pool with a far
    future release time."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    jobs = [ShardedNttJob(4096, banks=2), NttJob(1024), NttJob(1024), NttJob(256)]
    res = RequestScheduler(cfg).run_closed_loop(jobs)
    first_ntt_done = min(res.done_ns[1], res.done_ns[2])
    assert res.dispatch_ns[3] == pytest.approx(first_ntt_done)
    assert res.dispatch_ns[3] < res.done_ns[0]  # beats the gang release


def test_gang_stats_attributed_to_actual_banks():
    """Two same-channel-pattern gangs hit the plan cache but must charge
    their counters to the banks they actually ran on, not the first
    placement's."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    res = RequestScheduler(cfg).run_closed_loop([ShardedNttJob(1024, banks=2)] * 2)
    reg = res.stats
    # gang 1 on flats (0,1) = local bank 0 of each channel; gang 2 on
    # flats (2,3) = local bank 1: both halves must show work
    for ch in (0, 1):
        assert reg.bank_counts(ch, 0).get("c2", 0) > 0
        assert reg.bank_counts(ch, 0) == reg.bank_counts(ch, 1)


def test_gang_bus_utilization_not_saturated():
    """Merged gang stats use the whole run as the utilization window:
    sequential gangs on an otherwise idle device must NOT report a
    saturated bus."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    res = RequestScheduler(cfg).run_closed_loop([ShardedNttJob(1024, banks=4)] * 4)
    assert res.completed == 4
    for ch in res.stats.channels():
        assert res.stats.bus_utilization(ch) < 1.0


def test_job_commands_rejects_gang_jobs_descriptively(small_pim_cfg):
    from repro.pimsys import job_commands

    with pytest.raises(TypeError, match="local_streams"):
        job_commands(small_pim_cfg, ShardedNttJob(512, banks=2))


def test_scheduler_gang_too_large_rejected(small_pim_cfg):
    with pytest.raises(ValueError):
        RequestScheduler(small_pim_cfg).run_closed_loop(
            [ShardedNttJob(4096, banks=8)])


def test_scheduler_invalid_gang_fails_before_simulating(small_pim_cfg):
    """A malformed gang spec anywhere in the batch raises up front, not
    after earlier jobs have been simulated."""
    with pytest.raises(ValueError):
        RequestScheduler(small_pim_cfg).run_closed_loop(
            [NttJob(256), ShardedNttJob(512, banks=3)])


def test_job_rows_per_bank_for_gangs(small_pim_cfg):
    from repro.pimsys.scheduler import job_rows

    # 4096 words over 4 banks = 1024 words/bank = 4 rows of 256 words
    assert job_rows(small_pim_cfg, ShardedNttJob(4096, banks=4)) == 4
    assert job_rows(small_pim_cfg, NttJob(4096)) == 16


def test_sharded_explicit_placement_channels_matter():
    """Same 2 shards: cross-channel placement pays hop latency on every
    burst; same-channel placement pays bus serialization instead."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    topo = DeviceTopology.from_config(cfg)
    n = 512
    cross = ShardedNttPlan(cfg, n, 2, topo=topo, flat_banks=[0, 1]).simulate(baseline=False)
    same = ShardedNttPlan(cfg, n, 2, topo=topo, flat_banks=[0, 2]).simulate(baseline=False)
    assert cross.xfer_hops > 0
    assert same.xfer_hops == 0
    # both orders of magnitude sane and functionally the same plan
    assert cross.xfer_atoms == same.xfer_atoms


# ---------------------------------------------------------------------------
# pipelined exchange: stage breakdown, placement, param-charge threading
# ---------------------------------------------------------------------------


def test_stage_breakdown_sanity(small_pim_cfg):
    """`stage_breakdown` has one span per exchange stage, with sane
    occupancy/overlap and the four-step stride set {M, 2M, ...}."""
    n, banks = 1024, 4
    r = ShardedNttPlan(small_pim_cfg, n, banks).simulate(baseline=False)
    assert len(r.stage_breakdown) == 2  # log2(banks)
    m = n // banks
    assert {s.stride for s in r.stage_breakdown} == {m, 2 * m}
    for sp in r.stage_breakdown:
        assert sp.end_ns > sp.begin_ns >= 0.0
        assert sp.span_ns > 0.0
        assert sp.pairs == banks // 2
        assert 1 <= sp.channels <= small_pim_cfg.num_channels
        assert 0.0 < sp.occupancy <= 1.0
        assert 0.0 <= sp.overlap <= 1.0
    assert sum(sp.busy_ns for sp in r.stage_breakdown) > 0.0
    # the serial ablation reports the same stages over a wider window
    s = ShardedNttPlan(small_pim_cfg, n, banks).simulate(
        baseline=False, pipelined=False)
    assert {sp.stride for sp in s.stage_breakdown} == {m, 2 * m}


def test_conflict_placement_partners_cross_channel():
    """XOR-fold placement puts every stage's exchange partners on
    distinct channels: partner sub-indices differ in one bit, so a
    single-bit flip must change the mapped channel."""
    from repro.pimsys.sharded import conflict_aware_flat_banks

    cfg = PimConfig(num_buffers=2, num_channels=4, num_banks=4)
    topo = DeviceTopology.from_config(cfg)
    placed = conflict_aware_flat_banks(topo, tuple(range(16)))
    assert sorted(placed) == list(range(16))
    bit = 1
    while bit < 16:
        for b in range(16):
            ch_b = topo.address_of(placed[b]).channel
            ch_p = topo.address_of(placed[b ^ bit]).channel
            assert ch_b != ch_p, (b, b ^ bit, bit)
        bit <<= 1


def test_conflict_placement_fallbacks():
    """Degenerate shapes pass through; a channel-skewed pool (what a
    scheduler gang gets when only some banks are free) still yields a
    permutation of exactly the pool."""
    from repro.pimsys.sharded import conflict_aware_flat_banks

    one = DeviceTopology.from_config(
        PimConfig(num_buffers=2, num_channels=1, num_banks=8))
    assert conflict_aware_flat_banks(one, (0, 1, 2, 3)) == (0, 1, 2, 3)
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=4)
    topo = DeviceTopology.from_config(cfg)
    assert conflict_aware_flat_banks(topo, (0, 1, 2)) == (0, 1, 2)
    skew = tuple(f for f in range(8) if topo.address_of(f).channel == 0)
    placed = conflict_aware_flat_banks(topo, skew)
    assert sorted(placed) == sorted(skew)


def test_placement_identity_default_and_conflict_permutes(small_pim_cfg):
    ident = ShardedNttPlan(small_pim_cfg, 512, 4)
    assert ident.placement == "identity"
    assert tuple(ident.flat_banks) == tuple(range(4))
    conf = ShardedNttPlan(small_pim_cfg, 512, 4, placement="conflict")
    assert sorted(conf.flat_banks) == list(range(4))
    with pytest.raises(ValueError, match="placement"):
        ShardedNttPlan(small_pim_cfg, 512, 4, placement="banana")
    # placement moves commands between banks, never changes the math
    ctx = ntt.make_context(Q, 512)
    a = rand_poly(512, 7)
    assert np.array_equal(ident.run_functional(a, ctx),
                          conf.run_functional(a, ctx))


def test_sharded_op_placement_field(small_pim_cfg):
    from repro.pimsys import PimSession, ShardedNttOp

    sess = PimSession(small_pim_cfg)
    r = sess.run(sess.compile(ShardedNttOp(512, banks=4, placement="conflict")))
    assert r.timing.latency_ns > 0


def test_exchange_param_charges_pin_closed_form():
    """The LRU walk threaded across the local->exchange boundary must
    charge exactly the closed form the old code hardwired: exchange
    twiddle programs are keyed per (stage, pair) and disjoint from the
    local keys, so the first atom of a pair always misses (full load,
    code 1) and the rest re-select (hit beats, code 2)."""
    from repro.pimsys.engine import param_hit_beats

    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2,
                    param_cache_entries=8)
    plan = ShardedNttPlan(cfg, 1024, 4)  # inverse: locals seed the LRUs
    full = cfg.param_load_cycles * cfg.dram_ns
    hit = param_hit_beats(cfg) * cfg.dram_ns
    charges = plan.exchange_param_charges()
    assert len(charges) == 2 and all(len(st) == 2 for st in charges)
    for stage in charges:
        for first_ns, first_code, rest_ns, rest_code in stage:
            assert (first_code, rest_code) == (1, 2)
            assert first_ns == pytest.approx(full)
            assert rest_ns == pytest.approx(hit)
    off = ShardedNttPlan(cfg.with_(param_cache_entries=0), 1024, 4)
    for stage in off.exchange_param_charges():
        assert all(c == (None, 0, None, 0) for c in stage)


def test_sharded_fastpath_raises_naming_sharded(small_pim_cfg):
    """`PimSession.run(sharded_plan, backend="fastpath")` must fail with
    a message that names sharded plans and the working backend."""
    from repro.pimsys import PimSession, ShardedNttOp

    sess = PimSession(small_pim_cfg)
    plan = sess.compile(ShardedNttOp(512, banks=2))
    with pytest.raises(ValueError, match="sharded") as ei:
        sess.run(plan, backend="fastpath")
    assert "engine" in str(ei.value)


def test_run_service_fastpath_rejects_sharded_gangs(small_pim_cfg):
    from repro.pimsys import ServicePolicy, ServiceRequest

    reqs = [ServiceRequest(0.0, ShardedNttJob(512, banks=2))]
    with pytest.raises(ValueError, match="sharded"):
        RequestScheduler(small_pim_cfg).run_service(
            reqs, policy=ServicePolicy(backend="fastpath"))
