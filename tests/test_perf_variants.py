"""Equivalence tests for the §Perf optimization variants: the optimized
paths must be semantics-preserving vs the paper-faithful baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo import given, settings, st

from repro.configs.registry import get_config
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _fp32_layers():
    """Context: force fp32 compute to isolate routing from rounding."""
    class Ctx:
        def __enter__(self):
            self.old = L.COMPUTE_DTYPE
            L.COMPUTE_DTYPE = jnp.float32
            ssm.COMPUTE_DTYPE = jnp.float32
            T.COMPUTE_DTYPE = jnp.float32

        def __exit__(self, *a):
            L.COMPUTE_DTYPE = self.old
            ssm.COMPUTE_DTYPE = self.old
            T.COMPUTE_DTYPE = self.old

    return Ctx()


@pytest.mark.parametrize(
    "dispatch,cap",
    [("gather", 8.0), ("gather", 1.0), ("local", 8.0)],
)
def test_moe_dispatch_variants_match_scatter(dispatch, cap):
    """gather == scatter always (same global sort); local == scatter when
    capacity doesn't bind (its capacity is per-block — see moe_local doc)."""
    with _fp32_layers():
        cfg = get_config("qwen3-moe-30b-a3b").reduced(capacity_factor=cap)
        cfg_v = dataclasses.replace(cfg, moe_dispatch=dispatch)
        p = L.moe_init(KEY, cfg)
        p = jax.tree.map(lambda t: t.astype(jnp.float32), p)
        x = jax.random.normal(KEY, (4, 32, cfg.d_model), jnp.float32)
        o_base, aux_base = L.moe(p, cfg, x)
        o_var, aux_var = L.moe(p, cfg_v, x)
        np.testing.assert_allclose(np.asarray(o_base), np.asarray(o_var), atol=1e-5)
        np.testing.assert_allclose(float(aux_base), float(aux_var), rtol=1e-6)


def test_moe_local_tight_capacity_drop_semantics():
    """Under binding capacity, local dispatch drops per (block, expert) —
    outputs may differ from global-capacity scatter on a minority of
    tokens, but the drop RATE must be comparable (documented EP trade)."""
    with _fp32_layers():
        cfg = get_config("qwen3-moe-30b-a3b").reduced(capacity_factor=1.0)
        cfg_l = dataclasses.replace(cfg, moe_dispatch="local")
        p = L.moe_init(KEY, cfg)
        p = jax.tree.map(lambda t: t.astype(jnp.float32), p)
        x = jax.random.normal(KEY, (4, 32, cfg.d_model), jnp.float32)
        o_s, _ = L.moe(p, cfg, x)
        o_l, _ = L.moe(p, cfg_l, x)
        same = np.isclose(np.asarray(o_s), np.asarray(o_l), atol=1e-5).all(axis=-1)
        assert same.mean() > 0.7, same.mean()  # most tokens routed identically


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8)
def test_moe_local_property_random_inputs(seed):
    """Property: local dispatch == scatter for random inputs/weights."""
    with _fp32_layers():
        rng = np.random.default_rng(seed)
        cfg = get_config("qwen3-moe-30b-a3b").reduced(capacity_factor=2.0)
        cfg_l = dataclasses.replace(cfg, moe_dispatch="local")
        p = L.moe_init(jax.random.PRNGKey(seed % 2**31), cfg)
        p = jax.tree.map(lambda t: t.astype(jnp.float32), p)
        x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
        o1, _ = L.moe(p, cfg, x)
        o2, _ = L.moe(p, cfg_l, x)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("arch", ["mamba2-780m", "jamba-1.5-large-398b"])
def test_grouped_ssd_matches_baseline(arch):
    with _fp32_layers():
        cfg = get_config(arch).reduced()
        cfg_g = dataclasses.replace(cfg, ssm_impl="grouped")
        params = T.init_params(cfg, KEY)
        params = jax.tree.map(lambda t: t.astype(jnp.float32) if t.dtype == jnp.float32 else t, params)
        batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
        lb, _ = T.forward(params, cfg, batch)
        lg, _ = T.forward(params, cfg_g, batch)
        np.testing.assert_allclose(
            np.asarray(lb, np.float32), np.asarray(lg, np.float32), atol=1e-3, rtol=1e-3
        )


def test_grouped_ssd_decode_state_compatible():
    """Prefill with grouped impl -> decode continues correctly."""
    cfg = get_config("mamba2-780m").reduced(capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, ssm_impl="grouped")
    params = T.init_params(cfg, KEY)
    b, s = 2, 16
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": tokens})
    _, caches = T.prefill(params, cfg, {"tokens": tokens[:, : s - 1]}, cache_len=s)
    lg, _ = T.decode_step(params, cfg, tokens[:, s - 1], caches, jnp.int32(s - 1))
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full[:, s - 1], np.float32),
        rtol=0.2, atol=0.2,
    )


def test_param_dtype_bf16():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    params = T.init_params(cfg, KEY)
    # kimi config pins bfloat16 weights (1T on one pod)
    assert params["embed"].dtype == jnp.bfloat16
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    logits, _ = T.forward(params, cfg, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_train_step_works_with_all_perf_flags():
    """Optimized production settings still train (loss finite, params move)."""
    from repro.launch import steps as S
    from repro.optim import OptConfig

    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(cfg, moe_dispatch="local", remat=False)
    opt_cfg = OptConfig(total_steps=5, warmup_steps=1)
    params = T.init_params(cfg, KEY)
    opt = S.make_opt_init(cfg, opt_cfg)(params)
    step = S.make_train_step(cfg, opt_cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)}
    new_p, _, m = step(params, opt, batch, jnp.int32(1))
    assert np.isfinite(float(m["loss"]))
    moved = max(
        jax.tree.leaves(
            jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
                params, new_p,
            )
        )
    )
    assert moved > 0
