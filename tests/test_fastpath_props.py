"""Differential properties of the compiled vectorized timing backend.

The fastpath's whole contract is bit-identity with the interpreted
engine — not "close", EQUAL, float for float and counter for counter —
so every property here is a differential one:

  (a) on random homogeneous multibank workloads (size x banks x
      parameter-cache x buffer count x pipelining), `evaluate_gang`
      reproduces the interpreted `ChannelEngine`'s per-command start and
      done times, makespan, per-bank end times, bus occupancy, and stats
      dicts exactly;
  (b) the golden acceptance workload (16 banks, N=4096) agrees the same
      way, through the session API (`backend="fastpath"`) included;
  (c) a serving coalesced-gang profile (cold + warm concatenated
      streams) reproduces the per-member completion times the engine
      reports for the same gang on one bank;
  (d) `ServicePolicy(backend="fastpath", verify_every=1)` runs every
      dispatch through the differential oracle and conserves work
      (identical total command counters, `refresh` aside — the
      dedicated-bank profile timeline starts at t=0 by design);
  (e) the optional jax chain backend (`lax.scan` left fold) is
      bit-identical to the numpy one when jax is importable.

Unlike the other `*_props` modules this one does NOT skip wholesale when
hypothesis is absent: the randomized sweep degrades to a pinned
deterministic grid so the differential contract stays enforced on
hypothesis-free containers (and in `scripts/smoke.sh`).
"""
import importlib.util

import numpy as np
import pytest

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
if HAVE_HYPOTHESIS:
    from hypo import given, settings, st

from repro.core.mapping import RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.pimsys import (
    BatchOp,
    NttJob,
    NttOp,
    PimSession,
    PolymulOp,
    RequestScheduler,
    ServicePolicy,
    ServiceRequest,
    StatsRegistry,
    evaluate_gang,
    fastpath_verify,
    lower_commands,
    lower_plan,
    replay_gang,
    verify_stream,
)
from repro.pimsys.engine import param_beat_trace
from repro.pimsys.fastpath.jax_backend import HAS_JAX
from repro.pimsys.telemetry import Tracer

SIZES = [64, 128, 256]
ENTRIES = [0, 4, 128]


def _workload(cfg, n):
    cmds = RowCentricMapper(cfg, n).commands()
    trace = (param_beat_trace(cfg, n, cmds)
             if cfg.param_cache_entries else None)
    return cmds, trace


def _assert_identical(cfg, cmds, banks, trace, pipelined):
    """Full-depth differential check: per-command timestamps included."""
    tracer = Tracer()
    eng = replay_gang(cfg, cmds, banks, param_trace=trace,
                      pipelined=pipelined, tracer=tracer)
    lp = lower_commands(cfg, cmds, trace)
    g = evaluate_gang(lp, banks, pipelined=pipelined)

    assert g.makespan_ns == eng.makespan_ns
    assert g.bus_busy_ns == eng.bus_busy_ns
    for b in range(banks):
        assert g.bank_end_ns[b] == eng.engines[b].end_t
        assert g.counters[b] == dict(eng.engines[b].stats)
    # per-command starts/dones, per bank in issue order
    per_bank: dict = {b: [] for b in range(banks)}
    for (_, b, _, _, _, s, done, _, _) in tracer.commands:
        per_bank[b].append((s, done))
    for b in range(banks):
        rec = per_bank[b]
        assert len(rec) == lp.n_cmds
        assert [s for s, _ in rec] == list(g.starts[:, b])
        assert [d for _, d in rec] == list(g.dones[:, b])
    # interpreted-vs-fastpath stats through the registry diff helper
    a, c = StatsRegistry(), StatsRegistry()
    eng.record_stats(a)
    for b in range(banks):
        c.add_bank(0, b, dict(g.counters[b]))
    c.add_bus(0, g.bus_busy_ns, g.makespan_ns)
    assert a.diff(c) == {}


if HAVE_HYPOTHESIS:

    @settings(max_examples=20)
    @given(
        n=st.sampled_from(SIZES),
        banks=st.integers(min_value=1, max_value=16),
        entries=st.sampled_from(ENTRIES),
        nb=st.sampled_from([2, 4]),
        pipelined=st.booleans(),
    )
    def test_gang_bit_identical_to_engine(n, banks, entries, nb, pipelined):
        cfg = PimConfig(num_buffers=nb, param_cache_entries=entries)
        cmds, trace = _workload(cfg, n)
        _assert_identical(cfg, cmds, banks, trace, pipelined)


@pytest.mark.parametrize("n,banks,entries,nb,pipelined", [
    (64, 1, 0, 2, True),
    (64, 16, 128, 2, False),
    (128, 3, 4, 4, True),
    (128, 8, 0, 4, False),
    (256, 5, 128, 2, True),
    (256, 12, 4, 4, True),
])
def test_gang_bit_identical_pinned_grid(n, banks, entries, nb, pipelined):
    """Hypothesis-free floor of the property above: a pinned grid that
    crosses each axis at least once, run everywhere (incl. smoke)."""
    cfg = PimConfig(num_buffers=nb, param_cache_entries=entries)
    cmds, trace = _workload(cfg, n)
    _assert_identical(cfg, cmds, banks, trace, pipelined)


@pytest.mark.slow
def test_golden_16bank_n4096():
    """The acceptance workload: 16 banks, N=4096, cache sized to the
    working set — full-depth identity plus the session-level result."""
    cfg = PimConfig(num_buffers=4, param_cache_entries=128)
    cmds, trace = _workload(cfg, 4096)
    _assert_identical(cfg, cmds, 16, trace, True)

    sess = PimSession(cfg)
    plan = BatchOp(NttOp(4096), 16)
    a = sess.run(plan)
    b = sess.run(plan, backend="fastpath")
    assert a.timing == b.timing
    assert a.stats.diff(b.stats) == {}


def test_verify_stream_and_verify():
    cfg = PimConfig(num_buffers=2, param_cache_entries=16)
    cmds, trace = _workload(cfg, 128)
    g = verify_stream(cfg, cmds, 4, param_trace=trace)
    assert g.makespan_ns > 0
    sess = PimSession(cfg)
    plan = sess.compile(NttOp(128))
    assert fastpath_verify(plan, seed=3) > 0


def test_session_single_bank_backend_parity():
    cfg = PimConfig(num_buffers=4, param_cache_entries=64)
    sess = PimSession(cfg)
    for op in (NttOp(512), NttOp(512, forward=True), PolymulOp(256)):
        a = sess.run(op).timing
        b = sess.run(op, backend="fastpath").timing
        assert a == b  # ns, stats dict, AND the Mark phase breakdown
        assert b.phase_ns and a.phase_ns == b.phase_ns


def test_session_fastpath_rejections():
    cfg = PimConfig(num_buffers=2)
    sess = PimSession(cfg)
    with pytest.raises(ValueError, match="backend"):
        sess.run(NttOp(64), backend="warp")
    with pytest.raises(ValueError, match="telemetry"):
        PimSession(PimConfig(telemetry=True)).run(
            NttOp(64), backend="fastpath")
    with pytest.raises(ValueError, match="round-robin"):
        PimSession(cfg, policy="ready").run(
            BatchOp(NttOp(64), 2), backend="fastpath")


def test_batch_profile_matches_engine_gang():
    """A coalesced gang's profile (cold + warm concatenated streams on
    one bank) reports the same per-member completion offsets as the
    interpreted engine running the same gang."""
    cfg = PimConfig(num_buffers=2, num_channels=1, num_banks=4,
                    param_cache_entries=128)
    sched = RequestScheduler(cfg)
    job = NttJob(256)
    m = 3
    prof = sched._fast_profile(job, m)
    assert len(prof.member_done) == m

    from repro.pimsys.engine import ChannelEngine

    cmds, _ = sched._commands(job)
    cold, warm = sched._batch_traces(job)
    eng = ChannelEngine(cfg)
    bank = eng.add_bank()
    for k in range(m):
        eng.enqueue(bank, cmds, job_id=k,
                    param_trace=cold if k == 0 else warm)
    done = {ev.job_id: ev.done for ev in eng.drain()}
    assert tuple(done[k] for k in range(m)) == prof.member_done
    assert prof.release == max(prof.member_done)


def test_run_service_fastpath_verified_and_conserving():
    cfg = PimConfig(num_buffers=2, num_channels=1, num_banks=4,
                    param_cache_entries=128)
    sched = RequestScheduler(cfg)
    reqs = [ServiceRequest(arrival_ns=i * 900.0, job=NttJob(256),
                           qos="throughput" if i % 4 else "latency")
            for i in range(48)]
    pol_f = ServicePolicy(weight_latency=8.0, batch_window_us=2.0,
                          max_batch=3, backend="fastpath", verify_every=1)
    rf = sched.run_service(reqs, pol_f)
    assert rf.completed == len(reqs)
    assert np.isfinite(rf.done_ns).all()
    assert (rf.done_ns >= rf.dispatch_ns).all()
    assert sched._fast_verified  # the oracle actually ran

    pol_e = ServicePolicy(weight_latency=8.0, batch_window_us=2.0,
                          max_batch=3)
    re_ = sched.run_service(reqs, pol_e)

    def totals(stats):
        out: dict = {}
        for ch in stats.channels():
            for b in range(cfg.num_banks):
                for k, v in stats.bank_counts(ch, b).items():
                    out[k] = out.get(k, 0) + v
        out.pop("refresh", None)  # timeline-dependent by design
        # bank-release times differ between timing models, so coalescing
        # decisions (and thus the cold/warm trace mix) may differ; only
        # hit + miss is conserved — one increment per traced CU op
        out["param_ops"] = out.pop("param_hit", 0) + out.pop("param_miss", 0)
        return out

    assert totals(rf.stats) == totals(re_.stats)


def test_service_policy_fastpath_validation():
    with pytest.raises(ValueError, match="backend"):
        ServicePolicy(backend="warp")
    with pytest.raises(ValueError, match="verify_every"):
        ServicePolicy(verify_every=-1)
    with pytest.raises(ValueError, match="telemetry"):
        ServicePolicy(backend="fastpath", telemetry=True)


def test_lowering_rejects_rank_gates_and_sharded():
    cfg = PimConfig(num_buffers=2, tFAW=4)
    cmds = RowCentricMapper(cfg, 64).commands()
    with pytest.raises(ValueError):
        lower_commands(cfg, cmds)
    cfg2 = PimConfig(num_buffers=2, num_channels=1, num_banks=4)
    sess = PimSession(cfg2)
    from repro.pimsys import ShardedNttOp

    plan = sess.compile(ShardedNttOp(512, banks=4))
    with pytest.raises(ValueError):
        lower_plan(cfg2, plan)
    with pytest.raises(ValueError, match="fastpath"):
        sess.run(plan, backend="fastpath")


@pytest.mark.skipif(not HAS_JAX, reason="jax not importable")
@pytest.mark.slow
def test_jax_backend_bit_identical():
    cfg = PimConfig(num_buffers=4, param_cache_entries=32)
    cmds, trace = _workload(cfg, 256)
    lp = lower_commands(cfg, cmds, trace)
    for banks in (2, 8):
        a = evaluate_gang(lp, banks)
        b = evaluate_gang(lp, banks, backend="jax")
        assert a.makespan_ns == b.makespan_ns
        assert (a.starts == b.starts).all()
        assert (a.dones == b.dones).all()
        assert a.counters == b.counters
