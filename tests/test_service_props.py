"""Hypothesis twin of `test_service.py` — scheduler invariants.

Three properties over random traces, seeds, and policies:
  (a) job conservation: admitted + rejected == submitted, and every
      admitted request completes, across seeds and policies;
  (b) the default `ServicePolicy()` is bit-identical to the
      pre-redesign FIFO `RequestScheduler` on the same arrival trace;
  (c) batching never changes a throughput-class request's completion
      count (nor anyone else's): the completed population is identical
      with and without a coalescing window.
"""
import numpy as np
from hypo import given, settings, st

from repro.core.pim_config import PimConfig
from repro.pimsys import (
    STATUS_COMPLETED,
    STATUS_REJECTED,
    NttJob,
    PolymulJob,
    RequestScheduler,
    ServicePolicy,
    ServiceRequest,
)


def small_cfg(entries=0):
    return PimConfig(num_buffers=2, num_channels=2, num_banks=2,
                     param_cache_entries=entries)


@st.composite
def traces(draw, max_count=14):
    count = draw(st.integers(2, max_count))
    rate = draw(st.sampled_from([0.05, 0.3, 1.0]))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1e3 / rate, size=count))
    reqs = []
    for t in arrivals.tolist():
        n = draw(st.sampled_from([256, 512]))
        job = draw(st.sampled_from(["ntt", "polymul"]))
        job = NttJob(n) if job == "ntt" else PolymulJob(n)
        qos = draw(st.sampled_from(["latency", "throughput"]))
        reqs.append(ServiceRequest(t, job, qos=qos))
    return reqs


policies = st.sampled_from([
    ServicePolicy(),
    ServicePolicy(weight_latency=8.0),
    ServicePolicy(weight_latency=4.0, max_queue_depth=3),
    ServicePolicy(bucket_rate_per_us=0.2, bucket_burst=2),
    ServicePolicy(weight_latency=8.0, batch_window_us=10.0, max_batch=4),
])


@settings(max_examples=20)
@given(reqs=traces(), policy=policies)
def test_jobs_are_conserved(reqs, policy):
    res = RequestScheduler(small_cfg()).run_service(reqs, policy=policy)
    assert res.submitted == len(reqs)
    assert res.completed + res.rejected == res.submitted
    # every row is accounted for exactly once, with a valid status
    assert res.status is not None and len(res.status) == len(reqs)
    assert set(np.unique(res.status)) <= {STATUS_COMPLETED, STATUS_REJECTED}
    assert (res.status == STATUS_COMPLETED).sum() == res.completed
    # completed rows carry finite timings, rejected rows none
    done = res.status == STATUS_COMPLETED
    assert np.isfinite(res.done_ns[done]).all()
    assert np.isnan(res.done_ns[~done]).all()


@settings(max_examples=12)
@given(reqs=traces(max_count=10))
def test_default_policy_bit_identical_to_fifo(reqs):
    order = sorted(reqs, key=lambda r: r.arrival_ns)
    ref = RequestScheduler(small_cfg())._run(
        [(r.arrival_ns, r.job) for r in order])
    got = RequestScheduler(small_cfg()).run_service(reqs)
    assert got.makespan_ns == ref.makespan_ns
    assert np.array_equal(got.arrivals_ns, ref.arrivals_ns)
    assert np.array_equal(got.dispatch_ns, ref.dispatch_ns)
    assert np.array_equal(got.done_ns, ref.done_ns)
    assert got.stats.device_counts() == ref.stats.device_counts()


@settings(max_examples=12)
@given(reqs=traces(), window=st.sampled_from([1.0, 10.0, 100.0]),
       max_batch=st.integers(2, 6), entries=st.sampled_from([0, 128]))
def test_batching_never_changes_completion_counts(reqs, window, max_batch,
                                                  entries):
    cfg = small_cfg(entries)
    base = RequestScheduler(cfg).run_service(
        reqs, policy=ServicePolicy(weight_latency=2.0))
    bat = RequestScheduler(cfg).run_service(
        reqs, policy=ServicePolicy(weight_latency=2.0,
                                   batch_window_us=window,
                                   max_batch=max_batch))
    assert bat.completed == base.completed == len(reqs)
    for cls in ("latency", "throughput"):
        assert (bat._mask(cls).sum() == base._mask(cls).sum())
    # latency-class requests never ride a gang
    for row in np.flatnonzero(bat.batched):
        assert bat.qos[row] == "throughput"
