"""Core NTT library: oracles, identities, and property-based tests."""
import numpy as np
import pytest
from hypo import given, settings, st

from repro.core import modmath as mm
from repro.core import ntt

Q = mm.DEFAULT_Q
RNG = np.random.default_rng(1234)


def rand_poly(n, rng=RNG):
    return rng.integers(0, Q, n).astype(np.uint32)


# ---------------------------------------------------------------------------
# modular arithmetic primitives vs python big-int ground truth
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
@settings(max_examples=200)
def test_mulhi_u32(a, b):
    got = int(np.asarray(mm.mulhi_u32(np.uint32(a), np.uint32(b))))
    assert got == (a * b) >> 32


@given(st.integers(0, Q - 1), st.integers(0, Q - 1))
@settings(max_examples=200)
def test_mont_mul(a, b):
    qp, _, r2 = mm.mont_params(Q)
    got = int(np.asarray(mm.mont_mul_u32(np.uint32(a), np.uint32(b), Q, qp)))
    rinv = mm.inv_mod(1 << 32, Q)
    assert got == a * b * rinv % Q


@given(st.integers(0, Q - 1), st.integers(0, Q - 1))
@settings(max_examples=200)
def test_shoup_mul(a, w):
    wsh = mm.shoup(w, Q)
    got = int(np.asarray(mm.shoup_mulmod_u32(np.uint32(a), np.uint32(w), np.uint32(wsh), Q)))
    assert got == a * w % Q


@given(st.integers(0, Q - 1), st.integers(0, Q - 1))
@settings(max_examples=100)
def test_addsub_mod(a, b):
    assert int(np.asarray(mm.addmod_u32(np.uint32(a), np.uint32(b), Q))) == (a + b) % Q
    assert int(np.asarray(mm.submod_u32(np.uint32(a), np.uint32(b), Q))) == (a - b) % Q


def test_mont_roundtrip_vector():
    qp, _, r2 = mm.mont_params(Q)
    x = rand_poly(4096)
    m = mm.to_mont_u32(x, Q, qp, r2)
    back = np.asarray(mm.from_mont_u32(m, Q, qp))
    assert np.array_equal(back, x)


def test_find_ntt_prime_and_roots():
    for two_n in [2**12, 2**16]:
        q = mm.find_ntt_prime(two_n)
        assert mm.is_prime(q) and q % two_n == 1
        w = mm.root_of_unity(q, two_n)
        assert pow(w, two_n, q) == 1 and pow(w, two_n // 2, q) == q - 1


# ---------------------------------------------------------------------------
# NTT identities
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 32, 256, 1024])
def test_forward_matches_naive(n):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n)
    brv = mm.bit_reverse_indices(n)
    assert np.array_equal(ntt.ntt_forward_np(a, ctx)[brv], ntt.naive_negacyclic_ntt(a, ctx))


@pytest.mark.parametrize("n", [8, 64, 512, 4096, 16384])
def test_roundtrip(n):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n)
    assert np.array_equal(ntt.ntt_inverse_np(ntt.ntt_forward_np(a, ctx), ctx), a)


@pytest.mark.parametrize("n", [8, 64, 256])
def test_polymul_vs_schoolbook(n):
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n), rand_poly(n)
    assert np.array_equal(
        ntt.polymul_negacyclic_np(a, b, ctx), ntt.schoolbook_negacyclic(a, b, Q)
    )


@pytest.mark.parametrize("n", [16, 128, 1024])
def test_cyclic_matches_naive(n):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n)
    assert np.array_equal(ntt.cyclic_ntt_np(a, Q), ntt.naive_cyclic_ntt(a, Q, ctx.omega))


@pytest.mark.parametrize("n1,n2", [(4, 4), (8, 16), (32, 32)])
def test_four_step(n1, n2):
    n = n1 * n2
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n)
    assert np.array_equal(
        ntt.four_step_cyclic_np(a, Q, n1, n2), ntt.naive_cyclic_ntt(a, Q, ctx.omega)
    )


def test_jnp_matches_numpy():
    n = 512
    ctx = ntt.make_context(Q, n)
    a = rand_poly((3, n) if False else n).reshape(1, n).repeat(3, 0)
    a = RNG.integers(0, Q, (3, n)).astype(np.uint32)
    assert np.array_equal(np.asarray(ntt.ntt_forward_jnp(a, ctx)), ntt.ntt_forward_np(a, ctx))
    f = ntt.ntt_forward_jnp(a, ctx)
    assert np.array_equal(np.asarray(ntt.ntt_inverse_jnp(f, ctx)), a)


@given(st.sampled_from([16, 64, 256]), st.integers(0, 2**31))
@settings(max_examples=25)
def test_ntt_linearity(n, seed):
    """NTT(alpha*a + b) == alpha*NTT(a) + NTT(b)  (transform linearity)."""
    rng = np.random.default_rng(seed)
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n, rng), rand_poly(n, rng)
    alpha = int(rng.integers(1, Q))
    lhs = ntt.ntt_forward_np(np.asarray(mm.np_addmod(mm.np_mulmod(a, alpha, Q), b, Q), np.uint32), ctx)
    rhs = mm.np_addmod(mm.np_mulmod(ntt.ntt_forward_np(a, ctx), alpha, Q), ntt.ntt_forward_np(b, ctx), Q)
    assert np.array_equal(lhs.astype(np.int64), rhs)


@given(st.sampled_from([16, 64]), st.integers(0, 2**31))
@settings(max_examples=25)
def test_polymul_commutative_and_unit(n, seed):
    rng = np.random.default_rng(seed)
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n, rng), rand_poly(n, rng)
    ab = ntt.polymul_negacyclic_np(a, b, ctx)
    ba = ntt.polymul_negacyclic_np(b, a, ctx)
    assert np.array_equal(ab, ba)
    one = np.zeros(n, np.uint32)
    one[0] = 1
    assert np.array_equal(ntt.polymul_negacyclic_np(a, one, ctx), a)


def test_negacyclic_wraparound_sign():
    """x^(N-1) * x == -x^N == q-1 at coefficient 0 (X^N = -1)."""
    n = 32
    ctx = ntt.make_context(Q, n)
    xn1 = np.zeros(n, np.uint32)
    xn1[n - 1] = 1
    x = np.zeros(n, np.uint32)
    x[1] = 1
    prod = ntt.polymul_negacyclic_np(xn1, x, ctx)
    expect = np.zeros(n, np.uint32)
    expect[0] = Q - 1
    assert np.array_equal(prod, expect)
