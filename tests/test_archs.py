"""Per-architecture smoke tests: reduced config of the same family runs a
forward/train step on CPU; output shapes + finiteness asserted.  The FULL
configs are exercised via the dry-run only (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_NAMES, cell_status, get_config
from repro.data.pipeline import SyntheticStream
from repro.launch import steps as steps_lib
from repro.models import transformer as T
from repro.optim import OptConfig

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32, seed=0):
    stream = SyntheticStream(cfg, b, s, seed=seed)
    return {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_updates_params(arch):
    cfg = get_config(arch).reduced()
    opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
    params = T.init_params(cfg, KEY)
    init_opt = steps_lib.make_opt_init(cfg, opt_cfg)
    opt_state = init_opt(params)
    step_fn = steps_lib.make_train_step(cfg, opt_cfg)
    batch = make_batch(cfg)
    # step 1: step 0 has lr == 0 under linear warmup
    new_params, new_opt, metrics = step_fn(params, opt_state, batch, jnp.int32(1))
    assert np.isfinite(float(metrics["loss"]))
    # at least one weight moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params,
        new_params,
    )
    assert max(jax.tree.leaves(moved)) > 0
    # shapes preserved
    jax.tree.map(lambda a, b: (_ for _ in ()).throw(AssertionError()) if a.shape != b.shape else None,
                 params, new_params)


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-780m", "jamba-1.5-large-398b", "whisper-small"])
def test_prefill_decode_consistency(arch):
    """Serving path == scoring path (high MoE capacity to avoid drops)."""
    cfg = get_config(arch).reduced(capacity_factor=8.0)
    params = T.init_params(cfg, KEY)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    full_logits, _ = T.forward(params, cfg, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : s - 2]
    logits_pre, caches = T.prefill(params, cfg, pre, cache_len=s)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full_logits[:, s - 3], np.float32),
        rtol=0.2, atol=0.2,
    )
    lg, caches = T.decode_step(params, cfg, batch["tokens"][:, s - 2], caches, jnp.int32(s - 2))
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(full_logits[:, s - 2], np.float32),
        rtol=0.2, atol=0.2,
    )


def test_all_40_cells_defined():
    """Every (arch x shape) cell resolves to run or a documented skip."""
    cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    assert len(cells) == 40
    n_skip = 0
    for a, s in cells:
        status = cell_status(get_config(a), SHAPES[s])
        assert status == "run" or status.startswith("skip:")
        n_skip += status != "run"
    # 8 full-attention archs skip long_500k
    assert n_skip == 8


def test_config_exactness():
    """Spot-check the assigned config numbers are wired verbatim."""
    k = get_config("kimi-k2-1t-a32b")
    assert (k.num_layers, k.d_model, k.num_heads, k.num_kv_heads) == (61, 7168, 64, 8)
    assert (k.num_experts, k.experts_per_token, k.vocab_size) == (384, 8, 163840)
    j = get_config("jamba-1.5-large-398b")
    assert (j.num_layers, j.d_model, j.d_ff, j.num_experts) == (72, 8192, 24576, 16)
    assert j.pattern().count(("attn", "mlp")) + j.pattern().count(("attn", "moe")) == 1  # 1:7
    m = get_config("mamba2-780m")
    assert (m.num_layers, m.d_model, m.ssm_state) == (48, 1536, 128)
    assert m.pattern() == [("mamba", "none")]
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.num_experts, q.experts_per_token, q.num_kv_heads) == (128, 8, 4)
    w = get_config("whisper-small")
    assert (w.encoder_layers, w.d_model, w.vocab_size) == (12, 768, 51865)


@pytest.mark.parametrize("arch", ["mamba2-780m", "jamba-1.5-large-398b"])
def test_subquadratic_flags(arch):
    assert get_config(arch).subquadratic


def test_param_counts_order_of_magnitude():
    """Full configs should land near their nameplate sizes."""
    import repro.launch.steps as S

    def count(cfg):
        shapes = S.param_specs(cfg)
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    approx = {
        "qwen3-8b": 8e9,
        "deepseek-coder-33b": 33e9,
        "command-r-35b": 35e9,
        "kimi-k2-1t-a32b": 1.0e12,
        "mamba2-780m": 0.78e9,
    }
    for arch, target in approx.items():
        n = count(get_config(arch))
        assert 0.55 * target < n < 1.75 * target, (arch, n, target)
