"""Hypothesis property twin of `test_sharded.py`'s functional layer.

Random q-compatible sizes, bank counts in {2, 4, 8} and random inputs:
the sharded path must match the `core.ntt` reference EXACTLY, forward
and inverse, and round-trip to the identity.  Skips as a module when
hypothesis is absent (the `hypo` shim), like every property module in
the suite; `test_sharded.py` keeps a deterministic grid running either
way.
"""
import numpy as np
from hypo import given, settings, st

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.core.polymul import pim_ntt_sharded

Q = mm.DEFAULT_Q

# Property tests can't take the function-scoped `small_pim_cfg` fixture
# (hypothesis health check); they share this module-level twin instead.
CFG = PimConfig(num_buffers=2, num_channels=2, num_banks=2)


def rand_poly(n, seed):
    return np.random.default_rng(seed).integers(0, Q, n).astype(np.uint32)


@given(st.sampled_from([64, 128, 256, 512, 1024]), st.sampled_from([2, 4, 8]),
       st.integers(0, 2**31))
@settings(max_examples=15)
def test_sharded_inverse_matches_reference(n, banks, seed):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, seed)
    got, _ = pim_ntt_sharded(a, ctx, CFG, banks=banks)
    assert np.array_equal(got, ntt.ntt_inverse_np(a, ctx))


@given(st.sampled_from([64, 128, 256, 512, 1024]), st.sampled_from([2, 4, 8]),
       st.integers(0, 2**31))
@settings(max_examples=15)
def test_sharded_forward_matches_reference(n, banks, seed):
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, seed)
    got, _ = pim_ntt_sharded(a, ctx, CFG, banks=banks, forward=True)
    assert np.array_equal(got, ntt.ntt_forward_np(a, ctx))


@given(st.sampled_from([64, 256, 512]), st.sampled_from([2, 4, 8]),
       st.integers(0, 2**31))
@settings(max_examples=10)
def test_sharded_roundtrip(n, banks, seed):
    """INTT(NTT(x)) == x with BOTH transforms on the sharded path."""
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, seed)
    fwd, _ = pim_ntt_sharded(a, ctx, CFG, banks=banks, forward=True)
    back, _ = pim_ntt_sharded(fwd, ctx, CFG, banks=banks, forward=False)
    assert np.array_equal(back, a)


@given(st.sampled_from([256, 512, 1024]), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2]), st.booleans(), st.integers(0, 2**31))
@settings(max_examples=10)
def test_pipelined_exchange_never_slower_and_bit_exact(n, banks, ch, forward,
                                                       seed):
    """The double-buffered exchange driver is a pure schedule change:
    across sizes, bank counts, topologies and both directions it must
    never increase the makespan over the serial driver, and the plan it
    times must still compute exactly the `core.ntt` reference."""
    from repro.pimsys import PimSession, ShardedNttOp

    cfg = PimConfig(num_buffers=2, num_channels=ch, num_banks=banks // ch)
    sess = PimSession(cfg)
    cp = sess.compile(ShardedNttOp(n, banks, forward=forward))
    plan = cp.sharded_plan
    fast = plan.simulate(baseline=False)
    slow = plan.simulate(baseline=False, pipelined=False)
    assert fast.latency_ns <= slow.latency_ns + 1e-9
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, seed)
    got = sess.run(cp, a, ctx=ctx, time=False).value
    ref = (ntt.ntt_forward_np if forward else ntt.ntt_inverse_np)(a, ctx)
    assert np.array_equal(got, ref)


@given(st.sampled_from([2, 4, 8]), st.integers(0, 2**31))
@settings(max_examples=10)
def test_sharded_linearity(banks, seed):
    """NTT(alpha*a + b) == alpha*NTT(a) + NTT(b) through the shards."""
    n = 256
    rng = np.random.default_rng(seed)
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n, seed), rand_poly(n, seed + 1)
    alpha = int(rng.integers(1, Q))
    mixed = np.asarray(mm.np_addmod(mm.np_mulmod(a, alpha, Q), b, Q), np.uint32)
    lhs, _ = pim_ntt_sharded(mixed, ctx, CFG, banks=banks, forward=True)
    fa, _ = pim_ntt_sharded(a, ctx, CFG, banks=banks, forward=True)
    fb, _ = pim_ntt_sharded(b, ctx, CFG, banks=banks, forward=True)
    rhs = mm.np_addmod(mm.np_mulmod(fa, alpha, Q), fb, Q)
    assert np.array_equal(lhs.astype(np.int64), rhs)
