"""Hypothesis property twin of `test_session.py`'s parity layer.

Random sizes, buffer counts, orientations and inputs: the session's
compile/run path must match the `core.ntt` reference exactly, and plan
reuse (the whole point of the session) must not perturb results — the
same cached plan re-run on fresh inputs stays bit-exact.  Skips as a
module when hypothesis is absent (the `hypo` shim), like every property
module in the suite; `test_session.py` keeps a deterministic grid
running either way.
"""
import numpy as np
from hypo import given, settings, st

from repro.core import modmath as mm
from repro.core import ntt
from repro.core.pim_config import PimConfig
from repro.pimsys import NttOp, PimSession, PolymulOp, ShardedNttOp

Q = mm.DEFAULT_Q

# Sessions are module-level on purpose: every example below REUSES cached
# plans from earlier examples, so the properties exercise exactly the
# compile-once/run-many path the session exists for.
SESSIONS = {nb: PimSession(PimConfig(num_buffers=nb, num_channels=2,
                                     num_banks=2))
            for nb in (2, 4)}


def rand_poly(n, seed):
    return np.random.default_rng(seed).integers(0, Q, n).astype(np.uint32)


@given(st.sampled_from([64, 128, 256, 512, 1024]), st.sampled_from([2, 4]),
       st.booleans(), st.integers(0, 2**31))
@settings(max_examples=15)
def test_session_ntt_matches_reference(n, nb, forward, seed):
    sess = SESSIONS[nb]
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, seed)
    r = sess.run(sess.compile(NttOp(n, forward=forward)), a, ctx=ctx, time=False)
    ref = ntt.ntt_forward_np(a, ctx) if forward else ntt.ntt_inverse_np(a, ctx)
    assert np.array_equal(r.value, ref)


@given(st.sampled_from([64, 256, 512]), st.sampled_from([2, 4]),
       st.integers(0, 2**31))
@settings(max_examples=10)
def test_session_polymul_matches_reference(n, nb, seed):
    sess = SESSIONS[nb]
    ctx = ntt.make_context(Q, n)
    a, b = rand_poly(n, seed), rand_poly(n, seed ^ 0x5EED)
    r = sess.run(sess.compile(PolymulOp(n)), a, b, ctx=ctx, time=False)
    assert np.array_equal(r.value, ntt.polymul_negacyclic_np(a, b, ctx))


@given(st.sampled_from([128, 256, 512]), st.sampled_from([2, 4]),
       st.integers(0, 2**31))
@settings(max_examples=10)
def test_session_sharded_roundtrip(n, banks, seed):
    sess = SESSIONS[2]
    ctx = ntt.make_context(Q, n)
    a = rand_poly(n, seed)
    fwd = sess.run(sess.compile(ShardedNttOp(n, banks, forward=True)),
                   a, ctx=ctx, time=False).value
    back = sess.run(sess.compile(ShardedNttOp(n, banks)),
                    fwd, ctx=ctx, time=False).value
    assert np.array_equal(back, a)
