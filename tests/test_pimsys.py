"""Device-level memory system (`repro.pimsys`): controller equivalence vs
`BankTimer`, scaling invariants vs the analytic bus bound, trace
round-trips, and scheduler conservation."""
import numpy as np
import pytest

from repro.core.mapping import Mark, RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.pimsim import (
    BankTimer,
    analytic_multibank_bound,
    simulate_multibank,
    simulate_ntt,
)
from repro.core.polymul import polymul_batch, polymul_commands
from repro.pimsys import (
    ChannelController,
    Device,
    DeviceTopology,
    NttJob,
    PolymulJob,
    RequestScheduler,
    StatsRegistry,
    dumps_trace,
    loads_trace,
    replay_trace,
)


# ---------------------------------------------------------------------------
# topology / address mapping
# ---------------------------------------------------------------------------


def test_topology_roundtrip():
    topo = DeviceTopology(channels=4, ranks=2, banks_per_rank=4)
    assert topo.total_banks == 32
    seen = set()
    for flat in range(topo.total_banks):
        addr = topo.address_of(flat)
        assert topo.flat_of(addr) == flat
        assert topo.flat_from_local(addr.channel, topo.local_id(addr)) == flat
        seen.add(addr)
    assert len(seen) == 32
    # channel-interleaved: consecutive flat ids hit different channels
    assert topo.address_of(0).channel != topo.address_of(1).channel
    with pytest.raises(IndexError):
        topo.address_of(32)


def test_topology_from_config():
    cfg = PimConfig(num_channels=2, num_ranks=2, num_banks=8)
    topo = DeviceTopology.from_config(cfg)
    assert (topo.channels, topo.ranks, topo.banks_per_rank) == (2, 2, 8)
    assert topo.banks_per_channel == 16


# ---------------------------------------------------------------------------
# controller: banks=1 must be bit-identical to the paper's single-bank timer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("nb", [1, 2, 4, 6])
@pytest.mark.parametrize("policy", ["rr", "ready"])
def test_single_bank_bit_identical(n, nb, policy):
    cfg = PimConfig(num_buffers=nb)
    cmds = RowCentricMapper(cfg, n).commands()
    ref = BankTimer(cfg).simulate(cmds)
    ctrl = ChannelController(cfg, policy=policy)
    b = ctrl.add_bank()
    ctrl.enqueue(b, cmds, job_id="j0")
    evs = ctrl.drain()
    assert ctrl.bank_ns(b) == ref.ns  # exact ns, not approx
    assert [e.job_id for e in evs] == ["j0"]
    assert evs[0].done == ref.ns
    assert dict(ctrl.engines[b].stats) == ref.stats


def test_single_bank_polymul_bit_identical():
    cfg = PimConfig(num_buffers=4)
    cmds = polymul_commands(cfg, 1024)[0]
    ref = BankTimer(cfg).simulate(cmds)
    ctrl = ChannelController(cfg)
    b = ctrl.add_bank()
    ctrl.enqueue(b, cmds)
    ctrl.drain()
    assert ctrl.bank_ns(b) == ref.ns


def test_unpipelined_single_bank_bit_identical():
    cfg = PimConfig(num_buffers=2)
    cmds = RowCentricMapper(cfg, 512).commands()
    ref = BankTimer(cfg, pipelined=False).simulate(cmds)
    ctrl = ChannelController(cfg)
    b = ctrl.add_bank(pipelined=False)
    ctrl.enqueue(b, cmds)
    ctrl.drain()
    assert ctrl.bank_ns(b) == ref.ns


# ---------------------------------------------------------------------------
# controller: scaling invariants
# ---------------------------------------------------------------------------


def test_multibank_monotone_and_bounded():
    cfg = PimConfig(num_buffers=2)
    n = 1024
    single = simulate_ntt(n, cfg)
    prev_speedup = 0.0
    for banks in (1, 2, 4, 8):
        r = simulate_multibank(n, banks, cfg)
        # never beats the analytic shared-bus lower bound
        assert r.latency_ns >= r.analytic_latency_ns - 1e-6
        assert r.analytic_latency_ns == pytest.approx(
            analytic_multibank_bound(n, banks, cfg))
        # monotone speedup, never superlinear
        assert r.speedup >= prev_speedup - 1e-9
        assert r.speedup <= banks + 1e-9
        assert r.latency_ns >= single.ns - 1e-9
        prev_speedup = r.speedup


def test_multibank_banks1_equals_single():
    cfg = PimConfig(num_buffers=4)
    r = simulate_multibank(2048, 1, cfg)
    assert r.latency_ns == simulate_ntt(2048, cfg).ns  # exact


def test_ready_policy_not_slower_when_banks_stall():
    """Ready-first may reorder around banks stalled on tRAS/CU latency;
    it must at least not lose to round-robin on homogeneous traffic."""
    cfg = PimConfig(num_buffers=2)
    rr = simulate_multibank(1024, 8, cfg, policy="rr")
    rdy = simulate_multibank(1024, 8, cfg, policy="ready")
    assert rdy.latency_ns <= rr.latency_ns * 1.05


def test_heterogeneous_banks_on_one_bus():
    """Different-sized jobs on one channel: makespan is bounded below by
    the largest job alone and above by full serialization."""
    cfg = PimConfig(num_buffers=2)
    ctrl = ChannelController(cfg)
    sizes = [256, 1024, 4096]
    singles = []
    for i, n in enumerate(sizes):
        cmds = RowCentricMapper(cfg, n).commands()
        singles.append(BankTimer(cfg).simulate(cmds).ns)
        ctrl.enqueue(ctrl.add_bank(), cmds, job_id=i)
    ctrl.drain()
    assert ctrl.makespan_ns >= max(singles)
    assert ctrl.makespan_ns <= sum(singles)


# ---------------------------------------------------------------------------
# trace record -> replay
# ---------------------------------------------------------------------------


def test_trace_roundtrip_exact():
    cfg = PimConfig(num_buffers=4)
    streams = {
        (0, 0): RowCentricMapper(cfg, 512).commands(),
        (1, 1): polymul_commands(cfg, 256)[0],
        (0, 2): RowCentricMapper(PimConfig(num_buffers=1), 64).commands(),
    }
    text = dumps_trace(streams)
    back = loads_trace(text)
    assert back == {k: list(v) for k, v in streams.items()}
    # idempotent: dump(load(dump(x))) == dump(x)
    assert dumps_trace(back) == text


def test_trace_replay_matches_live_timing():
    cfg = PimConfig(num_buffers=2)
    cmds = RowCentricMapper(cfg, 1024).commands()
    live = ChannelController(cfg)
    for _ in range(2):
        live.enqueue(live.add_bank(), cmds)
    live.drain()
    dev = replay_trace(cfg, loads_trace(dumps_trace({(0, 0): cmds, (0, 1): cmds})))
    assert dev.makespan_ns == live.makespan_ns


def test_trace_skips_comments_and_preserves_marks():
    text = "# comment\n\n0 0 ACT 7\n0 0 MARK inter:64\n0 0 RD 7 3 1\n"
    streams = loads_trace(text)
    assert len(streams[(0, 0)]) == 3
    assert isinstance(streams[(0, 0)][1], Mark)
    with pytest.raises(ValueError):
        loads_trace("0 0 BOGUS 1\n")


# ---------------------------------------------------------------------------
# scheduler: conservation + queueing behaviour
# ---------------------------------------------------------------------------


def test_scheduler_conservation_closed_loop():
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=4)
    res = RequestScheduler(cfg).run_closed_loop([NttJob(512)] * 20)
    assert res.submitted == res.completed == 20
    assert np.all(res.done_ns >= res.dispatch_ns)
    assert np.all(res.dispatch_ns >= res.arrivals_ns)
    assert res.throughput_jobs_per_ms > 0


def test_scheduler_conservation_open_loop():
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    jobs = [NttJob(512) if i % 2 else PolymulJob(256) for i in range(30)]
    res = RequestScheduler(cfg).run_open_loop(jobs, rate_per_us=0.2, seed=11)
    assert res.submitted == res.completed == 30
    assert np.all(res.done_ns > res.arrivals_ns)
    p = res.latency_percentiles_us()
    assert p["p50"] <= p["p95"] <= p["p99"]


def test_scheduler_open_loop_deterministic_by_seed():
    cfg = PimConfig(num_buffers=2, num_banks=2)
    jobs = [NttJob(256)] * 12
    a = RequestScheduler(cfg).run_open_loop(jobs, rate_per_us=0.3, seed=5)
    b = RequestScheduler(cfg).run_open_loop(jobs, rate_per_us=0.3, seed=5)
    assert np.array_equal(a.done_ns, b.done_ns)
    c = RequestScheduler(cfg).run_open_loop(jobs, rate_per_us=0.3, seed=6)
    assert not np.array_equal(a.arrivals_ns, c.arrivals_ns)


def test_scheduler_queue_delay_appears_when_oversubscribed():
    """1 bank, many simultaneous jobs -> later jobs wait in the queue."""
    cfg = PimConfig(num_buffers=2, num_banks=1)
    res = RequestScheduler(cfg).run_closed_loop([NttJob(512)] * 4)
    delays = np.sort(res.queue_delay_ns)
    assert delays[0] == 0.0
    assert delays[-1] > 0.0
    # serial bank: makespan ~= 4x single job latency
    single = simulate_ntt(512, cfg).ns
    assert res.makespan_ns >= 4 * single - 1e-6


def test_scheduler_more_banks_cut_latency():
    cfg1 = PimConfig(num_buffers=2, num_banks=1)
    cfg8 = PimConfig(num_buffers=2, num_banks=8)
    jobs = [NttJob(512)] * 8
    r1 = RequestScheduler(cfg1).run_closed_loop(jobs)
    r8 = RequestScheduler(cfg8).run_closed_loop(jobs)
    assert r8.makespan_ns < r1.makespan_ns
    assert r8.latency_percentiles_us()["p99"] < r1.latency_percentiles_us()["p99"]


def test_scheduler_rejects_oversized_job():
    cfg = PimConfig(num_buffers=2, rows_per_bank=2)
    with pytest.raises(ValueError):
        RequestScheduler(cfg).run_closed_loop([PolymulJob(1024)])


def test_polymul_batch_wrapper():
    cfg = PimConfig(num_buffers=4, num_banks=4)
    res = polymul_batch(512, batch=8, cfg=cfg)
    assert res.completed == 8
    dev = res.stats.device_counts()
    assert dev["cmul"] > 0 and dev["act"] > 0


# ---------------------------------------------------------------------------
# stats registry
# ---------------------------------------------------------------------------


def test_stats_aggregation_and_energy():
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    res = RequestScheduler(cfg).run_closed_loop([NttJob(1024)] * 4)
    reg = res.stats
    dev = reg.device_counts()
    per_ch = [reg.channel_counts(ch) for ch in reg.channels()]
    assert sum(c.get("act", 0) for c in per_ch) == dev["act"]
    assert 0.0 < reg.bus_utilization(0) <= 1.0
    # 4 identical NTTs -> device energy ~= 4x single-bank energy
    single = simulate_ntt(1024, cfg)
    assert reg.energy_nj() == pytest.approx(4 * single.energy_nj(), rel=1e-9)
    s = reg.summary()
    assert s["per_channel"][0]["commands"] > 0


def test_device_multichannel_independent_buses():
    """Same total banks: 2 channels x 1 bank beats 1 channel x 2 banks
    (two private buses vs one shared), and equals two solo banks."""
    cfg = PimConfig(num_buffers=2)
    cmds = RowCentricMapper(cfg, 1024).commands()
    single = BankTimer(cfg).simulate(cmds).ns

    shared = ChannelController(cfg)
    for i in range(2):
        shared.enqueue(shared.add_bank(), cmds, job_id=i)
    shared.drain()

    dev = Device(cfg, DeviceTopology(channels=2, banks_per_rank=1))
    dev.enqueue_flat(0, cmds, job_id=0)
    dev.enqueue_flat(1, cmds, job_id=1)
    dev.drain()

    assert dev.makespan_ns == single  # private buses: no contention at all
    assert shared.makespan_ns > dev.makespan_ns


def test_extend_span_reaches_silent_channels():
    """Regression: `extend_span` before this fix only stretched channels
    that had already recorded bus traffic, so a silent channel that saw
    traffic LATER divided by the stale (shorter) span and over-reported
    its utilization."""
    reg = StatsRegistry(channels=2)
    reg.add_bus(0, busy_ns=10.0, span_ns=100.0)
    reg.extend_span(200.0)
    # channel 1 was silent at extend time; traffic arrives afterwards
    reg.add_bus(1, busy_ns=50.0, span_ns=0.0)
    assert reg.channels() == [0, 1]
    assert reg.bus_utilization(0) == pytest.approx(10.0 / 200.0)
    assert reg.bus_utilization(1) == pytest.approx(50.0 / 200.0)


def test_stats_summary_empty_registry():
    reg = StatsRegistry()
    s = reg.summary()
    assert s["device_counts"] == {}
    assert s["energy_nj"] == 0.0
    assert s["per_channel"] == {}
    assert "service" not in s and "timeseries" not in s
    assert reg.service_counts() == {}
    assert reg.param_hit_rate() == 0.0
    assert reg.bus_utilization(0) == 0.0


def test_param_hit_rate_bank_needs_channel_on_multichannel():
    reg = StatsRegistry(channels=2)
    reg.add_bank(0, 0, {"param_hit": 3, "param_miss": 1})
    reg.add_bank(1, 0, {"param_hit": 1, "param_miss": 3})
    with pytest.raises(ValueError, match="channel"):
        reg.param_hit_rate(bank=0)
    assert reg.param_hit_rate(channel=0, bank=0) == pytest.approx(0.75)
    assert reg.param_hit_rate(channel=1, bank=0) == pytest.approx(0.25)
    assert reg.param_hit_rate() == pytest.approx(0.5)
    # single-channel registries keep the channel-0 default
    solo = StatsRegistry(channels=1)
    solo.add_bank(0, 0, {"param_hit": 1, "param_miss": 1})
    assert solo.param_hit_rate(bank=0) == pytest.approx(0.5)


def test_service_counts_rejected_only_run():
    """An admission-controlled run where one class only ever got
    rejected: service counters must keep the class visible and
    `summary()` must carry the per-reason reject keys."""
    reg = StatsRegistry(channels=1)
    reg.add_service("latency", "submitted", 4)
    reg.add_service("latency", "rejected_queue_full", 4)
    assert reg.service_counts("latency") == {
        "submitted": 4, "rejected_queue_full": 4}
    assert reg.service_counts("throughput") == {}
    s = reg.summary()
    assert s["service"]["latency/rejected_queue_full"] == 4
    assert s["service"]["latency/submitted"] == 4
