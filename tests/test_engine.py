"""Hierarchical resource engine (`repro.pimsys.engine`): golden-cycle
bit-identity vs the pre-refactor simulator, rank-level timing windows,
and the device-side twiddle-parameter cache.

Layers of evidence:
  1. golden cycles: `tests/golden/engine_goldens.json` freezes the seed
     simulator's exact latencies over single-bank, multibank, sharded,
     and scheduler workloads; the unified engine must reproduce every
     one bit-for-bit at the default config (param_cache_entries=0, rank
     timing off).  Regenerate ONLY deliberately:
     `python scripts/gen_engine_goldens.py`.
  2. rank timing (`RankState`): tFAW caps activations per window, tRRD
     spaces same-rank ACTs, read<->write turnaround costs time; all four
     knobs are inert at 0 and only ever add latency.
  3. parameter cache: entries=0 is the seed model; enabling it tracks
     per-bank hit/miss in `StatsRegistry`, never slows any workload,
     visibly lifts the 16-bank multibank speedup, and keeps the analytic
     bus bound a true (trace-aware) lower bound.

The hypothesis twins live in `test_engine_props.py`.
"""
import json
import os
import warnings

import pytest

from repro.core.mapping import RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.pimsim import (
    BankTimer,
    analytic_multibank_bound,
)
from repro.pimsys import (
    BatchOp,
    ChannelController,
    Device,
    DeviceTopology,
    NttJob,
    NttOp,
    PimSession,
    PolymulJob,
    RequestScheduler,
    ShardedNttOp,
    ShardedNttPlan,
    param_beat_trace,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "engine_goldens.json")
RANK_CFG = dict(tFAW=24, tRRD=4, tRTW=8, tWTR=5)  # HBM2E-class windows


def _goldens():
    with open(GOLDEN) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# 1. golden cycle counts: the engine IS the seed model at defaults
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rec", _goldens()["single"],
                         ids=lambda r: f"n{r['n']}-nb{r['nb']}-f{int(r['forward'])}")
def test_golden_single_bank_bit_identical(rec):
    cfg = PimConfig(num_buffers=rec["nb"])
    cmds = RowCentricMapper(cfg, rec["n"], forward=rec["forward"]).commands()
    assert len(cmds) == rec["commands"]  # command list did not drift
    r = BankTimer(cfg).simulate(cmds)
    assert r.ns == rec["ns"]  # exact ns, not approx
    assert dict(sorted(r.stats.items())) == rec["stats"]


@pytest.mark.parametrize("rec", _goldens()["multibank"],
                         ids=lambda r: f"n{r['n']}-nb{r['nb']}-b{r['banks']}-{r['policy']}")
def test_golden_multibank_bit_identical(rec):
    cfg = PimConfig(num_buffers=rec["nb"])
    cmds = RowCentricMapper(cfg, rec["n"]).commands()
    ctrl = ChannelController(cfg, policy=rec["policy"])
    for i in range(rec["banks"]):
        ctrl.enqueue(ctrl.add_bank(), cmds, job_id=i)
    ctrl.drain()
    assert ctrl.makespan_ns == rec["latency_ns"]
    assert ctrl.bus_busy_ns == rec["bus_busy_ns"]
    assert analytic_multibank_bound(rec["n"], rec["banks"], cfg) == rec["analytic_ns"]


@pytest.mark.parametrize("rec", _goldens()["sharded"],
                         ids=lambda r: f"n{r['n']}-b{r['banks']}-f{int(r['forward'])}")
def test_golden_sharded_bit_identical(rec):
    cfg = PimConfig(num_buffers=rec["nb"], num_channels=rec["channels"],
                    num_banks=rec["banks_per_rank"])
    r = ShardedNttPlan(cfg, rec["n"], rec["banks"],
                       forward=rec["forward"]).simulate(baseline=False)
    assert r.latency_ns == rec["latency_ns"]
    assert r.local_ns == rec["local_ns"]
    assert r.exchange_ns == rec["exchange_ns"]
    assert (r.xfer_atoms, r.xfer_hops) == (rec["xfer_atoms"], rec["xfer_hops"])


def test_golden_scheduler_bit_identical():
    rec = _goldens()["scheduler"][0]
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=2)
    jobs = [NttJob(512), PolymulJob(256), NttJob(1024), NttJob(512),
            PolymulJob(512), NttJob(256)]
    closed = RequestScheduler(cfg).run_closed_loop(jobs)
    assert [float(x) for x in closed.done_ns] == rec["closed_done_ns"]
    assert closed.makespan_ns == rec["closed_makespan_ns"]
    open_ = RequestScheduler(cfg).run_open_loop(jobs, rate_per_us=0.1, seed=3)
    assert [float(x) for x in open_.done_ns] == rec["open_done_ns"]
    assert open_.makespan_ns == rec["open_makespan_ns"]


# ---------------------------------------------------------------------------
# 2. rank-level timing
# ---------------------------------------------------------------------------


def _multibank_device(cfg, n=1024, banks=8, record_acts=False):
    dev = Device(cfg, DeviceTopology(channels=1, banks_per_rank=banks),
                 record_acts=record_acts)
    cmds = RowCentricMapper(cfg, n).commands()
    for f in range(banks):
        dev.enqueue_flat(f, cmds, job_id=f)
    dev.drain()
    return dev


def test_rank_timing_inert_at_zero():
    """All-zero rank fields reproduce the unconstrained seed timing even
    when commands route through the (recording) rank path."""
    cfg = PimConfig(num_buffers=2)
    base = _multibank_device(cfg)
    rec = _multibank_device(cfg, record_acts=True)
    assert rec.makespan_ns == base.makespan_ns
    assert len(rec.channels[0].act_starts(0)) > 0


def test_tfaw_window_enforced():
    """With tFAW on, any tFAW-wide slice of the ACT trace holds <= 4
    activations per rank — and enforcing it costs latency on a rank of
    8 contending banks."""
    cfg = PimConfig(num_buffers=2)
    cfg_r = cfg.with_(**RANK_CFG)
    base = _multibank_device(cfg, record_acts=True)
    dev = _multibank_device(cfg_r, record_acts=True)
    acts = sorted(dev.channels[0].act_starts(0))
    faw = cfg_r.tFAW * cfg_r.dram_ns
    for i in range(len(acts) - 4):
        assert acts[i + 4] >= acts[i] + faw - 1e-9
    assert dev.makespan_ns > base.makespan_ns
    # the unconstrained run really does violate the window (the
    # constraint is not vacuous on this workload)
    acts0 = sorted(base.channels[0].act_starts(0))
    assert any(acts0[i + 4] < acts0[i] + faw for i in range(len(acts0) - 4))


def test_trrd_spacing_enforced():
    cfg = PimConfig(num_buffers=2).with_(tRRD=4)
    dev = _multibank_device(cfg, banks=4, record_acts=True)
    acts = sorted(dev.channels[0].act_starts(0))
    trrd = cfg.tRRD * cfg.dram_ns
    assert all(b - a >= trrd - 1e-9 for a, b in zip(acts, acts[1:]))


def test_rank_partitioning_relieves_tfaw():
    """Same 8 banks: 2 ranks of 4 see less tFAW pressure than 1 rank of
    8, so the two-rank device is never slower."""
    cfg = PimConfig(num_buffers=2).with_(**RANK_CFG)
    cmds = RowCentricMapper(cfg, 1024).commands()

    def run(ranks, banks_per_rank):
        dev = Device(cfg, DeviceTopology(channels=1, ranks=ranks,
                                         banks_per_rank=banks_per_rank))
        for f in range(8):
            dev.enqueue_flat(f, cmds, job_id=f)
        dev.drain()
        return dev.makespan_ns

    assert run(2, 4) <= run(1, 8)


def test_turnaround_only_adds_latency():
    cfg = PimConfig(num_buffers=2)
    base = _multibank_device(cfg, banks=4)
    turn = _multibank_device(cfg.with_(tRTW=8, tWTR=5), banks=4)
    assert turn.makespan_ns >= base.makespan_ns


def test_rank_timing_single_bank_unchanged():
    """One bank alone: tRAS spacing dominates every rank window, so the
    paper-calibrated single-bank timing is untouched even with rank
    timing enabled."""
    cfg = PimConfig(num_buffers=2)
    cmds = RowCentricMapper(cfg, 1024).commands()
    ref = BankTimer(cfg).simulate(cmds)
    ctrl = ChannelController(cfg.with_(**RANK_CFG))
    ctrl.enqueue(ctrl.add_bank(), cmds)
    ctrl.drain()
    assert ctrl.makespan_ns == ref.ns


# ---------------------------------------------------------------------------
# 3. device-side twiddle-parameter cache
# ---------------------------------------------------------------------------


def test_param_trace_disabled_is_none():
    cfg = PimConfig(num_buffers=2)
    cmds = RowCentricMapper(cfg, 256).commands()
    assert param_beat_trace(cfg, 256, cmds) is None


def test_param_trace_shape_and_monotone_beats():
    cfg = PimConfig(num_buffers=2, param_cache_entries=8)
    cmds = RowCentricMapper(cfg, 512).commands()
    trace = param_beat_trace(cfg, 512, cmds)
    from repro.core.pimsim import PARAM_OPS

    cu_ops = sum(1 for c in cmds if c.__class__ in PARAM_OPS)
    assert len(trace) == cu_ops
    full = cfg.param_load_cycles
    assert all(b == full or (b == 1 and code == 2) for b, code in trace)
    assert any(code == 2 for _, code in trace)  # some locality exists
    assert trace[0][1] == 1  # first access is compulsory-miss


def test_cache_lifts_16bank_speedup_and_counts_hits():
    """The acceptance bar: enabling the cache must measurably improve
    the 16-bank multibank speedup, with per-bank hit/miss counters in
    the stats registry."""
    n = 1024
    sess0 = PimSession(PimConfig(num_buffers=2))
    sessC = PimSession(PimConfig(num_buffers=2, param_cache_entries=8))
    plan0 = sess0.compile(BatchOp(NttOp(n), 16))
    planC = sessC.compile(BatchOp(NttOp(n), 16))
    r0 = sess0.run(plan0)
    rC = sessC.run(planC)
    assert rC.timing.speedup > r0.timing.speedup * 1.05
    assert rC.timing.latency_ns < r0.timing.latency_ns
    assert rC.timing.param_hit_rate > 0.3
    assert r0.timing.param_hit_rate == 0.0
    # per-bank tracking: every bank ran the same stream -> same counters
    for b in range(16):
        counts = rC.stats.bank_counts(0, b)
        assert counts["param_hit"] > 0 and counts["param_miss"] > 0
        assert counts == rC.stats.bank_counts(0, 0)
    assert rC.stats.param_hit_rate() == pytest.approx(
        rC.stats.param_hit_rate(bank=0))
    # the analytic bound is trace-aware and still a bound
    assert rC.timing.latency_ns >= rC.timing.analytic_latency_ns - 1e-6
    assert rC.timing.analytic_latency_ns < r0.timing.analytic_latency_ns


def test_cache_never_slows_sharded():
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=4)
    r0 = ShardedNttPlan(cfg, 2048, 8).simulate(baseline=False)
    rC = ShardedNttPlan(cfg.with_(param_cache_entries=8), 2048, 8).simulate(
        baseline=False)
    assert rC.latency_ns <= r0.latency_ns
    # the exchange shares one twiddle per pair: high hit rate there
    assert rC.stats.device_counts()["param_hit"] > 0
    assert rC.latency_ns >= ShardedNttPlan(
        cfg.with_(param_cache_entries=8), 2048, 8).analytic_local_bound() - 1e-6


def test_cache_single_bank_faster_and_consistent():
    cfg = PimConfig(num_buffers=2, param_cache_entries=16)
    sess = PimSession(cfg)
    plan = sess.compile(NttOp(1024))
    t = sess.run(plan).timing
    base = BankTimer(PimConfig(num_buffers=2)).simulate(plan.commands)
    assert t.ns < base.ns
    assert t.stats["param_hit"] + t.stats["param_miss"] == (
        t.stats["c1"] + t.stats["c2"])


def test_cache_zero_regeneration_on_repeat_runs():
    """Plan-level residency traces are frozen: a second run touches
    neither the mapper nor the trace builder."""
    from repro.core import mapping

    sess = PimSession(PimConfig(num_buffers=2, param_cache_entries=8))
    plan = sess.compile(BatchOp(NttOp(512), 4))
    sess.run(plan)
    gen0 = mapping.mapper_generations()
    t0 = plan.param_trace
    sess.run(plan)
    assert mapping.mapper_generations() == gen0
    assert plan.param_trace is t0  # same frozen object, no rebuild


def test_cache_through_scheduler_submit():
    cfg = PimConfig(num_buffers=2, num_banks=2, param_cache_entries=8)
    sess = PimSession(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = sess.submit(sess.compile(NttOp(512)), count=6)
    dev = res.stats.device_counts()
    assert dev["param_hit"] > 0
    sess0 = PimSession(PimConfig(num_buffers=2, num_banks=2))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res0 = sess0.submit(sess0.compile(NttOp(512)), count=6)
    assert res.timing.makespan_ns <= res0.timing.makespan_ns


def test_legacy_shims_cache_aware():
    """The deprecated entry points ride the session path, so the cache
    reaches them too — same cycles as the session for the same cfg."""
    from repro.core.pimsim import simulate_multibank

    cfg = PimConfig(num_buffers=2, param_cache_entries=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = simulate_multibank(1024, 8, cfg)
    sess = PimSession(cfg)
    direct = sess.run(sess.compile(BatchOp(NttOp(1024), 8))).timing
    assert legacy.latency_ns == direct.latency_ns
    assert legacy.param_hit_rate == direct.param_hit_rate


def test_trace_replay_with_param_traces_matches_live():
    """A recorded cache-enabled workload replays bit-exactly when the
    per-stream residency traces ride along; without them the replay
    charges the flat model — conservative, never faster."""
    from repro.pimsys import dumps_trace, loads_trace, replay_trace

    cfg = PimConfig(num_buffers=2, param_cache_entries=8)
    cmds = RowCentricMapper(cfg, 512).commands()
    trace = param_beat_trace(cfg, 512, cmds)
    live = ChannelController(cfg)
    for _ in range(2):
        live.enqueue(live.add_bank(), cmds, param_trace=trace)
    live.drain()
    streams = loads_trace(dumps_trace({(0, 0): cmds, (0, 1): cmds}))
    dev = replay_trace(cfg, streams,
                       param_traces={(0, 0): trace, (0, 1): trace})
    assert dev.makespan_ns == live.makespan_ns
    assert replay_trace(cfg, streams).makespan_ns >= dev.makespan_ns
    # the plan surfaces the same mapping, keyed like its trace_streams
    sess = PimSession(cfg)
    plan = sess.compile(BatchOp(NttOp(512), 2))
    r = sess.run(plan)
    pts = plan.param_trace_streams()
    assert set(pts) == set(plan.trace_streams())
    dev2 = replay_trace(cfg, loads_trace(r.trace.dumps()), param_traces=pts)
    assert dev2.makespan_ns == r.timing.latency_ns


def test_param_trace_length_mismatch_raises():
    cfg = PimConfig(num_buffers=2, param_cache_entries=4)
    cmds = RowCentricMapper(cfg, 256).commands()
    trace = param_beat_trace(cfg, 256, cmds)
    ctrl = ChannelController(cfg)
    with pytest.raises(ValueError, match="shorter"):
        ctrl.enqueue(ctrl.add_bank(), cmds, param_trace=trace[:-1])
    ctrl = ChannelController(cfg)
    with pytest.raises(ValueError, match="longer"):
        ctrl.enqueue(ctrl.add_bank(), cmds, param_trace=trace + trace[-1:])
    with pytest.raises(ValueError, match="longer"):
        BankTimer(cfg).simulate(cmds, trace + trace[-1:])


def test_sharded_op_with_rank_timing_and_cache():
    """Both features composed through the session API: still beats one
    bank, still above the (cache-aware) analytic local bound."""
    cfg = PimConfig(num_buffers=2, num_channels=2, num_banks=4,
                    param_cache_entries=8, **RANK_CFG)
    sess = PimSession(cfg)
    r = sess.run(sess.compile(ShardedNttOp(4096, 8))).timing
    assert r.speedup > 1.5
    assert r.latency_ns >= r.analytic_local_ns - 1e-6
