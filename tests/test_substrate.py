"""Substrate tests: optimizer math, data determinism, checkpoint + resume,
fault injection, straggler accounting, compression codecs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticStream
from repro.distributed.compression import ef_compress, ef_decompress
from repro.launch.train import FaultInjector, train
from repro.optim import OptConfig, make_optimizer, schedule


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_matches_reference():
    cfg = OptConfig(optimizer="adamw", lr_peak=1e-2, warmup_steps=0, total_steps=1000,
                    weight_decay=0.0, grad_clip=1e9)
    init, update = make_optimizer(cfg)
    p = {"w": jnp.ones((4, 4)) * 2.0}
    g = {"w": jnp.full((4, 4), 0.5)}
    state = init(p)
    new_p, state, _ = update(g, state, p, jnp.int32(0))
    # step 0: m=0.05, v=0.0125*... bias-corrected mhat=g, vhat=g^2 => delta=1
    expect = 2.0 - float(schedule(cfg, 0)) * (0.5 / (np.sqrt(0.25) + cfg.eps))
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)


def test_adamw_bf16_moments_close_to_fp32():
    base = dict(lr_peak=1e-3, warmup_steps=0, total_steps=100, weight_decay=0.01)
    rng = np.random.default_rng(0)
    p0 = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    grads = [
        {"w": jnp.asarray(rng.standard_normal((8, 8)) * 0.1, jnp.float32)}
        for _ in range(10)
    ]
    traj = {}
    for dt in ("float32", "bfloat16"):
        cfg = OptConfig(moment_dtype=dt, **base)
        init, update = make_optimizer(cfg)
        p, st = p0, init(p0)
        for t, g in enumerate(grads):
            p, st, _ = update(g, st, p, jnp.int32(t))
        traj[dt] = np.asarray(p["w"])
    np.testing.assert_allclose(traj["bfloat16"], traj["float32"], atol=5e-3)


def test_adafactor_reduces_loss_quadratic():
    cfg = OptConfig(optimizer="adafactor", lr_peak=0.1, warmup_steps=0, total_steps=100,
                    weight_decay=0.0)
    init, update = make_optimizer(cfg)
    target = jnp.asarray(np.random.default_rng(1).standard_normal((6, 6)), jnp.float32)
    p = {"w": jnp.zeros((6, 6))}
    st = init(p)
    losses = []
    for t in range(50):
        loss, g = jax.value_and_grad(lambda pp: jnp.mean((pp["w"] - target) ** 2))(p)
        p, st, _ = update(g, st, p, jnp.int32(t))
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0]


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, 0)) == 0.0
    assert abs(float(schedule(cfg, 10)) - 1e-3) < 1e-9
    assert float(schedule(cfg, 100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(schedule(cfg, 55)) < 1e-3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_host_sharded():
    cfg = get_config("qwen3-4b").reduced()
    a = SyntheticStream(cfg, 8, 64, seed=3).batch_at(17)
    b = SyntheticStream(cfg, 8, 64, seed=3).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticStream(cfg, 8, 64, seed=4).batch_at(17)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding: different hosts, disjoint-but-deterministic slices
    h0 = SyntheticStream(cfg, 8, 64, seed=3, host_id=0, num_hosts=2).batch_at(5)
    h1 = SyntheticStream(cfg, 8, 64, seed=3, host_id=1, num_hosts=2).batch_at(5)
    assert h0["tokens"].shape == (4, 64)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_prefetch_iterator():
    cfg = get_config("qwen3-4b").reduced()
    stream = SyntheticStream(cfg, 4, 32, seed=0)
    it = stream.iterate(start_step=7)
    s, batch = next(it)
    assert s == 7
    np.testing.assert_array_equal(batch["tokens"], stream.batch_at(7)["tokens"])
    s2, _ = next(it)
    assert s2 == 8


def test_tokens_in_vocab_range():
    cfg = get_config("command-r-35b").reduced()
    b = SyntheticStream(cfg, 4, 128, seed=0).batch_at(0)
    assert b["tokens"].min() >= 1 and b["tokens"].max() < cfg.vocab_size


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
             "b": [jnp.ones(5), {"c": jnp.zeros((2, 2), jnp.bfloat16)}]}
    mgr.save(3, state)
    assert mgr.latest_step() == 3
    restored, manifest = mgr.restore(3, state)
    assert manifest["step"] == 3
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(np.asarray(x, np.float32),
                                                            np.asarray(y, np.float32)),
                 state, restored)


def test_checkpoint_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp dir is never listed as a restorable step."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "step_9.tmp")
    assert mgr.all_steps() == []


# ---------------------------------------------------------------------------
# train loop: loss goes down, resume, fault injection
# ---------------------------------------------------------------------------


def test_train_loop_resume_and_fault_injection(tmp_path):
    kwargs = dict(
        arch="qwen3-4b", batch=4, seq=64, ckpt_dir=str(tmp_path),
        ckpt_every=5, log_every=100,
    )
    # phase 1: run 10 steps
    _, _, hist1 = train(steps=10, **kwargs)
    assert len(hist1) == 10
    # phase 2: resume (should start at 10, not 0) and hit an injected fault
    injector = FaultInjector([13])
    _, _, hist2 = train(steps=16, injector=injector, **kwargs)
    steps_run = [h["step"] for h in hist2]
    assert steps_run[0] == 10
    # the injected fault at 13 rolled back to ckpt 10 and re-ran 10..13
    assert steps_run.count(10) + steps_run.count(11) >= 2
    assert steps_run[-1] == 15
    # determinism: re-running a step after rollback gives identical data
    cfg = get_config("qwen3-4b").reduced()
    s = SyntheticStream(cfg, 4, 64, seed=0)
    np.testing.assert_array_equal(s.batch_at(12)["tokens"], s.batch_at(12)["tokens"])


def test_train_loss_decreases(tmp_path):
    _, _, hist = train(
        arch="qwen3-4b", steps=30, batch=8, seq=64, ckpt_dir=str(tmp_path),
        ckpt_every=50, log_every=100,
    )
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_ef_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    residual = jnp.zeros(1000)
    code, scale, residual = ef_compress(g, residual)
    assert code.dtype == jnp.int8
    decoded = ef_decompress(code, scale)
    # single-shot error bounded by scale/2
    assert float(jnp.max(jnp.abs(decoded - g))) <= float(scale) / 2 + 1e-7
    # error feedback: accumulated residual captures the quantization error
    np.testing.assert_allclose(np.asarray(decoded + residual), np.asarray(g), atol=1e-6)
