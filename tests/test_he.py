"""Differential tests for `repro.he`: every ciphertext op bit-exact vs
the big-integer CRT reference, keyswitch correctness under a known
secret, device-plan timing sanity, and session/service integration."""
import numpy as np
import pytest

import repro.he as he
from repro.core.pim_config import PimConfig
from repro.pimsys import (
    GangJob,
    PimSession,
    ServicePolicy,
    validate_chrome_trace,
)

N = 64
CFG = PimConfig(num_channels=2, num_banks=4, param_cache_entries=8)
SESS = PimSession(CFG)  # shared: exercises plan memoization across tests

LEVELS = [2, 4, 8]


def _basis(towers):
    return he.make_basis(N, towers)


# --------------------------------------------------------------------------
# RNS layer vs big-int CRT oracles
# --------------------------------------------------------------------------


@pytest.mark.parametrize("towers", LEVELS)
def test_crt_roundtrip(towers):
    basis = _basis(towers)
    rng = np.random.default_rng(towers)
    coeffs = [int(x) for x in rng.integers(0, 1 << 60, N)]
    res = basis.encode(coeffs)
    assert res.shape == (towers, N) and res.dtype == np.uint32
    assert basis.decode(res) == [c % basis.modulus for c in coeffs]


@pytest.mark.parametrize("towers", LEVELS)
def test_ntt_towers_roundtrip(towers):
    basis = _basis(towers)
    x = he.random_poly(basis, 11)
    back = he.ntt_towers(basis, he.ntt_towers(basis, x, True), False)
    assert np.array_equal(back, x)


@pytest.mark.parametrize("towers", LEVELS)
def test_ct_mul_matches_bigint_reference(towers):
    basis = _basis(towers)
    a, b = he.random_ct(basis, 1), he.random_ct(basis, 2)
    got = he.ct_mul(basis, a, b)
    want = he.ct_mul_reference(basis, a, b)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("towers", LEVELS)
def test_keyswitch_matches_bigint_reference(towers):
    basis = _basis(towers)
    s_from, s_to = he.make_secret(basis, 1), he.make_secret(basis, 0)
    ksk = he.make_keyswitch_key(basis, s_from, s_to, seed=3)
    c2 = he.random_poly(basis, 9)
    got = he.keyswitch(basis, c2, ksk)
    want = he.keyswitch_reference(basis, c2, ksk)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("towers", LEVELS)
def test_keyswitch_correct_under_known_secret(towers):
    """<ks(c2), (1, s)> == c2 * s^2 for a relinearization key (e = 0)."""
    basis = _basis(towers)
    s = he.make_secret(basis, 0)
    rlk = he.relin_key(basis, s, seed=7)
    c2 = he.random_poly(basis, 13)
    ks = he.keyswitch(basis, c2, rlk)
    lhs = basis.decode(
        (ks[0].astype(np.uint64)
         + he.poly_mul_towers(basis, ks[1], s).astype(np.uint64))
        % np.array(basis.moduli, np.uint64)[:, None])
    rhs = basis.decode(
        he.poly_mul_towers(basis, c2, he.poly_mul_towers(basis, s, s)))
    assert lhs == rhs


@pytest.mark.parametrize("towers", LEVELS)
def test_relinearize_preserves_decryption(towers):
    basis = _basis(towers)
    s = he.make_secret(basis, 0)
    rlk = he.relin_key(basis, s, seed=7)
    a, b = he.random_ct(basis, 4), he.random_ct(basis, 5)
    d = he.ct_mul(basis, a, b)
    ct2 = he.relinearize(basis, d, rlk)
    assert he.decrypt(basis, ct2, s) == he.decrypt(basis, d, s)


@pytest.mark.parametrize("towers", LEVELS)
def test_fused_matches_unfused(towers):
    basis = _basis(towers)
    s = he.make_secret(basis, 0)
    rlk = he.relin_key(basis, s, seed=7)
    a, b = he.random_ct(basis, 4), he.random_ct(basis, 5)
    fused = he.ct_mul_relin(basis, a, b, rlk)
    unfused = he.relinearize(basis, he.ct_mul(basis, a, b), rlk)
    assert np.array_equal(fused, unfused)


@pytest.mark.parametrize("towers", LEVELS)
def test_rescale_matches_bigint_reference(towers):
    basis = _basis(towers)
    ct = he.random_ct(basis, 6)
    got = he.rescale(basis, ct)
    want = he.rescale_reference(basis, ct)
    assert got.shape == (2, towers - 1, N)
    assert np.array_equal(got, want)


def test_base_extend_exact():
    basis = _basis(4)
    x = he.random_poly(basis, 21)
    ext = basis.base_extend(x)
    q = np.array(basis.moduli, np.uint64)
    for j in range(4):
        digit = [int(v) for v in _lift(basis, x[j], j)]
        for i in range(4):
            want = np.array([d % basis.moduli[i] for d in digit], np.uint32)
            assert np.array_equal(ext[j, i], want)


def _lift(basis, row, j):
    return row.astype(np.uint64)  # digits are the [0, q_j) lift itself


# --------------------------------------------------------------------------
# Device plans: session compile/run
# --------------------------------------------------------------------------


@pytest.mark.parametrize("towers", LEVELS)
def test_session_ct_mul_value_exact(towers):
    basis = _basis(towers)
    plan = SESS.compile(he.RlweCtMulOp(n=N, towers=towers))
    a, b = he.random_ct(basis, 1), he.random_ct(basis, 2)
    r = SESS.run(plan, a, b)
    assert np.array_equal(r.value, he.ct_mul_reference(basis, a, b))
    assert r.timing.towers == towers
    assert r.timing.banks == min(towers, CFG.num_channels * CFG.num_banks)


def test_session_keyswitch_and_rescale_values():
    basis = _basis(4)
    s = he.make_secret(basis, 0)
    rlk = he.relin_key(basis, s, seed=7)
    c2 = he.random_poly(basis, 9)
    rk = SESS.run(SESS.compile(he.KeySwitchOp(n=N, towers=4)), c2, rlk)
    assert np.array_equal(rk.value, he.keyswitch_reference(basis, c2, rlk))
    ct = he.random_ct(basis, 6)
    rr = SESS.run(SESS.compile(he.RescaleOp(n=N, towers=4)), ct)
    assert np.array_equal(rr.value, he.rescale_reference(basis, ct))
    a, b = he.random_ct(basis, 1), he.random_ct(basis, 2)
    rf = SESS.run(SESS.compile(he.CtMulRelinOp(n=N, towers=4)), a, b, rlk)
    assert np.array_equal(rf.value, he.ct_mul_relin(basis, a, b, rlk))


def test_plans_memoized():
    p1 = SESS.compile(he.RlweCtMulOp(n=N, towers=4))
    p2 = SESS.compile(he.RlweCtMulOp(n=N, towers=4))
    assert p1 is p2
    assert p1.job() == GangJob(op=p1.op, banks=p1.ext.banks, rows=p1.ext.rows)


def test_tower_parallel_speedup():
    """banks = towers beats one bank, with efficiency >= 0.7 for the
    compute-bound ops (the acceptance gate)."""
    for op in (he.RlweCtMulOp(n=N, towers=4),
               he.KeySwitchOp(n=N, towers=4),
               he.CtMulRelinOp(n=N, towers=4)):
        t = SESS.run(SESS.compile(op)).timing
        assert t.single_ns > t.latency_ns
        assert t.efficiency >= 0.7, (op, t.efficiency)
        # superlinearity from per-tower param-cache residency is capped
        assert t.speedup <= 1.5 * t.banks


def test_keyswitch_moves_real_bursts():
    t = SESS.run(SESS.compile(he.KeySwitchOp(n=N, towers=4))).timing
    assert t.xfer_atoms > 0
    assert t.xfer_hops > 0            # 2 channels -> some cross-channel
    assert t.phase_ns["base_extend"] > 0
    assert set(t.phase_ns) == {"base_extend", "digit_ntt", "inner", "inv"}


def test_rescale_movement_dominated():
    t = SESS.run(SESS.compile(he.RescaleOp(n=N, towers=4))).timing
    assert t.xfer_atoms == 2 * 3 * (N // CFG.atom_words)  # 2 polys x 3 peers
    assert t.phase_ns["mod_down"] > 0


def test_single_bank_run_has_no_bursts():
    op = he.KeySwitchOp(n=N, towers=3, banks=1)
    t = SESS.run(SESS.compile(op)).timing
    assert t.banks == 1
    assert t.xfer_atoms == 0 and t.xfer_hops == 0
    assert t.efficiency == pytest.approx(1.0)


def test_param_cache_residency_per_tower():
    """Co-located towers must not alias programs: 1 bank (all moduli
    share one LRU) hits strictly less often than banks = towers."""
    op_wide = he.KeySwitchOp(n=N, towers=4)
    op_one = he.KeySwitchOp(n=N, towers=4, banks=1)
    wide = SESS.run(SESS.compile(op_wide)).timing
    one = SESS.run(SESS.compile(op_one)).timing
    assert wide.param_hit_rate is not None
    assert one.param_hit_rate <= wide.param_hit_rate


def test_op_validation():
    with pytest.raises(ValueError):
        SESS.compile(he.RlweCtMulOp(n=48, towers=2))     # not a power of two
    with pytest.raises(ValueError):
        SESS.compile(he.RescaleOp(n=N, towers=1))        # nothing to drop
    with pytest.raises(ValueError):
        SESS.compile(he.KeySwitchOp(n=N, towers=2, banks=999))
    with pytest.raises(ValueError):
        plan = SESS.compile(he.RlweCtMulOp(n=N, towers=2))
        basis = _basis(2)
        SESS.run(plan, he.random_ct(basis, 1))           # arity
    with pytest.raises(ValueError):
        plan = SESS.compile(he.KeySwitchOp(n=N, towers=2))
        other = he.make_basis(N, 3)
        key = he.relin_key(other, he.make_secret(other, 0))
        SESS.run(plan, he.random_poly(_basis(2), 1), key)  # wrong basis


def test_fastpath_direct_run_rejected():
    plan = SESS.compile(he.RlweCtMulOp(n=N, towers=2))
    with pytest.raises(ValueError, match="fastpath"):
        SESS.run(plan, backend="fastpath")


def test_telemetry_spans_cover_base_extend():
    sess = PimSession(PimConfig(num_channels=2, num_banks=4, telemetry=True))
    basis = _basis(4)
    rlk = he.relin_key(basis, he.make_secret(basis, 0), seed=7)
    r = sess.run(sess.compile(he.KeySwitchOp(n=N, towers=4)),
                 he.random_poly(basis, 9), rlk)
    assert r.telemetry is not None
    names = {p[1] for p in r.telemetry.tracer.phases}
    assert {"base_extend", "digit_ntt", "inner", "inv"} <= names
    assert validate_chrome_trace(r.telemetry.chrome_trace()) == []


# --------------------------------------------------------------------------
# Service integration: gang issue through the scheduler
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["engine", "fastpath"])
def test_service_he_traffic(backend):
    svc = SESS.service(ServicePolicy(backend=backend))
    mul = SESS.compile(he.RlweCtMulOp(n=N, towers=4))
    ks = SESS.compile(he.KeySwitchOp(n=N, towers=4))
    futs = [svc.submit(mul) for _ in range(5)]
    futs += [svc.submit(ks, qos="latency") for _ in range(3)]
    done = [f.result() for f in svc.as_completed(futs)]
    assert len(done) == 8
    assert all(d.status == "completed" for d in done)
    assert all(d.done_us > d.arrival_us for d in done)


def test_service_mixed_he_and_polymul():
    from repro.pimsys import PolymulOp
    svc = SESS.service(ServicePolicy())
    he_plan = SESS.compile(he.CtMulRelinOp(n=N, towers=3))
    pm_plan = SESS.compile(PolymulOp(N))
    futs = [svc.submit(he_plan), svc.submit(pm_plan), svc.submit(he_plan)]
    done = [f.result() for f in svc.as_completed(futs)]
    assert [d.status for d in done] == ["completed"] * 3


def test_gang_job_validation():
    sched = SESS.scheduler()
    with pytest.raises(ValueError):
        sched._validate_gang(GangJob(op="x", banks=0))
    with pytest.raises(ValueError):
        sched._validate_gang(GangJob(op="x", banks=10 ** 6))
    with pytest.raises(TypeError, match="resolver"):
        sched._gang_latency(GangJob(op="unprimed"), [0])
