"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode on CPU (the TPU lowering shares the
same code path; see also the dry-run which .lower().compile()s them)."""
import numpy as np
import pytest
from hypo import given, settings, st

from repro.core import modmath as mm
from repro.core.ntt import make_context, schoolbook_negacyclic
from repro.kernels import ops, ref
from repro.kernels.modmul import modmul_pallas
from repro.kernels.ntt import ntt_pallas

Q = mm.DEFAULT_Q
RNG = np.random.default_rng(42)


def rand(shape, q=Q, rng=RNG):
    return rng.integers(0, q, shape).astype(np.uint32)


# ---------------------------------------------------------------------------
# shape sweep: fused-full and two-regime paths, both directions
# ---------------------------------------------------------------------------

SHAPES = [
    # (batch, n, tile, batch_block)
    (1, 256, None, None),
    (3, 512, None, 2),
    (8, 1024, None, 8),
    (5, 4096, None, 4),     # odd batch -> padding path
    (2, 4096, 512, None),   # two-regime
    (4, 8192, 1024, 2),
    (1, 16384, 2048, None),
    (2, 16384, 4096, 2),
]


@pytest.mark.parametrize("batch,n,tile,bb", SHAPES)
@pytest.mark.parametrize("forward", [True, False])
def test_ntt_kernel_matches_ref(batch, n, tile, bb, forward):
    ctx = make_context(Q, n)
    x = rand((batch, n))
    got = np.asarray(ntt_pallas(x, ctx, forward=forward, tile=tile, batch_block=bb))
    exp_fn = ref.ntt_forward_ref if forward else ref.ntt_inverse_ref
    exp = np.asarray(exp_fn(x, ctx))
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("n,tile", [(1024, None), (8192, 1024)])
def test_ntt_kernel_roundtrip(n, tile):
    ctx = make_context(Q, n)
    x = rand((3, n))
    f = ntt_pallas(x, ctx, forward=True, tile=tile)
    back = np.asarray(ntt_pallas(f, ctx, forward=False, tile=tile))
    np.testing.assert_array_equal(back, x)


def test_ntt_kernel_1d_input():
    ctx = make_context(Q, 512)
    x = rand(512)
    got = np.asarray(ntt_pallas(x, ctx, forward=True))
    exp = np.asarray(ref.ntt_forward_ref(x, ctx))
    np.testing.assert_array_equal(got, exp)


# -- alternative modulus (dtype/parameter sweep: q is the "dtype" here) ------


@pytest.mark.parametrize("q", [998244353, 469762049, mm.find_ntt_prime(2**15, bits=30)])
def test_ntt_kernel_other_primes(q):
    n = 1024
    ctx = make_context(q, n)
    x = rand((2, n), q=q)
    got = np.asarray(ntt_pallas(x, ctx, forward=True))
    exp = np.asarray(ref.ntt_forward_ref(x, ctx))
    np.testing.assert_array_equal(got, exp)
    back = np.asarray(ntt_pallas(got, ctx, forward=False))
    np.testing.assert_array_equal(back, x)


# ---------------------------------------------------------------------------
# modmul kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(17,), (2, 1000), (3, 4, 256), (1, 65536)])
def test_modmul_matches_ref(shape):
    ctx = make_context(Q, 256)
    a, b = rand(shape), rand(shape)
    got = np.asarray(modmul_pallas(a, b, ctx))
    exp = np.asarray(ref.modmul_ref(a, b, ctx))
    np.testing.assert_array_equal(got, exp)
    exact = (a.astype(object) * b.astype(object)) % Q
    np.testing.assert_array_equal(got.astype(object), exact)


# ---------------------------------------------------------------------------
# composed ops
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 2048])
def test_polymul_ntt_vs_schoolbook(n):
    ctx = make_context(Q, n)
    a, b = rand(n), rand(n)
    got = np.asarray(ops.polymul_ntt(a, b, ctx))
    np.testing.assert_array_equal(got, schoolbook_negacyclic(a, b, Q))


def test_polymul_batched():
    n = 512
    ctx = make_context(Q, n)
    a, b = rand((4, n)), rand((4, n))
    got = np.asarray(ops.polymul_ntt(a, b, ctx))
    for i in range(4):
        np.testing.assert_array_equal(got[i], schoolbook_negacyclic(a[i], b[i], Q))


def test_ntt_conv_fixedpoint_close_to_direct():
    n = 256
    ctx = make_context(Q, n)
    rng = np.random.default_rng(3)
    u = rng.standard_normal(n).astype(np.float32)
    k = (rng.standard_normal(n) * 0.1).astype(np.float32)
    got = np.asarray(ops.ntt_conv_fixedpoint(u, k, ctx, frac_bits=10))
    # direct negacyclic conv in float64
    direct = np.zeros(n)
    for i in range(n):
        for j in range(n):
            idx = (i + j) % n
            sign = 1.0 if i + j < n else -1.0
            direct[idx] += sign * float(u[i]) * float(k[j])
    np.testing.assert_allclose(got, direct, atol=0.05, rtol=0.01)


# ---------------------------------------------------------------------------
# property-based: kernel respects transform algebra
# ---------------------------------------------------------------------------


@given(st.sampled_from([256, 1024]), st.integers(0, 2**31 - 1))
@settings(max_examples=10)
def test_kernel_linearity(n, seed):
    rng = np.random.default_rng(seed)
    ctx = make_context(Q, n)
    a = rng.integers(0, Q, (1, n)).astype(np.uint32)
    b = rng.integers(0, Q, (1, n)).astype(np.uint32)
    fa = np.asarray(ntt_pallas(a, ctx)).astype(np.int64)
    fb = np.asarray(ntt_pallas(b, ctx)).astype(np.int64)
    ab = ((a.astype(np.int64) + b) % Q).astype(np.uint32)
    fab = np.asarray(ntt_pallas(ab, ctx)).astype(np.int64)
    np.testing.assert_array_equal(fab, (fa + fb) % Q)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10)
def test_kernel_delta_transform(seed):
    """NTT(delta_0) = all-ones (psi^0 * w^0 = 1 in every output)."""
    n = 512
    ctx = make_context(Q, n)
    delta = np.zeros((1, n), np.uint32)
    delta[0, 0] = 1
    out = np.asarray(ntt_pallas(delta, ctx))
    np.testing.assert_array_equal(out, np.ones((1, n), np.uint32))


# ---------------------------------------------------------------------------
# NttBackend: the unified {reference, pim-sim, pallas} differential
# ---------------------------------------------------------------------------


@given(st.sampled_from([256, 1024]), st.booleans(), st.integers(0, 2**31 - 1))
@settings(max_examples=8)
def test_backend_differential_property(n, forward, seed):
    """Random inputs, both directions: every available backend agrees
    BIT-exactly with the reference.  `tests/test_backend.py` is the
    deterministic twin that runs even without hypothesis."""
    from repro.kernels.backend import available_backends, get_backend

    x = np.random.default_rng(seed).integers(0, Q, (2, n)).astype(np.uint32)
    exp = get_backend("reference").ntt(x, forward=forward)
    for b in available_backends():
        assert np.array_equal(b.ntt(x, forward=forward), exp), b.name
