"""RNS-CKKS arithmetic layer: residue towers, CRT, keyswitch, rescale.

The paper's motivating application is FHE, where a ciphertext is a pair
(or triple) of degree-n polynomials under an RNS modulus Q = q_0 ... q_{L-1}
of NTT-friendly primes: every polynomial is stored as L independent
*residue towers* (rows of `np.uint32`, shape `[L, n]`), and every
tower's arithmetic is an ordinary negacyclic NTT/polymul modulo its own
prime — exactly the workload one NTT-PIM bank serves.  This module is
the functional half of `repro.he`:

  * `RnsBasis` — a chain of distinct NTT-friendly moduli (q = 1 mod 2n,
    descending 31-bit primes) with one `ntt.make_context` per tower,
    CRT `encode`/`decode` between big-int coefficient vectors and the
    tower matrix, and the gadget of CRT idempotents used for digit
    decomposition.
  * production tower ops — `ct_mul`, `keyswitch`, `relinearize`,
    `ct_mul_relin`, `rescale`: vectorized per-tower numpy NTT math,
    bit-exact against the big-int references below (per-tower equality
    follows from CRT: schoolbook mod Q reduced mod q_i equals the
    tower-i NTT convolution).
  * big-int references — `ct_mul_reference`, `keyswitch_reference`,
    `rescale_reference`, `decrypt`: O(n^2) schoolbook over python ints
    mod Q, the oracle the differential tests pin every op against.

Keyswitching uses the exact RNS gadget: digit j of a polynomial is its
tower-j residue lifted to [0, q_j), the gadget element g_j is the CRT
idempotent (Q/q_j) * [(Q/q_j)^{-1}]_{q_j} (g_j = 1 mod q_j, 0 mod q_i),
so sum_j D_j g_j = c exactly mod Q, and keys are generated with zero
noise — keyswitch output is therefore bit-exact, not approximate, which
is what makes the device path differentially testable.  Rescale is the
exact mod-down c' = (c - [c]_{q_last}) / q_last on the shortened basis.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt


# --------------------------------------------------------------------------
# Basis
# --------------------------------------------------------------------------


def rns_primes(n: int, towers: int, bits: int = 31) -> tuple[int, ...]:
    """`towers` distinct primes q = 1 (mod 2n), descending from 2**bits."""
    if towers < 1:
        raise ValueError("towers must be >= 1")
    two_n = 2 * n
    out: list[int] = []
    p = ((1 << bits) - 2) // two_n * two_n + 1
    while len(out) < towers and p > two_n:
        if mm.is_prime(p):
            out.append(p)
        p -= two_n
    if len(out) < towers:
        raise ValueError(
            f"only {len(out)} NTT-friendly {bits}-bit primes exist for n={n}")
    return tuple(out)


@dataclasses.dataclass(frozen=True, eq=False)
class RnsBasis:
    """A chain of NTT-friendly moduli with per-tower twiddle contexts.

    Compared by identity (like `NttContext`): `make_basis` memoizes, so
    equal parameters return the same object and plan caches stay keyed
    by the hashable `(n, moduli)` op fields, never by the basis itself.
    """

    n: int
    moduli: tuple[int, ...]
    contexts: tuple[ntt.NttContext, ...] = dataclasses.field(repr=False)

    @property
    def towers(self) -> int:
        return len(self.moduli)

    @functools.cached_property
    def modulus(self) -> int:
        """Q = prod(q_i), a python big int."""
        q = 1
        for m in self.moduli:
            q *= m
        return q

    @functools.cached_property
    def _crt(self) -> tuple[tuple[int, ...], tuple[int, ...]]:
        """(Q/q_i, [(Q/q_i)^{-1}]_{q_i}) per tower."""
        hats = tuple(self.modulus // q for q in self.moduli)
        invs = tuple(mm.inv_mod(h % q, q) for h, q in zip(hats, self.moduli))
        return hats, invs

    @functools.cached_property
    def gadget(self) -> tuple[int, ...]:
        """CRT idempotents g_j mod Q: g_j = 1 mod q_j, 0 mod q_{i!=j}."""
        hats, invs = self._crt
        return tuple(h * v % self.modulus for h, v in zip(hats, invs))

    def encode(self, coeffs) -> np.ndarray:
        """Big-int coefficient vector -> residue matrix `[towers, n]`."""
        if len(coeffs) != self.n:
            raise ValueError(f"expected {self.n} coefficients, got {len(coeffs)}")
        ints = [int(c) for c in coeffs]
        out = np.empty((self.towers, self.n), np.uint32)
        for i, q in enumerate(self.moduli):
            out[i] = np.array([c % q for c in ints], np.uint32)
        return out

    def decode(self, res: np.ndarray) -> list[int]:
        """Residue matrix `[towers, n]` -> coefficients in [0, Q)."""
        res = np.asarray(res)
        if res.shape != (self.towers, self.n):
            raise ValueError(f"expected shape {(self.towers, self.n)}, "
                             f"got {res.shape}")
        big_q = self.modulus
        out = [0] * self.n
        for i, g in enumerate(self.gadget):
            row = res[i]
            for k in range(self.n):
                out[k] = (out[k] + int(row[k]) * g) % big_q
        return out

    def base_extend(self, res: np.ndarray) -> np.ndarray:
        """Digit-decompose and extend: `[towers, n]` -> `[towers, towers, n]`.

        Digit j is the tower-j residue lifted to the integer range
        [0, q_j); entry `[j, i]` is that lift reduced mod q_i (exact —
        the lift is already a full integer, no approximate floating
        base conversion).  On the device this is the keyswitch
        broadcast: digit j leaves tower j's bank for every other bank.
        """
        res = np.asarray(res, np.uint64)
        out = np.empty((self.towers, self.towers, self.n), np.uint32)
        for j in range(self.towers):
            lift = res[j]
            for i, qi in enumerate(self.moduli):
                out[j, i] = (lift % qi).astype(np.uint32)
        return out

    def drop_last(self) -> "RnsBasis":
        """The rescale target basis (one fewer tower), memoized."""
        if self.towers < 2:
            raise ValueError("cannot drop the last remaining tower")
        return make_basis(self.n, self.towers - 1, moduli=self.moduli[:-1])


def make_basis(n: int, towers: int,
               moduli: tuple[int, ...] | None = None) -> RnsBasis:
    """Memoized basis factory (shared twiddle contexts across sessions)."""
    if moduli is None:
        moduli = rns_primes(n, towers)
    else:
        moduli = tuple(int(q) for q in moduli)
        if len(moduli) != towers:
            raise ValueError(f"{towers} towers but {len(moduli)} moduli")
        if len(set(moduli)) != len(moduli):
            raise ValueError("moduli must be distinct")
    return _cached_basis(n, moduli)


@functools.lru_cache(maxsize=None)
def _cached_basis(n: int, moduli: tuple[int, ...]) -> RnsBasis:
    contexts = tuple(ntt.make_context(q, n) for q in moduli)
    return RnsBasis(n=n, moduli=moduli, contexts=contexts)


# --------------------------------------------------------------------------
# Per-tower vector math (the production path the device plans mirror)
# --------------------------------------------------------------------------


def ntt_towers(basis: RnsBasis, x: np.ndarray, forward: bool = True) -> np.ndarray:
    """Per-tower (inverse) NTT over the trailing two axes `[..., L, n]`.

    `ntt.ntt_inverse_np` includes the 1/N scaling, matching the device
    plan's explicit `scale` pass after each inverse phase.
    """
    x = np.asarray(x, np.uint32)
    out = np.empty_like(x)
    fn = ntt.ntt_forward_np if forward else ntt.ntt_inverse_np
    for i, ctx in enumerate(basis.contexts):
        out[..., i, :] = fn(x[..., i, :], ctx)
    return out


def _mul(basis: RnsBasis, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    out = np.empty(np.broadcast_shapes(x.shape, y.shape), np.uint32)
    for i, q in enumerate(basis.moduli):
        out[..., i, :] = mm.np_mulmod(x[..., i, :], y[..., i, :], q)
    return out


def _add(basis: RnsBasis, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    out = np.empty(np.broadcast_shapes(x.shape, y.shape), np.uint32)
    for i, q in enumerate(basis.moduli):
        out[..., i, :] = mm.np_addmod(x[..., i, :], y[..., i, :], q)
    return out


def random_poly(basis: RnsBasis, seed: int) -> np.ndarray:
    """A uniformly random residue matrix `[towers, n]` (independent
    towers — i.e. a uniform element of R_Q by CRT)."""
    rng = np.random.default_rng(seed)
    out = np.empty((basis.towers, basis.n), np.uint32)
    for i, q in enumerate(basis.moduli):
        out[i] = rng.integers(0, q, basis.n, dtype=np.uint64).astype(np.uint32)
    return out


def random_ct(basis: RnsBasis, seed: int, k: int = 2) -> np.ndarray:
    """A random `k`-component ciphertext `[k, towers, n]`."""
    return np.stack([random_poly(basis, seed * 1000 + c) for c in range(k)])


def make_secret(basis: RnsBasis, seed: int = 0) -> np.ndarray:
    """A ternary secret s in {-1, 0, 1}^n, encoded per tower."""
    rng = np.random.default_rng(seed)
    s = rng.integers(-1, 2, basis.n)
    out = np.empty((basis.towers, basis.n), np.uint32)
    for i, q in enumerate(basis.moduli):
        out[i] = np.mod(s, q).astype(np.uint32)
    return out


def poly_mul_towers(basis: RnsBasis, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Negacyclic product per tower (NTT domain round trip)."""
    return ntt_towers(basis, _mul(basis, ntt_towers(basis, a),
                                  ntt_towers(basis, b)), forward=False)


def ct_mul(basis: RnsBasis, ct_a: np.ndarray, ct_b: np.ndarray) -> np.ndarray:
    """Tensor two ciphertexts: `[2, L, n]` x `[2, L, n]` -> `[3, L, n]`.

    (a0 + a1 s)(b0 + b1 s) = d0 + d1 s + d2 s^2 with d0 = a0 b0,
    d1 = a0 b1 + a1 b0, d2 = a1 b1 — 4 forward NTTs, 4 pointwise
    products + 1 add, 3 inverse NTTs per tower (the device plan's
    fwd/pointwise/inv phase counts come from exactly this).
    """
    a = ntt_towers(basis, np.asarray(ct_a, np.uint32))
    b = ntt_towers(basis, np.asarray(ct_b, np.uint32))
    d0 = _mul(basis, a[0], b[0])
    d1 = _add(basis, _mul(basis, a[0], b[1]), _mul(basis, a[1], b[0]))
    d2 = _mul(basis, a[1], b[1])
    return ntt_towers(basis, np.stack([d0, d1, d2]), forward=False)


@dataclasses.dataclass(frozen=True, eq=False)
class KeySwitchKey:
    """Gadget keyswitch key from `s_from` to `s_to`, zero noise.

    `b[j] = -a[j] s_to + g_j s_from` with uniform `a[j]`, per tower:
    since g_j is the CRT idempotent, tower i of b[j] is
    `-a[j] s_to + (s_from if i == j else 0)`.  Both halves are kept in
    the coefficient domain (`b`, `a`, shape `[L, L, n]`) and the NTT
    domain (`b_hat`, `a_hat`) — the device holds the NTT-domain copy
    resident so the inner products are pointwise.
    """

    basis: RnsBasis
    b: np.ndarray
    a: np.ndarray

    @functools.cached_property
    def b_hat(self) -> np.ndarray:
        return ntt_towers(self.basis, self.b)

    @functools.cached_property
    def a_hat(self) -> np.ndarray:
        return ntt_towers(self.basis, self.a)


def make_keyswitch_key(basis: RnsBasis, s_from: np.ndarray, s_to: np.ndarray,
                       seed: int = 0) -> KeySwitchKey:
    big_l = basis.towers
    a = np.stack([random_poly(basis, seed * 7919 + j) for j in range(big_l)])
    b = np.empty_like(a)
    for j in range(big_l):
        prod = poly_mul_towers(basis, a[j], s_to)
        for i, q in enumerate(basis.moduli):
            row = mm.np_submod(np.zeros(basis.n, np.uint32), prod[i], q)
            if i == j:
                row = mm.np_addmod(row, s_from[i], q)
            b[j, i] = row
    return KeySwitchKey(basis=basis, b=b, a=a)


def relin_key(basis: RnsBasis, s: np.ndarray, seed: int = 0) -> KeySwitchKey:
    """Relinearization key: keyswitch from s^2 to s."""
    return make_keyswitch_key(basis, poly_mul_towers(basis, s, s), s, seed=seed)


def keyswitch(basis: RnsBasis, c2: np.ndarray, ksk: KeySwitchKey) -> np.ndarray:
    """Switch one polynomial to the key pair: `[L, n]` -> `[2, L, n]`.

    Digits base-extend (the device's broadcast phase), forward-NTT per
    tower (L transforms each), pointwise inner products against the
    resident NTT-domain key, one accumulator pair, two inverse NTTs.
    Exact: c0' + c1' s_to = c2 * s_from mod Q.
    """
    digits = basis.base_extend(np.asarray(c2, np.uint32))   # [L, L, n]
    dhat = ntt_towers(basis, digits)
    acc0 = _mul(basis, dhat[0], ksk.b_hat[0])
    acc1 = _mul(basis, dhat[0], ksk.a_hat[0])
    for j in range(1, basis.towers):
        acc0 = _add(basis, acc0, _mul(basis, dhat[j], ksk.b_hat[j]))
        acc1 = _add(basis, acc1, _mul(basis, dhat[j], ksk.a_hat[j]))
    return ntt_towers(basis, np.stack([acc0, acc1]), forward=False)


def relinearize(basis: RnsBasis, d: np.ndarray, ksk: KeySwitchKey) -> np.ndarray:
    """Degree-2 -> degree-1: `[3, L, n]` -> `[2, L, n]`."""
    ks = keyswitch(basis, d[2], ksk)
    return np.stack([_add(basis, d[0], ks[0]), _add(basis, d[1], ks[1])])


def ct_mul_relin(basis: RnsBasis, ct_a: np.ndarray, ct_b: np.ndarray,
                 ksk: KeySwitchKey) -> np.ndarray:
    """Fused multiply + relinearize: `[2, L, n]` x 2 -> `[2, L, n]`.

    Functionally `relinearize(ct_mul(...))`; the fused device plan
    differs only in *timing* (d0/d1 and the keyswitch accumulators stay
    in the NTT domain, saving 3 inverse NTTs per tower), so this one
    definition is the functional value of both spellings.
    """
    return relinearize(basis, ct_mul(basis, ct_a, ct_b), ksk)


def rescale(basis: RnsBasis, ct: np.ndarray) -> np.ndarray:
    """Exact mod-down by q_last: `[k, L, n]` -> `[k, L-1, n]`.

    c'_i = (c_i - [c]_{q_last}) * q_last^{-1} mod q_i — the integer
    c - [c]_{q_last} is divisible by q_last, so this is the exact value
    (c - [c]_{q_last}) / q_last on the shortened basis.
    """
    ct = np.asarray(ct, np.uint32)
    if ct.shape[-2] != basis.towers:
        raise ValueError(f"ciphertext has {ct.shape[-2]} towers, "
                         f"basis {basis.towers}")
    q_last = basis.moduli[-1]
    last = ct[..., -1, :].astype(np.uint64)
    out = np.empty(ct.shape[:-2] + (basis.towers - 1, basis.n), np.uint32)
    for i, q in enumerate(basis.moduli[:-1]):
        inv = np.uint32(mm.inv_mod(q_last % q, q))
        delta = mm.np_submod(ct[..., i, :], (last % q).astype(np.uint32), q)
        out[..., i, :] = mm.np_mulmod(delta, inv, q)
    return out


# --------------------------------------------------------------------------
# Big-int CRT references (the differential oracle)
# --------------------------------------------------------------------------


def _poly_mul_int(a: list[int], b: list[int], n: int, big_q: int) -> list[int]:
    """Negacyclic schoolbook over python ints mod Q (x^n = -1)."""
    out = [0] * n
    for i, ai in enumerate(a):
        if ai == 0:
            continue
        for j, bj in enumerate(b):
            k = i + j
            if k < n:
                out[k] += ai * bj
            else:
                out[k - n] -= ai * bj
    return [x % big_q for x in out]


def ct_mul_reference(basis: RnsBasis, ct_a: np.ndarray,
                     ct_b: np.ndarray) -> np.ndarray:
    """Big-int oracle for `ct_mul` (O(n^2) schoolbook mod Q)."""
    big_q, n = basis.modulus, basis.n
    a0, a1 = (basis.decode(c) for c in np.asarray(ct_a))
    b0, b1 = (basis.decode(c) for c in np.asarray(ct_b))
    d0 = _poly_mul_int(a0, b0, n, big_q)
    d1 = [(x + y) % big_q for x, y in zip(_poly_mul_int(a0, b1, n, big_q),
                                          _poly_mul_int(a1, b0, n, big_q))]
    d2 = _poly_mul_int(a1, b1, n, big_q)
    return np.stack([basis.encode(d) for d in (d0, d1, d2)])


def keyswitch_reference(basis: RnsBasis, c2: np.ndarray,
                        ksk: KeySwitchKey) -> np.ndarray:
    """Big-int oracle for `keyswitch`: sum_j D_j * (b_j, a_j) mod Q."""
    big_q, n = basis.modulus, basis.n
    res = np.asarray(c2)
    c0 = [0] * n
    c1 = [0] * n
    for j in range(basis.towers):
        digit = [int(v) for v in res[j]]  # the lift, already in [0, q_j)
        pb = _poly_mul_int(digit, basis.decode(ksk.b[j]), n, big_q)
        pa = _poly_mul_int(digit, basis.decode(ksk.a[j]), n, big_q)
        c0 = [(x + y) % big_q for x, y in zip(c0, pb)]
        c1 = [(x + y) % big_q for x, y in zip(c1, pa)]
    return np.stack([basis.encode(c0), basis.encode(c1)])


def rescale_reference(basis: RnsBasis, ct: np.ndarray) -> np.ndarray:
    """Big-int oracle for `rescale`: (v - [v]_{q_last}) / q_last mod Q'."""
    ct = np.asarray(ct)
    sub = basis.drop_last()
    q_last = basis.moduli[-1]
    out = []
    for comp in ct:
        v = basis.decode(comp)
        scaled = [((x - int(r)) // q_last) % sub.modulus
                  for x, r in zip(v, comp[-1])]
        out.append(sub.encode(scaled))
    return np.stack(out)


def decrypt(basis: RnsBasis, ct: np.ndarray, s: np.ndarray) -> list[int]:
    """c0 + c1 s (+ c2 s^2) mod Q over python ints — the test probe that
    proves keyswitch/relinearize preserve the encrypted value."""
    big_q, n = basis.modulus, basis.n
    ct = np.asarray(ct)
    s_int = basis.decode(s)
    out = basis.decode(ct[0])
    pw = s_int
    for comp in ct[1:]:
        term = _poly_mul_int(basis.decode(comp), pw, n, big_q)
        out = [(x + y) % big_q for x, y in zip(out, term)]
        pw = _poly_mul_int(pw, s_int, n, big_q)
    return out
