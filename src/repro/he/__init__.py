"""`repro.he` — RNS-CKKS ciphertext ops on the PIM device (beyond the paper).

The paper's NTT-PIM bank is the inner loop of RNS homomorphic
encryption: every ciphertext op is a bundle of independent per-modulus
negacyclic NTTs and pointwise passes — one *residue tower* per
modulus, and towers are the natural bank-parallel axis.  This package
opens that workload:

  * `rns` — the math layer: `RnsBasis` (chain of NTT-friendly moduli,
    each with its own `ntt.make_context`), CRT encode/decode, and
    exact numpy references for ciphertext multiply, gadget keyswitch
    (with base extension), and rescale — plus big-integer oracles the
    differential tests check against.
  * `ops` — the device layer: `RlweCtMulOp` / `KeySwitchOp` /
    `RescaleOp` / `CtMulRelinOp` specs that register with
    `PimSession.compile` through the op-handler registry and lower
    each tower onto its own reserved bank (gang issue through
    `DeviceEngine`, base-extension modeled as real bus bursts,
    per-tower modulus-salted parameter-cache residency).

Importing `repro.he` is enough to enable the ops:

    import repro.he as he
    from repro.pimsys import PimSession

    sess = PimSession(cfg)
    plan = sess.compile(he.RlweCtMulOp(n=4096, towers=4))
    basis = he.basis_for(plan.op)
    r = sess.run(plan, he.random_ct(basis, 1), he.random_ct(basis, 2))
    r.timing.efficiency      # tower-parallel efficiency vs one bank
"""
from repro.he.ops import (
    HE_OPS,
    CtMulRelinOp,
    HeOpHandler,
    HePlan,
    HeTimingResult,
    KeySwitchOp,
    RescaleOp,
    RlweCtMulOp,
    basis_for,
)
from repro.he.rns import (
    KeySwitchKey,
    RnsBasis,
    ct_mul,
    ct_mul_reference,
    ct_mul_relin,
    decrypt,
    keyswitch,
    keyswitch_reference,
    make_basis,
    make_keyswitch_key,
    make_secret,
    ntt_towers,
    poly_mul_towers,
    random_ct,
    random_poly,
    relin_key,
    relinearize,
    rescale,
    rescale_reference,
    rns_primes,
)

__all__ = [
    "HE_OPS",
    "CtMulRelinOp",
    "HeOpHandler",
    "HePlan",
    "HeTimingResult",
    "KeySwitchKey",
    "KeySwitchOp",
    "RescaleOp",
    "RlweCtMulOp",
    "RnsBasis",
    "basis_for",
    "ct_mul",
    "ct_mul_reference",
    "ct_mul_relin",
    "decrypt",
    "keyswitch",
    "keyswitch_reference",
    "make_basis",
    "make_keyswitch_key",
    "make_secret",
    "ntt_towers",
    "poly_mul_towers",
    "random_ct",
    "random_poly",
    "relin_key",
    "relinearize",
    "rescale",
    "rescale_reference",
    "rns_primes",
]
