"""Ciphertext-level op specs compiled to multi-tower PIM plans.

The device half of `repro.he`: four hashable op specs —

    RlweCtMulOp(n, towers)     tensor two ciphertexts -> degree-2 ct
    KeySwitchOp(n, towers)     gadget keyswitch of one polynomial
    RescaleOp(n, towers)       exact mod-down by the last tower
    CtMulRelinOp(n, towers)    fused multiply + relinearize

— registered with `PimSession.compile` through the op-handler registry
(`repro.pimsys.session.register_op_handler`), so importing `repro.he`
is all it takes: plans are frozen and memoized by `(cfg, op)` like the
builtins, `run()` returns the ordinary `RunResult`, and the service
dispatches them as `GangJob`s (each plan primes a latency resolver the
scheduler caches by channel pattern — O(1) replay per request, which
keeps fastpath-policy serving eligible for homogeneous HE traffic).

Lowering model (tower -> bank, phase-barriered)
-----------------------------------------------
Tower t maps to reserved bank `flats[t % banks]` — at banks = towers
each residue tower owns a bank (and, flat order being channel-
interleaved, spreads over channels), which is the embarrassingly
parallel axis of RNS: every tower's NTT/pointwise phase is an
independent single-modulus stream the paper's bank already serves.
A plan is a sequence of *segments*:

  * compute segments enqueue one identical command stream per tower
    (forward NTTs, pointwise passes, inverse NTTs + scaling) on the
    tower's bank, gated on that tower's previous segment;
  * transfer segments model real data movement over the shared buses
    with `DeviceEngine.burst` — keyswitch base-extension broadcasts
    each digit from its home bank to every other reserved bank,
    rescale broadcasts the dropped tower's polynomials; same-bank
    moves are local row traffic and free.

The parameter-cache residency trace is computed PER TOWER with the
program key salted by the tower's modulus: the device cache keys
(w0, r_w) programs, and two towers share a bank but never a modulus,
so their programs must not alias (`engine.param_program_key` alone
would).  Towers sharing a bank walk one LRU sequentially in tower
order — the coarse serialization the bank FIFO imposes anyway.

Commands are identical across towers (only parameter *values* differ,
which timing never sees), so phase command streams are built once per
plan and replayed per tower: compile-once/run-many, like every other
plan in the repo.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Mapping, Sequence


from repro.core.mapping import Command, RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.pimsim import PARAM_OPS
from repro.core.polymul import pointwise_commands, scaling_commands
from repro.he import rns
from repro.pimsys.engine import (
    _P_HIT,
    _P_MISS,
    DeviceEngine,
    param_hit_beats,
    param_program_key,
)
from repro.pimsys.scheduler import GangJob, RequestScheduler
from repro.pimsys.session import (
    CompiledPlan,
    OpHandler,
    PimSession,
    RunResult,
    register_op_handler,
)
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.telemetry import TelemetryHandle, Tracer
from repro.pimsys.topology import DeviceTopology


# --------------------------------------------------------------------------
# Op specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RlweCtMulOp:
    """Tensor two degree-1 ciphertexts into a degree-2 one.

    Inputs `[2, towers, n]` x 2, output `[3, towers, n]`.  Per tower:
    4 forward NTTs, 4 pointwise products + 1 accumulate pass, 3 inverse
    NTTs (+ scaling).  `banks=0` reserves min(towers, device banks);
    `moduli=None` uses the default descending-prime basis.
    """

    n: int
    towers: int
    banks: int = 0
    moduli: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class KeySwitchOp:
    """Gadget keyswitch of one polynomial: `[L, n]` (+ key) -> `[2, L, n]`.

    The NTT-dominated HE kernel: base-extension broadcast (modeled as
    bus bursts), L forward NTTs per tower, 2L pointwise products
    against the bank-resident NTT-domain key, 2 inverse NTTs.
    """

    n: int
    towers: int
    banks: int = 0
    moduli: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class RescaleOp:
    """Exact mod-down by the last tower: `[2, L, n]` -> `[2, L-1, n]`.

    Movement-dominated: the dropped tower's two polynomials broadcast
    to every surviving tower's bank, then a subtract + scalar-multiply
    pass per component per tower.  No NTTs.
    """

    n: int
    towers: int
    banks: int = 0
    moduli: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class CtMulRelinOp:
    """Fused multiply + relinearize: `[2, L, n]` x 2 (+ key) -> `[2, L, n]`.

    Keeps d0/d1 and the keyswitch accumulators in the NTT domain so
    only d2 round-trips for digit decomposition — 3 inverse NTTs per
    tower against 5 for the unfused `RlweCtMulOp` + `KeySwitchOp` pair.
    """

    n: int
    towers: int
    banks: int = 0
    moduli: tuple[int, ...] | None = None


HE_OPS = (RlweCtMulOp, KeySwitchOp, RescaleOp, CtMulRelinOp)


def basis_for(op) -> rns.RnsBasis:
    """The (memoized) `RnsBasis` an HE op spec computes under."""
    return rns.make_basis(op.n, op.towers, moduli=op.moduli)


# --------------------------------------------------------------------------
# Plan segments
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class _Compute:
    """One per-tower command stream, issued on every (listed) tower's
    bank at that tower's ready time."""

    name: str
    commands: tuple[Command, ...]
    towers: tuple[int, ...] | None = None  # None = every tower


@dataclasses.dataclass(frozen=True, eq=False)
class _Xfer:
    """Broadcast `polys` polynomials from each source tower's bank to
    every other reserved bank (same-bank destinations are free local
    row traffic)."""

    name: str
    src_towers: tuple[int, ...]
    polys: int


@dataclasses.dataclass(eq=False)
class HePlan:
    """Handler-owned artifact on `CompiledPlan.ext`: the segment
    schedule plus per-(banks, channel-pattern) simulation caches."""

    op: object
    basis: rns.RnsBasis
    segments: tuple
    banks: int
    rows: int
    _sim_cache: dict = dataclasses.field(default_factory=dict, repr=False)
    _trace_cache: dict = dataclasses.field(default_factory=dict, repr=False)


def _ntt(cfg: PimConfig, n: int, row: int, forward: bool) -> list[Command]:
    return RowCentricMapper(cfg, n, forward=forward, base_row=row).commands()


def _segments(cfg: PimConfig, op) -> tuple[tuple, int]:
    """(segments, per-bank row bound) for one op spec.

    Row layout is slot-based: polynomial slot k lives at rows
    [k*R, (k+1)*R).  Streams only need plausible row addresses (timing
    counts ACT/col/CU traffic; values replay functionally off-device).
    """
    n, big_l = op.n, op.towers
    rows_per_poly = max(1, n // cfg.row_words)

    def slot(k: int) -> int:
        return k * rows_per_poly

    def cat(*streams) -> tuple[Command, ...]:
        return tuple(c for s in streams for c in s)

    segs: list = []
    if isinstance(op, RlweCtMulOp):
        # slots: a0 a1 b0 b1 | cross d2 — d0/d1 reuse a0/cross in place
        slots = 6
        segs.append(_Compute("fwd", cat(
            *(_ntt(cfg, n, slot(k), True) for k in range(4)))))
        segs.append(_Compute("pointwise", cat(
            pointwise_commands(cfg, n, slot(0), slot(2)),   # d0 = a0.b0
            pointwise_commands(cfg, n, slot(4), slot(3)),   # cross = a0.b1
            pointwise_commands(cfg, n, slot(1), slot(3)),   # d2 = a1.b1
            pointwise_commands(cfg, n, slot(5), slot(2)),   # a1.b0
            scaling_commands(cfg, n, slot(4)),              # d1 accumulate
        )))
        segs.append(_Compute("inv", cat(
            _ntt(cfg, n, slot(0), False), scaling_commands(cfg, n, slot(0)),
            _ntt(cfg, n, slot(4), False), scaling_commands(cfg, n, slot(4)),
            _ntt(cfg, n, slot(1), False), scaling_commands(cfg, n, slot(1)),
        )))
    elif isinstance(op, KeySwitchOp):
        # slots: L digits | 2L resident key halves | 2 accumulators
        slots = 3 * big_l + 2
        segs.append(_Xfer("base_extend", tuple(range(big_l)), 1))
        segs.append(_Compute("digit_ntt", cat(
            *(_ntt(cfg, n, slot(j), True) for j in range(big_l)))))
        inner: list = []
        for j in range(big_l):
            inner += pointwise_commands(cfg, n, slot(j), slot(big_l + 2 * j))
            inner += pointwise_commands(cfg, n, slot(j), slot(big_l + 2 * j + 1))
            if j:  # accumulate into the two running sums
                inner += scaling_commands(cfg, n, slot(3 * big_l))
                inner += scaling_commands(cfg, n, slot(3 * big_l + 1))
        segs.append(_Compute("inner", tuple(inner)))
        segs.append(_Compute("inv", cat(
            _ntt(cfg, n, slot(3 * big_l), False),
            scaling_commands(cfg, n, slot(3 * big_l)),
            _ntt(cfg, n, slot(3 * big_l + 1), False),
            scaling_commands(cfg, n, slot(3 * big_l + 1)),
        )))
    elif isinstance(op, RescaleOp):
        # slots: c0 c1 | the dropped tower's two broadcast polys
        slots = 4
        segs.append(_Xfer("mod_down", (big_l - 1,), 2))
        survivors = tuple(range(big_l - 1))
        segs.append(_Compute("fold", cat(
            pointwise_commands(cfg, n, slot(0), slot(2)),  # c0 - last0
            scaling_commands(cfg, n, slot(0)),             # * q_last^-1
            pointwise_commands(cfg, n, slot(1), slot(3)),
            scaling_commands(cfg, n, slot(1)),
        ), towers=survivors))
    elif isinstance(op, CtMulRelinOp):
        # slots: a0 a1 b0 b1 cross d2 | L digits | 2L key | 2 accumulators
        slots = 6 + 3 * big_l + 2
        digit0, key0, acc0 = 6, 6 + big_l, 6 + 3 * big_l
        segs.append(_Compute("fwd", cat(
            *(_ntt(cfg, n, slot(k), True) for k in range(4)))))
        segs.append(_Compute("pointwise", cat(
            pointwise_commands(cfg, n, slot(0), slot(2)),
            pointwise_commands(cfg, n, slot(4), slot(3)),
            pointwise_commands(cfg, n, slot(1), slot(3)),
            pointwise_commands(cfg, n, slot(5), slot(2)),
            scaling_commands(cfg, n, slot(4)),
        )))
        segs.append(_Compute("inv_d2", cat(
            _ntt(cfg, n, slot(5), False), scaling_commands(cfg, n, slot(5)))))
        segs.append(_Xfer("base_extend", tuple(range(big_l)), 1))
        segs.append(_Compute("digit_ntt", cat(
            *(_ntt(cfg, n, slot(digit0 + j), True) for j in range(big_l)))))
        inner = []
        for j in range(big_l):
            inner += pointwise_commands(cfg, n, slot(digit0 + j),
                                        slot(key0 + 2 * j))
            inner += pointwise_commands(cfg, n, slot(digit0 + j),
                                        slot(key0 + 2 * j + 1))
            inner += scaling_commands(cfg, n, slot(acc0))      # accumulate /
            inner += scaling_commands(cfg, n, slot(acc0 + 1))  # add d0, d1
        segs.append(_Compute("inner", tuple(inner)))
        segs.append(_Compute("inv", cat(
            _ntt(cfg, n, slot(acc0), False),
            scaling_commands(cfg, n, slot(acc0)),
            _ntt(cfg, n, slot(acc0 + 1), False),
            scaling_commands(cfg, n, slot(acc0 + 1)),
        )))
    else:  # pragma: no cover - registry only routes HE_OPS here
        raise TypeError(f"not an HE op: {op!r}")
    return tuple(segs), slots * rows_per_poly


# --------------------------------------------------------------------------
# Simulation on the device engine
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _SimOutcome:
    latency_ns: float
    bank_counters: list          # aligned with the reserved flats
    bus_busy: dict               # channel -> busy ns
    dev_counters: dict           # xfer_atoms / xfer_hops
    phase_ns: dict               # segment name -> duration ns
    tower_done_ns: tuple         # per-tower completion
    param_hit_rate: float | None
    stats: StatsRegistry


def _tower_traces(cfg: PimConfig, hp: HePlan, banks: int):
    """Per-(tower, segment) parameter-cache residency traces, q-salted.

    One LRU per bank; the towers mapped to a bank walk it sequentially
    in tower order (the bank FIFO's coarse serialization).  Keys carry
    the tower's modulus so co-located towers never alias programs.
    Cached per bank count on the plan.  None when the cache is off.
    """
    if cfg.param_cache_entries <= 0:
        return None
    hit = hp._trace_cache.get(banks)
    if hit is not None:
        return hit
    entries, full = cfg.param_cache_entries, cfg.param_load_cycles
    hit_beats = param_hit_beats(cfg)
    big_l, n = hp.basis.towers, hp.basis.n
    traces: dict[tuple[int, int], tuple] = {}
    for b in range(min(banks, big_l)):
        lru: OrderedDict = OrderedDict()
        for t in range(b, big_l, banks):
            q = hp.basis.moduli[t]
            for si, seg in enumerate(hp.segments):
                if not isinstance(seg, _Compute):
                    continue
                if seg.towers is not None and t not in seg.towers:
                    continue
                out = []
                for cmd in seg.commands:
                    if cmd.__class__ not in PARAM_OPS:
                        continue
                    key = param_program_key(cfg, n, cmd)
                    if key is None:  # CMul: no reusable program
                        out.append((full, _P_MISS))
                    elif (q, key) in lru:
                        lru.move_to_end((q, key))
                        out.append((hit_beats, _P_HIT))
                    else:
                        lru[(q, key)] = True
                        if len(lru) > entries:
                            lru.popitem(last=False)
                        out.append((full, _P_MISS))
                traces[(t, si)] = tuple(out)
    hp._trace_cache[banks] = traces
    return traces


def _simulate(cfg: PimConfig, topo: DeviceTopology, policy: str,
              pipelined: bool, hp: HePlan, flats: Sequence[int],
              tracer: Tracer | None = None) -> _SimOutcome:
    """Run the segment schedule on a fresh `DeviceEngine`.

    Tower t executes on `flats[t % len(flats)]`; each segment gates on
    the tower's previous completion (phase barrier per tower), transfer
    segments route real `burst`s over the channel buses and gate every
    destination tower on its bank's last arrival.
    """
    basis = hp.basis
    big_l, n = basis.towers, basis.n
    banks = len(flats)
    bank_of = [flats[t % banks] for t in range(big_l)]
    dev = DeviceEngine(cfg, topo, policy=policy, pipelined=pipelined,
                       tracer=tracer)
    traces = _tower_traces(cfg, hp, banks)
    ready = [0.0] * big_l
    phase_ns: dict[str, float] = {}
    xfer_atoms = xfer_hops = 0
    for si, seg in enumerate(hp.segments):
        if isinstance(seg, _Compute):
            towers = seg.towers if seg.towers is not None else range(big_l)
            start = min(ready[t] for t in towers)
            for t in towers:
                dev.enqueue_flat(
                    bank_of[t], seg.commands, gate=ready[t], job_id=t,
                    param_trace=None if traces is None else traces[(t, si)])
            end = start
            for ev in dev.drain():
                ready[ev.job_id] = ev.done
                if ev.done > end:
                    end = ev.done
        else:
            start = min(ready[t] for t in seg.src_towers)
            atoms_per_poly = max(1, n // cfg.atom_words)
            atoms = seg.polys * atoms_per_poly
            arrive: dict[int, float] = {}
            for j in seg.src_towers:
                src = bank_of[j]
                ch_src = topo.channel_of(src)
                for dst in sorted(set(bank_of)):
                    if dst == src:
                        # local: the digit already lives in this bank's rows
                        arrive[dst] = max(arrive.get(dst, 0.0), ready[j])
                        continue
                    ch_dst = topo.channel_of(dst)
                    last = ready[j]
                    for _ in range(atoms):
                        last = dev.burst(ch_src, ch_dst, last)
                    xfer_atoms += atoms
                    if ch_src != ch_dst:
                        xfer_hops += atoms
                    arrive[dst] = max(arrive.get(dst, 0.0), last)
            end = start
            for t in range(big_l):
                t_arr = arrive.get(bank_of[t], 0.0)
                if t_arr > ready[t]:
                    ready[t] = t_arr
                if t_arr > end:
                    end = t_arr
        phase_ns[seg.name] = end - start
        if tracer is not None:
            tracer.phase("he", seg.name, start, end)
    latency = max(max(ready), dev.makespan_ns)
    stats = dev.stats()
    stats.add_device({"xfer_atoms": xfer_atoms, "xfer_hops": xfer_hops})
    stats.extend_span(latency)
    counters = []
    for f in flats:
        addr = topo.address_of(f)
        counters.append(stats.bank_counts(addr.channel, topo.local_id(addr)))
    bus_busy = {ch: stats.bus_busy_ns(ch) for ch in stats.channels()}
    return _SimOutcome(
        latency_ns=latency,
        bank_counters=counters,
        bus_busy=bus_busy,
        dev_counters={"xfer_atoms": xfer_atoms, "xfer_hops": xfer_hops},
        phase_ns=phase_ns,
        tower_done_ns=tuple(ready),
        param_hit_rate=stats.param_hit_rate() if traces is not None else None,
        stats=stats,
    )


def _sim_cached(cfg, topo, policy, pipelined, hp: HePlan,
                flats: Sequence[int]) -> _SimOutcome:
    """Channel-pattern-cached simulation (the gang resolver's cache
    discipline, shared with the session run path)."""
    key = (len(flats), tuple(topo.channel_of(f) for f in flats),
           policy, pipelined)
    hit = hp._sim_cache.get(key)
    if hit is None:
        hit = hp._sim_cache[key] = _simulate(
            cfg, topo, policy, pipelined, hp, flats)
    return hit


# --------------------------------------------------------------------------
# Timing result
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeTimingResult:
    """Timing of one HE ciphertext op on its reserved gang.

    `single_ns` is the one-bank run (every tower serialized on the
    first reserved bank, movement local) — the baseline `speedup` and
    `efficiency` (= speedup / banks) divide by.  `phase_ns` has one
    entry per plan segment (keyswitch includes `base_extend`);
    `tower_done_ns` the per-tower completion times.
    """

    towers: int
    banks: int
    latency_ns: float
    single_ns: float
    speedup: float
    efficiency: float
    phase_ns: Mapping[str, float]
    tower_done_ns: tuple[float, ...]
    xfer_atoms: int
    xfer_hops: int
    param_hit_rate: float | None

    @property
    def latency_us(self) -> float:
        return self.latency_ns / 1e3


# --------------------------------------------------------------------------
# The handler
# --------------------------------------------------------------------------


class HeOpHandler(OpHandler):
    """Session integration for the four HE ciphertext ops."""

    def canonical(self, op):
        if op.n < 1 or op.n & (op.n - 1):
            raise ValueError(f"n must be a power of two, got {op.n}")
        if op.towers < 1:
            raise ValueError("towers must be >= 1")
        if op.banks < 0:
            raise ValueError("banks must be >= 0 (0 = min(towers, device))")
        if isinstance(op, RescaleOp) and op.towers < 2:
            raise ValueError("rescale needs at least 2 towers")
        return op

    def compile(self, sess: PimSession, op) -> CompiledPlan:
        cfg = sess.cfg
        if op.n < cfg.atom_words:
            raise ValueError("n must be at least one atom")
        banks = op.banks or min(op.towers, sess.topo.total_banks)
        if banks > sess.topo.total_banks:
            raise ValueError(f"{op} wants {banks} banks; topology has "
                             f"{sess.topo.total_banks}")
        segments, rows = _segments(cfg, op)
        if rows > cfg.rows_per_bank:
            raise ValueError(f"{op} working set ({rows} rows) does not fit "
                             f"in one bank ({cfg.rows_per_bank} rows)")
        hp = HePlan(op=op, basis=basis_for(op), segments=segments,
                    banks=banks, rows=rows)
        phases = {seg.name: seg.commands for seg in segments
                  if isinstance(seg, _Compute)}
        return CompiledPlan(
            cfg=cfg, op=op, commands=(), phases=phases,
            placement={"towers": op.towers, "banks": banks, "rows": rows},
            ext=hp,
        )

    # -- functional dispatch -------------------------------------------------
    def _value(self, op, hp: HePlan, inputs):
        basis = hp.basis
        if isinstance(op, RlweCtMulOp):
            _require(inputs, 2, "RlweCtMulOp(ct_a, ct_b)")
            return rns.ct_mul(basis, inputs[0], inputs[1])
        if isinstance(op, KeySwitchOp):
            _require(inputs, 2, "KeySwitchOp(c2, ksk)")
            return rns.keyswitch(basis, inputs[0], _ksk(basis, inputs[1]))
        if isinstance(op, RescaleOp):
            _require(inputs, 1, "RescaleOp(ct)")
            return rns.rescale(basis, inputs[0])
        _require(inputs, 3, "CtMulRelinOp(ct_a, ct_b, ksk)")
        return rns.ct_mul_relin(basis, inputs[0], inputs[1],
                                _ksk(basis, inputs[2]))

    def run(self, sess: PimSession, plan: CompiledPlan, inputs, *,
            ctx=None, single=None, time=True, backend="engine") -> RunResult:
        if backend == "fastpath":
            raise ValueError(
                "backend='fastpath' does not support HE gang plans in a "
                "direct run: the base-extension phase needs the interpreted "
                "engine's bus model (ServicePolicy(backend='fastpath') "
                "serving replays the cached gang resolver and stays valid)")
        op, hp = plan.op, plan.ext
        value = self._value(op, hp, inputs) if inputs else None
        if not time:
            return RunResult(op=op, value=value, timing=None, stats=None,
                             trace=None)
        flats = list(range(hp.banks))
        tracer = sess._tracer()
        if tracer is None:
            out = _sim_cached(sess.cfg, sess.topo, sess.policy,
                              sess.pipelined, hp, flats)
        else:
            out = _simulate(sess.cfg, sess.topo, sess.policy,
                            sess.pipelined, hp, flats, tracer=tracer)
        base = _sim_cached(sess.cfg, sess.topo, sess.policy, sess.pipelined,
                           hp, [flats[0]])
        speedup = base.latency_ns / out.latency_ns
        timing = HeTimingResult(
            towers=op.towers,
            banks=hp.banks,
            latency_ns=out.latency_ns,
            single_ns=base.latency_ns,
            speedup=speedup,
            efficiency=speedup / hp.banks,
            phase_ns=dict(out.phase_ns),
            tower_done_ns=out.tower_done_ns,
            xfer_atoms=out.dev_counters["xfer_atoms"],
            xfer_hops=out.dev_counters["xfer_hops"],
            param_hit_rate=out.param_hit_rate,
        )
        tel = TelemetryHandle(tracer) if tracer is not None else None
        return RunResult(op=op, value=value, timing=timing, stats=out.stats,
                         trace=None, telemetry=tel)

    # -- service integration -------------------------------------------------
    def job(self, plan: CompiledPlan) -> GangJob:
        hp: HePlan = plan.ext
        return GangJob(op=plan.op, banks=hp.banks, rows=hp.rows)

    def prime(self, plan: CompiledPlan, sched: RequestScheduler) -> None:
        hp: HePlan = plan.ext

        def resolver(flats):
            out = _sim_cached(sched.cfg, sched.topo, sched.policy,
                              sched.pipelined, hp, flats)
            return (out.latency_ns, out.bank_counters, dict(out.bus_busy),
                    dict(out.dev_counters))

        sched.prime_gang(self.job(plan), resolver)


def _require(inputs, k: int, what: str) -> None:
    if len(inputs) != k:
        raise ValueError(f"{what} takes {k} input(s), got {len(inputs)}")


def _ksk(basis: rns.RnsBasis, ksk) -> rns.KeySwitchKey:
    if not isinstance(ksk, rns.KeySwitchKey):
        raise TypeError(f"expected a KeySwitchKey, got {type(ksk).__name__}")
    if ksk.basis is not basis:
        raise ValueError("keyswitch key was generated under a different basis")
    return ksk


_HANDLER = HeOpHandler()
for _cls in HE_OPS:
    register_op_handler(_cls, _HANDLER)
