"""Cycle-level timing model of one NTT-PIM bank (paper §VI: in-house
simulator = MC front-end + DRAMsim3-style bank timing).

The scheduler is **in-order issue, dependency-driven start** — the MC
issues commands in program order on the shared command bus, and each
command begins as soon as (a) the bus is free, (b) its hardware resources
(bank column path, CU, buffers) are free, and (c) its data dependencies
are met.  Pipelining (§V, Fig 6) *emerges* from buffer availability: with
Nb=2 the next butterfly's reads must wait for the previous writes (the
buffers are busy), while with Nb>=4 rotated buffer pairs let reads overlap
compute — exactly the paper's observation that "to overlap n executions
requires n times as many buffers".  `pipelined=False` forces strictly
serial execution (Fig 6a) for the ablation.

Clock-domain split (Fig 8 protocol): DRAM command/timing parameters are
fixed in ns (Table I cycles at 1200 MHz); CU compute latency scales with
the CU clock.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable

from repro.core.mapping import (
    Act,
    BUWord,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    WordLoad,
    WordStore,
)
from repro.core.pim_config import EnergyModel, PimConfig


@dataclasses.dataclass
class TimingResult:
    ns: float
    stats: dict
    phase_ns: dict

    @property
    def us(self) -> float:
        return self.ns / 1e3

    def cycles(self, cfg: PimConfig) -> float:
        return self.ns / cfg.dram_ns

    def energy_nj(self, model: EnergyModel | None = None) -> float:
        return (model or EnergyModel()).energy_nj(self.stats)


class BankTimer:
    def __init__(self, cfg: PimConfig, pipelined: bool = True):
        self.cfg = cfg
        self.pipelined = pipelined
        d = cfg.dram_ns
        c = cfg.cu_ns
        # latencies in ns
        self.t_bus = 1 * d
        self.t_ccd = cfg.tCCD * d
        self.t_cl = cfg.CL * d
        self.t_act = (cfg.tRP + cfg.tRCD) * d  # PRE + ACT to column-ready
        self.t_ras = cfg.tRAS * d
        self.t_wr = cfg.tWR * d
        self.t_c1 = cfg.c1_latency * c
        self.t_c2 = cfg.c2_latency * c
        self.t_c2_extra = cfg.atom_words * c  # per extra grouped atom pair
        self.t_buw = cfg.bu_word_latency * c
        self.t_param = cfg.param_load_cycles * d  # twiddle params on the bus

    def simulate(self, commands: Iterable[Command]) -> TimingResult:
        cfg = self.cfg
        nb = max(1, cfg.num_buffers)
        bus_t = 0.0
        col_t = 0.0  # column channel free
        cu_t = 0.0
        row_usable_t = 0.0
        act_start_ok = 0.0  # tRAS / tWR gating for the next activate
        open_row = None
        data_ready = [0.0] * nb  # buffer contents valid
        buf_free = [0.0] * nb  # last consumer done (WAR hazard)
        reg_ready = [0.0, 0.0]
        row_quiesce = 0.0  # last in-flight column transfer on the open row
        end_t = 0.0
        serial_barrier = 0.0
        stats: dict = defaultdict(int)
        phase_ns: dict = {}
        phase_name = "intra"
        phase_start = 0.0

        next_ref = cfg.tREFI_ns

        def begin(*deps: float) -> float:
            return max(bus_t, serial_barrier, *deps)

        def dram_begin(*deps: float) -> float:
            """begin() + periodic refresh stall (bank busy tRFC every tREFI)."""
            nonlocal next_ref
            s = begin(*deps)
            while s >= next_ref:
                stats["refresh"] += 1
                s = max(s, next_ref + cfg.tRFC_ns)
                next_ref += cfg.tREFI_ns
            return s

        for cmd in commands:
            if isinstance(cmd, Mark):
                phase_ns[phase_name] = phase_ns.get(phase_name, 0.0) + (end_t - phase_start)
                phase_name, phase_start = cmd.name, end_t
                continue

            if isinstance(cmd, Act):
                # PRE may not cut off in-flight transfers or write recovery.
                s = dram_begin(act_start_ok, row_quiesce)
                done = s + self.t_act
                open_row = cmd.row
                row_usable_t = done
                act_start_ok = s + self.t_ras
                stats["act"] += 1
            elif isinstance(cmd, ColRead):
                assert open_row == cmd.row
                s = dram_begin(col_t, row_usable_t, buf_free[cmd.buf])
                col_t = s + self.t_ccd
                done = s + self.t_cl + self.t_ccd
                data_ready[cmd.buf] = done
                row_quiesce = max(row_quiesce, done)
                stats["col_read"] += 1
            elif isinstance(cmd, ColWrite):
                assert open_row == cmd.row
                s = dram_begin(col_t, row_usable_t, data_ready[cmd.buf])
                col_t = s + self.t_ccd
                done = s + self.t_ccd
                buf_free[cmd.buf] = done
                act_start_ok = max(act_start_ok, done + self.t_wr)
                row_quiesce = max(row_quiesce, done)
                stats["col_write"] += 1
            elif isinstance(cmd, C1):
                # (w0, r_w) parameters stream over the shared bus first.
                s = begin(cu_t, data_ready[cmd.buf]) + self.t_param
                done = s + self.t_c1
                cu_t = done
                data_ready[cmd.buf] = done
                buf_free[cmd.buf] = done
                stats["c1"] += 1
                stats["bu_ops"] += (cfg.atom_words // 2) * (cmd.stages_hi - cmd.stages_lo)
            elif isinstance(cmd, C2):
                deps = [data_ready[b] for b in cmd.bufs_u + cmd.bufs_v]
                s = begin(cu_t, *deps) + self.t_param
                done = s + self.t_c2 + self.t_c2_extra * (len(cmd.bufs_u) - 1)
                cu_t = done
                for b in cmd.bufs_u + cmd.bufs_v:
                    data_ready[b] = done
                    buf_free[b] = done
                stats["c2"] += 1
                stats["bu_ops"] += cfg.atom_words * len(cmd.bufs_u)
            elif isinstance(cmd, CMul):
                s = begin(cu_t, data_ready[cmd.buf_u], data_ready[cmd.buf_v]) + self.t_param
                done = s + self.t_c2
                cu_t = done
                data_ready[cmd.buf_u] = done
                buf_free[cmd.buf_u] = done
                buf_free[cmd.buf_v] = done
                stats["cmul"] += 1
            elif isinstance(cmd, WordLoad):
                assert open_row == cmd.row
                s = dram_begin(col_t, row_usable_t, reg_ready[cmd.reg])
                col_t = s + self.t_ccd
                done = s + self.t_cl
                reg_ready[cmd.reg] = done
                row_quiesce = max(row_quiesce, done)
                stats["word_load"] += 1
            elif isinstance(cmd, WordStore):
                assert open_row == cmd.row
                s = dram_begin(col_t, row_usable_t, reg_ready[cmd.reg])
                col_t = s + self.t_ccd
                done = s + self.t_ccd
                act_start_ok = max(act_start_ok, done + self.t_wr)
                row_quiesce = max(row_quiesce, done)
                stats["word_store"] += 1
            elif isinstance(cmd, BUWord):
                s = begin(cu_t, reg_ready[0], reg_ready[1])
                done = s + self.t_buw
                cu_t = done
                reg_ready[0] = reg_ready[1] = done
                stats["bu_word"] += 1
                stats["bu_ops"] += 1
            else:  # pragma: no cover
                raise TypeError(cmd)

            bus_t = s + self.t_bus
            end_t = max(end_t, done)
            if not self.pipelined:
                serial_barrier = done

        phase_ns[phase_name] = phase_ns.get(phase_name, 0.0) + (end_t - phase_start)
        return TimingResult(ns=end_t, stats=dict(stats), phase_ns=phase_ns)


def simulate_ntt(
    n: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    pipelined: bool = True,
) -> TimingResult:
    """Map + time one size-n NTT on one bank (no functional execution)."""
    from repro.core.mapping import RowCentricMapper

    cfg = cfg or PimConfig()
    cmds = RowCentricMapper(cfg, n, forward=forward).commands()
    return BankTimer(cfg, pipelined=pipelined).simulate(cmds)


@dataclasses.dataclass
class MultiBankResult:
    banks: int
    latency_ns: float
    speedup: float
    efficiency: float
    bus_utilization: float


def simulate_multibank(n: int, banks: int, cfg: PimConfig | None = None) -> MultiBankResult:
    """Bank-level parallelism under SHARED command-bus contention.

    The paper (§VII) expects near-linear speedup from running independent
    NTTs on independent banks, leaving the system-level check as future
    work.  All banks in a channel share one command/address bus, and
    NTT-PIM additionally streams (w0, r_w) parameters over it per CU op
    (§IV-A), so the bus eventually serializes the banks:

        latency(k) >= max( single_bank_latency,
                           k * bus_cycles_one_bank * t_cycle )

    where bus_cycles_one_bank = #commands + param_load_cycles * #CU-ops.
    This lower-bound contention model is exact in the two asymptotes and
    conservative in between (no inter-bank reordering credit).
    """
    cfg = cfg or PimConfig()
    single = simulate_ntt(n, cfg)
    st = single.stats
    n_cmds = sum(
        st.get(k, 0)
        for k in ("act", "col_read", "col_write", "c1", "c2", "cmul",
                   "word_load", "word_store", "bu_word")
    )
    cu_ops = st.get("c1", 0) + st.get("c2", 0) + st.get("cmul", 0)
    bus_ns_one = (n_cmds + cfg.param_load_cycles * cu_ops) * cfg.dram_ns
    latency = max(single.ns, banks * bus_ns_one)
    speedup = banks * single.ns / latency
    return MultiBankResult(
        banks=banks,
        latency_ns=latency,
        speedup=speedup,
        efficiency=speedup / banks,
        bus_utilization=min(1.0, banks * bus_ns_one / latency),
    )
