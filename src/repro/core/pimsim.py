"""Cycle-level timing model of one NTT-PIM bank (paper §VI: in-house
simulator = MC front-end + DRAMsim3-style bank timing).

The scheduler is **in-order issue, dependency-driven start** — the MC
issues commands in program order on the shared command bus, and each
command begins as soon as (a) the bus is free, (b) its hardware resources
(bank column path, CU, buffers) are free, and (c) its data dependencies
are met.  Pipelining (§V, Fig 6) *emerges* from buffer availability: with
Nb=2 the next butterfly's reads must wait for the previous writes (the
buffers are busy), while with Nb>=4 rotated buffer pairs let reads overlap
compute — exactly the paper's observation that "to overlap n executions
requires n times as many buffers".  `pipelined=False` forces strictly
serial execution (Fig 6a) for the ablation.

Clock-domain split (Fig 8 protocol): DRAM command/timing parameters are
fixed in ns (Table I cycles at 1200 MHz); CU compute latency scales with
the CU clock.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable

from repro.core.mapping import (
    Act,
    BUWord,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    WordLoad,
    WordStore,
)
from repro.core.pim_config import EnergyModel, PimConfig


@dataclasses.dataclass
class TimingResult:
    ns: float
    stats: dict
    phase_ns: dict

    @property
    def us(self) -> float:
        return self.ns / 1e3

    def cycles(self, cfg: PimConfig) -> float:
        return self.ns / cfg.dram_ns

    def energy_nj(self, model: EnergyModel | None = None) -> float:
        return (model or EnergyModel()).energy_nj(self.stats)


class BankEngine:
    """Per-bank resource/hazard tracker (the inner state machine of
    `BankTimer`), factored out so `repro.pimsys.controller` can multiplex
    MANY banks onto one shared command/address bus while reusing exactly
    this timing model.  The bus itself is *external* state: callers pass
    the bus-grant time into :meth:`issue` and own `bus_free = s + t_bus`
    bookkeeping, which is what makes single-bank results bit-identical
    between `BankTimer` and a one-bank channel controller.
    """

    def __init__(self, cfg: PimConfig, pipelined: bool = True):
        self.cfg = cfg
        self.pipelined = pipelined
        d = cfg.dram_ns
        c = cfg.cu_ns
        # latencies in ns
        self.t_bus = 1 * d
        self.t_ccd = cfg.tCCD * d
        self.t_cl = cfg.CL * d
        self.t_act = (cfg.tRP + cfg.tRCD) * d  # PRE + ACT to column-ready
        self.t_ras = cfg.tRAS * d
        self.t_wr = cfg.tWR * d
        self.t_c1 = cfg.c1_latency * c
        self.t_c2 = cfg.c2_latency * c
        self.t_c2_extra = cfg.atom_words * c  # per extra grouped atom pair
        self.t_buw = cfg.bu_word_latency * c
        self.t_param = cfg.param_load_cycles * d  # twiddle params on the bus

        nb = max(1, cfg.num_buffers)
        self.col_t = 0.0  # column channel free
        self.cu_t = 0.0
        self.row_usable_t = 0.0
        self.act_start_ok = 0.0  # tRAS / tWR gating for the next activate
        self.open_row: int | None = None
        self.data_ready = [0.0] * nb  # buffer contents valid
        self.buf_free = [0.0] * nb  # last consumer done (WAR hazard)
        self.reg_ready = [0.0, 0.0]
        self.row_quiesce = 0.0  # last in-flight column transfer on the open row
        self.end_t = 0.0
        self.serial_barrier = 0.0
        self.next_ref = cfg.tREFI_ns
        self.stats: dict = defaultdict(int)

    # -- arbitration support -------------------------------------------------
    def bus_hold(self, cmd: Command) -> float:
        """Bus occupancy of `cmd`: 1 command cycle, plus the (w0, r_w)
        parameter stream for CU ops (§IV-A)."""
        if isinstance(cmd, (C1, C2, CMul)):
            return self.t_param + self.t_bus
        return self.t_bus

    def earliest_start(self, cmd: Command, bus_free: float) -> float:
        """The start time :meth:`issue` would produce, without mutating —
        used by the ready-first arbiter to rank competing banks."""
        return self._start(cmd, bus_free, commit=False)

    def _start(self, cmd: Command, bus_free: float, commit: bool) -> float:
        """Start time of `cmd`: dependencies, refresh stall, param stream.

        The single source of truth for WHEN a command begins; `_commit`
        holds the per-type state updates for what it then does.
        """
        deps, is_dram, is_param = self._classify(cmd)
        s = max(bus_free, self.serial_barrier, *deps)
        if is_dram:
            # periodic refresh stall (bank busy tRFC every tREFI)
            next_ref = self.next_ref
            while s >= next_ref:
                if commit:
                    self.stats["refresh"] += 1
                s = max(s, next_ref + self.cfg.tRFC_ns)
                next_ref += self.cfg.tREFI_ns
            if commit:
                self.next_ref = next_ref
        if is_param:
            s += self.t_param  # (w0, r_w) stream over the shared bus first
        return s

    def _classify(self, cmd: Command) -> tuple[list[float], bool, bool]:
        """(dependency times, uses DRAM refresh gating, is CU param op)."""
        if isinstance(cmd, Act):
            # PRE may not cut off in-flight transfers or write recovery.
            return [self.act_start_ok, self.row_quiesce], True, False
        if isinstance(cmd, ColRead):
            return [self.col_t, self.row_usable_t, self.buf_free[cmd.buf]], True, False
        if isinstance(cmd, ColWrite):
            return [self.col_t, self.row_usable_t, self.data_ready[cmd.buf]], True, False
        if isinstance(cmd, C1):
            return [self.cu_t, self.data_ready[cmd.buf]], False, True
        if isinstance(cmd, C2):
            return [self.cu_t] + [self.data_ready[b] for b in cmd.bufs_u + cmd.bufs_v], False, True
        if isinstance(cmd, CMul):
            return [self.cu_t, self.data_ready[cmd.buf_u], self.data_ready[cmd.buf_v]], False, True
        if isinstance(cmd, (WordLoad, WordStore)):
            return [self.col_t, self.row_usable_t, self.reg_ready[cmd.reg]], True, False
        if isinstance(cmd, BUWord):
            return [self.cu_t, self.reg_ready[0], self.reg_ready[1]], False, False
        raise TypeError(cmd)

    # -- issue ---------------------------------------------------------------
    def issue(self, cmd: Command, bus_free: float) -> tuple[float, float]:
        """Issue one command once the bus grants at `bus_free`.

        Returns `(s, done)`; the caller must advance the shared bus to
        `s + t_bus` (the command occupies the bus until then — for CU ops
        `s` already includes the t_param parameter stream).
        """
        s = self._start(cmd, bus_free, commit=True)
        done = self._commit(cmd, s)
        self.end_t = max(self.end_t, done)
        if not self.pipelined:
            self.serial_barrier = done
        return s, done

    def _commit(self, cmd: Command, s: float) -> float:
        """Apply `cmd`'s state updates given its start time; return done."""
        cfg = self.cfg
        if isinstance(cmd, Act):
            done = s + self.t_act
            self.open_row = cmd.row
            self.row_usable_t = done
            self.act_start_ok = s + self.t_ras
            self.stats["act"] += 1
        elif isinstance(cmd, ColRead):
            assert self.open_row == cmd.row
            self.col_t = s + self.t_ccd
            done = s + self.t_cl + self.t_ccd
            self.data_ready[cmd.buf] = done
            self.row_quiesce = max(self.row_quiesce, done)
            self.stats["col_read"] += 1
        elif isinstance(cmd, ColWrite):
            assert self.open_row == cmd.row
            self.col_t = s + self.t_ccd
            done = s + self.t_ccd
            self.buf_free[cmd.buf] = done
            self.act_start_ok = max(self.act_start_ok, done + self.t_wr)
            self.row_quiesce = max(self.row_quiesce, done)
            self.stats["col_write"] += 1
        elif isinstance(cmd, C1):
            done = s + self.t_c1
            self.cu_t = done
            self.data_ready[cmd.buf] = done
            self.buf_free[cmd.buf] = done
            self.stats["c1"] += 1
            self.stats["bu_ops"] += (cfg.atom_words // 2) * (cmd.stages_hi - cmd.stages_lo)
        elif isinstance(cmd, C2):
            done = s + self.t_c2 + self.t_c2_extra * (len(cmd.bufs_u) - 1)
            self.cu_t = done
            for b in cmd.bufs_u + cmd.bufs_v:
                self.data_ready[b] = done
                self.buf_free[b] = done
            self.stats["c2"] += 1
            self.stats["bu_ops"] += cfg.atom_words * len(cmd.bufs_u)
        elif isinstance(cmd, CMul):
            done = s + self.t_c2
            self.cu_t = done
            self.data_ready[cmd.buf_u] = done
            self.buf_free[cmd.buf_u] = done
            self.buf_free[cmd.buf_v] = done
            self.stats["cmul"] += 1
        elif isinstance(cmd, WordLoad):
            assert self.open_row == cmd.row
            self.col_t = s + self.t_ccd
            done = s + self.t_cl
            self.reg_ready[cmd.reg] = done
            self.row_quiesce = max(self.row_quiesce, done)
            self.stats["word_load"] += 1
        elif isinstance(cmd, WordStore):
            assert self.open_row == cmd.row
            self.col_t = s + self.t_ccd
            done = s + self.t_ccd
            self.act_start_ok = max(self.act_start_ok, done + self.t_wr)
            self.row_quiesce = max(self.row_quiesce, done)
            self.stats["word_store"] += 1
        elif isinstance(cmd, BUWord):
            done = s + self.t_buw
            self.cu_t = done
            self.reg_ready[0] = self.reg_ready[1] = done
            self.stats["bu_word"] += 1
            self.stats["bu_ops"] += 1
        else:  # pragma: no cover
            raise TypeError(cmd)
        return done


class BankTimer:
    def __init__(self, cfg: PimConfig, pipelined: bool = True):
        self.cfg = cfg
        self.pipelined = pipelined

    def simulate(self, commands: Iterable[Command]) -> TimingResult:
        eng = BankEngine(self.cfg, pipelined=self.pipelined)
        bus_t = 0.0
        phase_ns: dict = {}
        phase_name = "intra"
        phase_start = 0.0

        for cmd in commands:
            if isinstance(cmd, Mark):
                phase_ns[phase_name] = phase_ns.get(phase_name, 0.0) + (eng.end_t - phase_start)
                phase_name, phase_start = cmd.name, eng.end_t
                continue
            s, _ = eng.issue(cmd, bus_t)
            bus_t = s + eng.t_bus

        phase_ns[phase_name] = phase_ns.get(phase_name, 0.0) + (eng.end_t - phase_start)
        return TimingResult(ns=eng.end_t, stats=dict(eng.stats), phase_ns=phase_ns)


def _time_ntt(
    n: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    pipelined: bool = True,
) -> TimingResult:
    """Map + time one size-n NTT on one bank (no functional execution).

    Internal, warning-free baseline used by the analytic bound and the
    sharded plan; external callers go through `simulate_ntt` (a session
    shim) or `PimSession` directly.
    """
    from repro.core.mapping import RowCentricMapper

    cfg = cfg or PimConfig()
    cmds = RowCentricMapper(cfg, n, forward=forward).commands()
    return BankTimer(cfg, pipelined=pipelined).simulate(cmds)


def simulate_ntt(
    n: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    pipelined: bool = True,
) -> TimingResult:
    """Map + time one size-n NTT on one bank (no functional execution).

    Legacy shim over `repro.pimsys.session.PimSession` — bit-identical
    to the session path by construction (same mapper stream, same
    `BankTimer`).
    """
    from repro.pimsys.session import NttOp, PimSession, warn_legacy

    warn_legacy("simulate_ntt", "run(compile(NttOp(n)))")
    sess = PimSession(cfg, pipelined=pipelined)
    return sess.run(sess.compile(NttOp(n, forward=forward))).timing


@dataclasses.dataclass
class MultiBankResult:
    banks: int
    latency_ns: float
    speedup: float
    efficiency: float
    bus_utilization: float
    analytic_latency_ns: float = 0.0  # lower-bound cross-check (see below)
    policy: str = "rr"


def analytic_multibank_bound(
    n: int, banks: int, cfg: PimConfig | None = None, single: TimingResult | None = None
) -> float:
    """Analytic LOWER bound on k-bank latency under shared-bus contention.

    All banks in a channel share one command/address bus, and NTT-PIM
    additionally streams (w0, r_w) parameters over it per CU op (§IV-A),
    so the bus eventually serializes the banks:

        latency(k) >= max( single_bank_latency,
                           k * bus_cycles_one_bank * t_cycle )

    where bus_cycles_one_bank = #commands + param_load_cycles * #CU-ops.
    Exact in the two asymptotes, conservative in between (no hazard
    stalls charged to the bus); the cycle-level controller in
    `repro.pimsys` can therefore never beat it.
    """
    cfg = cfg or PimConfig()
    single = single or _time_ntt(n, cfg)
    st = single.stats
    n_cmds = sum(
        st.get(k, 0)
        for k in ("act", "col_read", "col_write", "c1", "c2", "cmul",
                   "word_load", "word_store", "bu_word")
    )
    cu_ops = st.get("c1", 0) + st.get("c2", 0) + st.get("cmul", 0)
    bus_ns_one = (n_cmds + cfg.param_load_cycles * cu_ops) * cfg.dram_ns
    return max(single.ns, banks * bus_ns_one)


def simulate_ntt_sharded(
    n: int,
    banks: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    policy: str = "rr",
    topo=None,
    single: TimingResult | None = None,
):
    """Time ONE size-n NTT sharded over `banks` banks (four-step split).

    Unlike `simulate_multibank` (independent NTTs, one per bank), this
    decomposes a single transform: per-bank N/banks-point local passes
    plus log2(banks) cross-bank exchange stages over the per-channel
    shared buses.  Returns a `ShardedTimingResult`.  Pass `single` (the
    one-bank `simulate_ntt(n, cfg, forward)` result) when sweeping over
    `banks` to avoid re-simulating the baseline each call.

    Legacy shim over `repro.pimsys.session.PimSession`.
    """
    from repro.pimsys.session import PimSession, ShardedNttOp, warn_legacy

    warn_legacy("simulate_ntt_sharded", "run(compile(ShardedNttOp(n, banks)))")
    sess = PimSession(cfg, topo=topo, policy=policy)
    plan = sess.compile(ShardedNttOp(n, banks, forward=forward))
    return sess.run(plan, single=single).timing


def simulate_multibank(
    n: int,
    banks: int,
    cfg: PimConfig | None = None,
    policy: str = "rr",
    single: TimingResult | None = None,
) -> MultiBankResult:
    """Bank-level parallelism under SHARED command-bus contention.

    The paper (§VII) expects near-linear speedup from running independent
    NTTs on independent banks, leaving the system-level check as future
    work.  This runs `banks` identical size-n NTT command streams through
    the cycle-level channel controller (`repro.pimsys.controller`) — one
    shared bus, per-bank `BankEngine` hazard tracking — and cross-checks
    the result against `analytic_multibank_bound` (the controller must
    never report a latency below the bound).  Pass `single` (the one-bank
    `simulate_ntt(n, cfg)` result) when sweeping over `banks` to avoid
    re-simulating the baseline each call.

    Legacy shim over `repro.pimsys.session.PimSession`."""
    from repro.pimsys.session import BatchOp, NttOp, PimSession, warn_legacy

    warn_legacy("simulate_multibank", "run(compile(BatchOp(NttOp(n), banks)))")
    sess = PimSession(cfg, policy=policy)
    plan = sess.compile(BatchOp(NttOp(n), banks))
    return sess.run(plan, single=single).timing
