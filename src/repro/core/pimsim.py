"""Cycle-level timing model of one NTT-PIM bank (paper §VI: in-house
simulator = MC front-end + DRAMsim3-style bank timing).

The scheduler is **in-order issue, dependency-driven start** — the MC
issues commands in program order on the shared command bus, and each
command begins as soon as (a) the bus is free, (b) its hardware resources
(bank column path, CU, buffers) are free, and (c) its data dependencies
are met.  Pipelining (§V, Fig 6) *emerges* from buffer availability: with
Nb=2 the next butterfly's reads must wait for the previous writes (the
buffers are busy), while with Nb>=4 rotated buffer pairs let reads overlap
compute — exactly the paper's observation that "to overlap n executions
requires n times as many buffers".  `pipelined=False` forces strictly
serial execution (Fig 6a) for the ablation.

Clock-domain split (Fig 8 protocol): DRAM command/timing parameters are
fixed in ns (Table I cycles at 1200 MHz); CU compute latency scales with
the CU clock.

`BankEngine` is the BANK layer of the hierarchical resource engine
(`repro.pimsys.engine`): pure per-bank hazards — column path, CU,
buffers, refresh.  Everything above the bank is external state owned by
the issue path: the shared bus (callers pass the grant time and keep
`bus_free = s + t_bus`), rank-level tFAW/turnaround windows
(`engine.RankState`), and the per-CU-op (w0, r_w) parameter-beat charge
(`param_ns`, resolved by the caller from `PimConfig.param_cache_entries`
via `engine.param_beat_trace`; `None` charges the flat seed-model
`param_load_cycles`).  That layering is what makes a one-bank channel
bit-identical to `BankTimer` by construction.

The per-command-class dispatch tables (`_ISSUE`/`_START`) replace the
seed's isinstance chains; see `benchmarks/engine_speed.py` for the
commands/s microbenchmark that guards the hot loop.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable, Sequence

from repro.core.mapping import (
    Act,
    BUWord,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    WordLoad,
    WordStore,
)
from repro.core.pim_config import EnergyModel, PimConfig

#: CU ops that stream a (w0, r_w) parameter program over the shared bus
#: per issue (§IV-A) — the traffic `PimConfig.param_cache_entries` cuts.
#: (`BUWord` rides the Nb=1 word path and never charged parameter beats.)
PARAM_OPS = frozenset({C1, C2, CMul})


@dataclasses.dataclass
class TimingResult:
    ns: float
    stats: dict
    phase_ns: dict

    @property
    def us(self) -> float:
        return self.ns / 1e3

    def cycles(self, cfg: PimConfig) -> float:
        return self.ns / cfg.dram_ns

    def energy_nj(self, model: EnergyModel | None = None) -> float:
        return (model or EnergyModel()).energy_nj(self.stats)


class BankEngine:
    """Per-bank resource/hazard tracker: the innermost layer of the
    hierarchical issue path (`repro.pimsys.engine`), also driven
    directly by `BankTimer` for the paper's single-bank experiments.

    The bus is *external* state: callers pass the bus-grant time into
    :meth:`issue` and own `bus_free = s + t_bus` bookkeeping; likewise
    the parameter-beat charge `param_ns` is resolved by the caller
    (flat `param_load_cycles` when `None` — the seed model).  Start
    semantics per command: `s = max(grant, serial_barrier, deps...)`,
    then the refresh stall window for DRAM ops, then `+ param_ns` for
    CU ops (the (w0, r_w) stream crosses the bus before the command
    proper).
    """

    __slots__ = (
        "cfg", "pipelined", "t_bus", "t_ccd", "t_cl", "t_act", "t_ras",
        "t_wr", "t_c1", "t_c2", "t_c2_extra", "t_buw", "t_param",
        "col_t", "cu_t", "row_usable_t", "act_start_ok", "open_row",
        "data_ready", "buf_free", "reg_ready", "row_quiesce", "end_t",
        "serial_barrier", "next_ref", "stats", "_trefi", "_trfc",
        "_c1_bu", "_c2_bu",
    )

    def __init__(self, cfg: PimConfig, pipelined: bool = True):
        self.cfg = cfg
        self.pipelined = pipelined
        d = cfg.dram_ns
        c = cfg.cu_ns
        # latencies in ns
        self.t_bus = 1 * d
        self.t_ccd = cfg.tCCD * d
        self.t_cl = cfg.CL * d
        self.t_act = (cfg.tRP + cfg.tRCD) * d  # PRE + ACT to column-ready
        self.t_ras = cfg.tRAS * d
        self.t_wr = cfg.tWR * d
        self.t_c1 = cfg.c1_latency * c
        self.t_c2 = cfg.c2_latency * c
        self.t_c2_extra = cfg.atom_words * c  # per extra grouped atom pair
        self.t_buw = cfg.bu_word_latency * c
        self.t_param = cfg.param_load_cycles * d  # twiddle params on the bus

        nb = max(1, cfg.num_buffers)
        self.col_t = 0.0  # column channel free
        self.cu_t = 0.0
        self.row_usable_t = 0.0
        self.act_start_ok = 0.0  # tRAS / tWR gating for the next activate
        self.open_row: int | None = None
        self.data_ready = [0.0] * nb  # buffer contents valid
        self.buf_free = [0.0] * nb  # last consumer done (WAR hazard)
        self.reg_ready = [0.0, 0.0]
        self.row_quiesce = 0.0  # last in-flight column transfer on the open row
        self.end_t = 0.0
        self.serial_barrier = 0.0
        self.next_ref = cfg.tREFI_ns
        self.stats: dict = defaultdict(int)
        self._trefi = cfg.tREFI_ns
        self._trfc = cfg.tRFC_ns
        self._c1_bu = cfg.atom_words // 2
        self._c2_bu = cfg.atom_words

    # -- arbitration support -------------------------------------------------
    def earliest_start(self, cmd: Command, bus_free: float,
                       param_ns: float | None = None) -> float:
        """The start time :meth:`issue` would produce, without mutating —
        used by the ready-first arbiter to rank competing banks."""
        if param_ns is None:
            param_ns = self.t_param if cmd.__class__ in PARAM_OPS else 0.0
        return self._START[cmd.__class__](self, cmd, bus_free, param_ns)

    # -- refresh -------------------------------------------------------------
    def _refresh(self, s: float) -> float:
        """Periodic refresh stall (bank busy tRFC every tREFI), committed."""
        nr = self.next_ref
        trfc, trefi = self._trfc, self._trefi
        stats = self.stats
        while s >= nr:
            stats["refresh"] += 1
            r = nr + trfc
            if r > s:
                s = r
            nr += trefi
        self.next_ref = nr
        return s

    def _refresh_peek(self, s: float) -> float:
        nr = self.next_ref
        trfc, trefi = self._trfc, self._trefi
        while s >= nr:
            r = nr + trfc
            if r > s:
                s = r
            nr += trefi
        return s

    # -- issue ---------------------------------------------------------------
    def issue(self, cmd: Command, bus_free: float,
              param_ns: float | None = None) -> tuple[float, float]:
        """Issue one command once the bus grants at `bus_free`.

        Returns `(s, done)`; the caller must advance the shared bus to
        `s + t_bus` (the command occupies the bus until then — for CU
        ops `s` already includes the `param_ns` parameter stream).
        """
        if param_ns is None:
            param_ns = self.t_param if cmd.__class__ in PARAM_OPS else 0.0
        s, done = self._ISSUE[cmd.__class__](self, cmd, bus_free, param_ns)
        if done > self.end_t:
            self.end_t = done
        if not self.pipelined:
            self.serial_barrier = done
        return s, done

    # -- per-command-class handlers (issue: start + commit fused) ------------
    def _i_act(self, cmd, s, _pn):
        b = self.serial_barrier
        if b > s:
            s = b
        a = self.act_start_ok
        if a > s:
            s = a
        q = self.row_quiesce
        if q > s:
            s = q
        if s >= self.next_ref:
            s = self._refresh(s)
        done = s + self.t_act
        self.open_row = cmd.row
        self.row_usable_t = done
        self.act_start_ok = s + self.t_ras
        self.stats["act"] += 1
        return s, done

    def _i_col_read(self, cmd, s, _pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.col_t
        if c > s:
            s = c
        r = self.row_usable_t
        if r > s:
            s = r
        f = self.buf_free[cmd.buf]
        if f > s:
            s = f
        if s >= self.next_ref:
            s = self._refresh(s)
        assert self.open_row == cmd.row
        self.col_t = s + self.t_ccd
        done = s + self.t_cl + self.t_ccd
        self.data_ready[cmd.buf] = done
        if done > self.row_quiesce:
            self.row_quiesce = done
        self.stats["col_read"] += 1
        return s, done

    def _i_col_write(self, cmd, s, _pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.col_t
        if c > s:
            s = c
        r = self.row_usable_t
        if r > s:
            s = r
        d = self.data_ready[cmd.buf]
        if d > s:
            s = d
        if s >= self.next_ref:
            s = self._refresh(s)
        assert self.open_row == cmd.row
        self.col_t = s + self.t_ccd
        done = s + self.t_ccd
        self.buf_free[cmd.buf] = done
        wr = done + self.t_wr
        if wr > self.act_start_ok:
            self.act_start_ok = wr
        if done > self.row_quiesce:
            self.row_quiesce = done
        self.stats["col_write"] += 1
        return s, done

    def _i_c1(self, cmd, s, pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.cu_t
        if c > s:
            s = c
        d = self.data_ready[cmd.buf]
        if d > s:
            s = d
        s += pn  # (w0, r_w) stream over the shared bus first
        done = s + self.t_c1
        self.cu_t = done
        self.data_ready[cmd.buf] = done
        self.buf_free[cmd.buf] = done
        stats = self.stats
        stats["c1"] += 1
        stats["bu_ops"] += self._c1_bu * (cmd.stages_hi - cmd.stages_lo)
        return s, done

    def _i_c2(self, cmd, s, pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.cu_t
        if c > s:
            s = c
        data_ready = self.data_ready
        bufs_u = cmd.bufs_u
        for bb in bufs_u:
            d = data_ready[bb]
            if d > s:
                s = d
        for bb in cmd.bufs_v:
            d = data_ready[bb]
            if d > s:
                s = d
        s += pn
        done = s + self.t_c2 + self.t_c2_extra * (len(bufs_u) - 1)
        self.cu_t = done
        buf_free = self.buf_free
        for bb in bufs_u:
            data_ready[bb] = done
            buf_free[bb] = done
        for bb in cmd.bufs_v:
            data_ready[bb] = done
            buf_free[bb] = done
        stats = self.stats
        stats["c2"] += 1
        stats["bu_ops"] += self._c2_bu * len(bufs_u)
        return s, done

    def _i_cmul(self, cmd, s, pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.cu_t
        if c > s:
            s = c
        d = self.data_ready[cmd.buf_u]
        if d > s:
            s = d
        d = self.data_ready[cmd.buf_v]
        if d > s:
            s = d
        s += pn
        done = s + self.t_c2
        self.cu_t = done
        self.data_ready[cmd.buf_u] = done
        self.buf_free[cmd.buf_u] = done
        self.buf_free[cmd.buf_v] = done
        self.stats["cmul"] += 1
        return s, done

    def _i_word_load(self, cmd, s, _pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.col_t
        if c > s:
            s = c
        r = self.row_usable_t
        if r > s:
            s = r
        g = self.reg_ready[cmd.reg]
        if g > s:
            s = g
        if s >= self.next_ref:
            s = self._refresh(s)
        assert self.open_row == cmd.row
        self.col_t = s + self.t_ccd
        done = s + self.t_cl
        self.reg_ready[cmd.reg] = done
        if done > self.row_quiesce:
            self.row_quiesce = done
        self.stats["word_load"] += 1
        return s, done

    def _i_word_store(self, cmd, s, _pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.col_t
        if c > s:
            s = c
        r = self.row_usable_t
        if r > s:
            s = r
        g = self.reg_ready[cmd.reg]
        if g > s:
            s = g
        if s >= self.next_ref:
            s = self._refresh(s)
        assert self.open_row == cmd.row
        self.col_t = s + self.t_ccd
        done = s + self.t_ccd
        wr = done + self.t_wr
        if wr > self.act_start_ok:
            self.act_start_ok = wr
        if done > self.row_quiesce:
            self.row_quiesce = done
        self.stats["word_store"] += 1
        return s, done

    def _i_bu_word(self, cmd, s, _pn):
        b = self.serial_barrier
        if b > s:
            s = b
        c = self.cu_t
        if c > s:
            s = c
        r = self.reg_ready
        if r[0] > s:
            s = r[0]
        if r[1] > s:
            s = r[1]
        done = s + self.t_buw
        self.cu_t = done
        r[0] = r[1] = done
        stats = self.stats
        stats["bu_word"] += 1
        stats["bu_ops"] += 1
        return s, done

    # -- per-command-class start-only handlers (no mutation) -----------------
    def _s_act(self, cmd, s, _pn):
        return self._refresh_peek(max(s, self.serial_barrier,
                                      self.act_start_ok, self.row_quiesce))

    def _s_col_read(self, cmd, s, _pn):
        return self._refresh_peek(max(s, self.serial_barrier, self.col_t,
                                      self.row_usable_t,
                                      self.buf_free[cmd.buf]))

    def _s_col_write(self, cmd, s, _pn):
        return self._refresh_peek(max(s, self.serial_barrier, self.col_t,
                                      self.row_usable_t,
                                      self.data_ready[cmd.buf]))

    def _s_c1(self, cmd, s, pn):
        return max(s, self.serial_barrier, self.cu_t,
                   self.data_ready[cmd.buf]) + pn

    def _s_c2(self, cmd, s, pn):
        data_ready = self.data_ready
        return max(s, self.serial_barrier, self.cu_t,
                   *(data_ready[b] for b in cmd.bufs_u),
                   *(data_ready[b] for b in cmd.bufs_v)) + pn

    def _s_cmul(self, cmd, s, pn):
        return max(s, self.serial_barrier, self.cu_t,
                   self.data_ready[cmd.buf_u],
                   self.data_ready[cmd.buf_v]) + pn

    def _s_word(self, cmd, s, _pn):
        return self._refresh_peek(max(s, self.serial_barrier, self.col_t,
                                      self.row_usable_t,
                                      self.reg_ready[cmd.reg]))

    def _s_bu_word(self, cmd, s, _pn):
        return max(s, self.serial_barrier, self.cu_t,
                   self.reg_ready[0], self.reg_ready[1])

    _ISSUE = {
        Act: _i_act,
        ColRead: _i_col_read,
        ColWrite: _i_col_write,
        C1: _i_c1,
        C2: _i_c2,
        CMul: _i_cmul,
        WordLoad: _i_word_load,
        WordStore: _i_word_store,
        BUWord: _i_bu_word,
    }
    _START = {
        Act: _s_act,
        ColRead: _s_col_read,
        ColWrite: _s_col_write,
        C1: _s_c1,
        C2: _s_c2,
        CMul: _s_cmul,
        WordLoad: _s_word,
        WordStore: _s_word,
        BUWord: _s_bu_word,
    }


class BankTimer:
    """One bank, private bus, program order — the paper's §VI simulator.

    A thin driver of `BankEngine`: the loop owns the bus cursor
    (`bus_t = s + t_bus`) and resolves each CU op's parameter-beat
    charge from `param_trace` (a `pimsys.engine.param_beat_trace`
    residency trace; `None` = flat `param_load_cycles`, the seed
    model).  `Mark`s delimit the per-phase breakdown.
    """

    def __init__(self, cfg: PimConfig, pipelined: bool = True):
        self.cfg = cfg
        self.pipelined = pipelined

    def simulate(self, commands: Iterable[Command],
                 param_trace: Sequence[tuple[int, int]] | None = None,
                 tracer=None) -> TimingResult:
        """Time one command stream.  `tracer` (a
        `repro.pimsys.telemetry.Tracer`, duck-typed so core stays free
        of pimsys imports) records per-command issue events on the
        (0, 0) track and each Mark-delimited phase as a span; `None`
        (default) records nothing and adds no per-command work beyond
        one `is not None` test."""
        eng = BankEngine(self.cfg, pipelined=self.pipelined)
        issue = eng.issue
        t_bus = eng.t_bus
        t_param = eng.t_param
        dram_ns = self.cfg.dram_ns
        stats = eng.stats
        it = iter(param_trace) if param_trace is not None else None
        bus_t = 0.0
        phase_ns: dict = {}
        phase_name = "intra"
        phase_start = 0.0
        if tracer is not None:
            tracer.meta.setdefault("dram_ns", dram_ns)
            trace_cmds = tracer.commands
        else:
            trace_cmds = None

        for cmd in commands:
            cls = cmd.__class__
            if cls is Mark:
                phase_ns[phase_name] = phase_ns.get(phase_name, 0.0) + (eng.end_t - phase_start)
                if tracer is not None:
                    tracer.phases.append(("bank", phase_name, phase_start, eng.end_t))
                phase_name, phase_start = cmd.name, eng.end_t
                continue
            if cls in PARAM_OPS:
                if it is None:
                    pn = t_param
                    code = 0
                else:
                    try:
                        beats, code = next(it)
                    except StopIteration:
                        raise ValueError(
                            "param_trace shorter than the stream's CU ops"
                        ) from None
                    pn = beats * dram_ns
                    stats["param_hit" if code == 2 else "param_miss"] += 1
            else:
                pn = 0.0
                code = 0
            s, done = issue(cmd, bus_t, pn)
            if trace_cmds is not None:
                # single bank, private bus: gate == grant == bus cursor
                trace_cmds.append((0, 0, cls.__name__, bus_t, bus_t, s, done,
                                   pn, code))
            bus_t = s + t_bus

        if it is not None and next(it, None) is not None:
            raise ValueError("param_trace longer than the stream's CU ops")
        phase_ns[phase_name] = phase_ns.get(phase_name, 0.0) + (eng.end_t - phase_start)
        if tracer is not None and eng.end_t > phase_start:
            tracer.phases.append(("bank", phase_name, phase_start, eng.end_t))
        return TimingResult(ns=eng.end_t, stats=dict(eng.stats), phase_ns=phase_ns)


def _time_ntt(
    n: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    pipelined: bool = True,
) -> TimingResult:
    """Map + time one size-n NTT on one bank (no functional execution).

    Internal, warning-free baseline used by the analytic bound and the
    sharded plan; external callers go through `simulate_ntt` (a session
    shim) or `PimSession` directly.  Cache-aware: with
    `param_cache_entries > 0` the stream's residency trace is computed
    and charged, matching the session path.
    """
    from repro.core.mapping import RowCentricMapper

    cfg = cfg or PimConfig()
    cmds = RowCentricMapper(cfg, n, forward=forward).commands()
    trace = None
    if cfg.param_cache_entries:
        from repro.pimsys.engine import param_beat_trace

        trace = param_beat_trace(cfg, n, cmds)
    return BankTimer(cfg, pipelined=pipelined).simulate(cmds, trace)


def simulate_ntt(
    n: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    pipelined: bool = True,
) -> TimingResult:
    """Map + time one size-n NTT on one bank (no functional execution).

    Legacy shim over `repro.pimsys.session.PimSession` — bit-identical
    to the session path by construction (same mapper stream, same
    `BankTimer`).
    """
    from repro.pimsys.session import NttOp, PimSession, warn_legacy

    warn_legacy("simulate_ntt", "run(compile(NttOp(n)))")
    sess = PimSession(cfg, pipelined=pipelined)
    return sess.run(sess.compile(NttOp(n, forward=forward))).timing


@dataclasses.dataclass
class MultiBankResult:
    banks: int
    latency_ns: float
    speedup: float
    efficiency: float
    bus_utilization: float
    analytic_latency_ns: float = 0.0  # lower-bound cross-check (see below)
    policy: str = "rr"
    param_hit_rate: float = 0.0  # device-side twiddle-parameter cache


def analytic_multibank_bound(
    n: int, banks: int, cfg: PimConfig | None = None,
    single: TimingResult | None = None,
    param_trace: Sequence[tuple[int, int]] | None = None,
) -> float:
    """Analytic LOWER bound on k-bank latency under shared-bus contention.

    All banks in a channel share one command/address bus, and NTT-PIM
    additionally streams (w0, r_w) parameters over it per CU op (§IV-A),
    so the bus eventually serializes the banks:

        latency(k) >= max( single_bank_latency,
                           k * bus_cycles_one_bank * t_cycle )

    where bus_cycles_one_bank = #commands + param_beats, and param_beats
    is the stream's residency-trace total when the device-side parameter
    cache is enabled (`param_trace`, from `engine.param_beat_trace` —
    the plan layer passes its precomputed one) or the flat
    `param_load_cycles * cu_ops` when it is not.  Exact in the two
    asymptotes, conservative in between (no hazard stalls charged to
    the bus); the cycle-level controller in `repro.pimsys` charges
    exactly these beats per command and can therefore never beat it.
    """
    cfg = cfg or PimConfig()
    single = single or _time_ntt(n, cfg)
    st = single.stats
    n_cmds = sum(
        st.get(k, 0)
        for k in ("act", "col_read", "col_write", "c1", "c2", "cmul",
                   "word_load", "word_store", "bu_word")
    )
    cu_ops = st.get("c1", 0) + st.get("c2", 0) + st.get("cmul", 0)
    if param_trace is None and cfg.param_cache_entries:
        from repro.core.mapping import RowCentricMapper
        from repro.pimsys.engine import param_beat_trace

        param_trace = param_beat_trace(cfg, n, RowCentricMapper(cfg, n).commands())
    if param_trace is None:
        param_beats = cfg.param_load_cycles * cu_ops
    else:
        param_beats = sum(b for b, _ in param_trace)
    bus_ns_one = (n_cmds + param_beats) * cfg.dram_ns
    return max(single.ns, banks * bus_ns_one)


def simulate_ntt_sharded(
    n: int,
    banks: int,
    cfg: PimConfig | None = None,
    forward: bool = False,
    policy: str = "rr",
    topo=None,
    single: TimingResult | None = None,
):
    """Time ONE size-n NTT sharded over `banks` banks (four-step split).

    Unlike `simulate_multibank` (independent NTTs, one per bank), this
    decomposes a single transform: per-bank N/banks-point local passes
    plus log2(banks) cross-bank exchange stages over the per-channel
    shared buses.  Returns a `ShardedTimingResult`.  Pass `single` (the
    one-bank `simulate_ntt(n, cfg, forward)` result) when sweeping over
    `banks` to avoid re-simulating the baseline each call.

    Legacy shim over `repro.pimsys.session.PimSession`.
    """
    from repro.pimsys.session import PimSession, ShardedNttOp, warn_legacy

    warn_legacy("simulate_ntt_sharded", "run(compile(ShardedNttOp(n, banks)))")
    sess = PimSession(cfg, topo=topo, policy=policy)
    plan = sess.compile(ShardedNttOp(n, banks, forward=forward))
    return sess.run(plan, single=single).timing


def simulate_multibank(
    n: int,
    banks: int,
    cfg: PimConfig | None = None,
    policy: str = "rr",
    single: TimingResult | None = None,
) -> MultiBankResult:
    """Bank-level parallelism under SHARED command-bus contention.

    The paper (§VII) expects near-linear speedup from running independent
    NTTs on independent banks, leaving the system-level check as future
    work.  This runs `banks` identical size-n NTT command streams through
    the cycle-level channel engine (`repro.pimsys.engine`) — one shared
    bus, per-bank `BankEngine` hazard tracking — and cross-checks the
    result against `analytic_multibank_bound` (the controller must never
    report a latency below the bound).  Pass `single` (the one-bank
    `simulate_ntt(n, cfg)` result) when sweeping over `banks` to avoid
    re-simulating the baseline each call.

    Legacy shim over `repro.pimsys.session.PimSession`."""
    from repro.pimsys.session import BatchOp, NttOp, PimSession, warn_legacy

    warn_legacy("simulate_multibank", "run(compile(BatchOp(NttOp(n), banks)))")
    sess = PimSession(cfg, policy=policy)
    plan = sess.compile(BatchOp(NttOp(n), banks))
    return sess.run(plan, single=single).timing
