"""Modular arithmetic for NTT: host-side (python int / numpy int64) and
device-side (jnp uint32 16-bit-limb) implementations.

The paper's CU performs ModAdd/Sub and ModMult for arbitrary moduli via
Montgomery reduction on a 32x32 hardware multiplier.  TPUs have no 64-bit
integer multiply, so the device-side code emulates the 32x32->64 product
with 16x16->32 partial products (see DESIGN.md "hardware adaptation").

Conventions: all residues are in [0, q), q < 2^31 so that a+b never wraps
uint32 and Shoup reduction's 2q intermediate fits.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Host-side: primes, roots of unity, parameter precomputation (python ints)
# ---------------------------------------------------------------------------

#: Default 31-bit NTT-friendly prime: 15 * 2^27 + 1 (supports N | 2^27).
DEFAULT_Q = 2013265921
#: A generator of (Z/DEFAULT_Q)^*.
DEFAULT_GENERATOR = 31

_SMALL_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3e24 (covers all 64-bit)."""
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _SMALL_PRIMES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def find_ntt_prime(two_n: int, bits: int = 31) -> int:
    """Smallest prime q < 2^bits with q ≡ 1 (mod two_n), searching downward."""
    if two_n & (two_n - 1):
        raise ValueError("two_n must be a power of two")
    q = ((1 << bits) - 1) // two_n * two_n + 1
    while q > two_n:
        if is_prime(q):
            return q
        q -= two_n
    raise ValueError(f"no NTT prime below 2^{bits} for order {two_n}")


def primitive_root(q: int) -> int:
    """Smallest primitive root modulo prime q."""
    factors = []
    phi = q - 1
    m = phi
    d = 2
    while d * d <= m:
        if m % d == 0:
            factors.append(d)
            while m % d == 0:
                m //= d
        d += 1
    if m > 1:
        factors.append(m)
    for g in range(2, q):
        if all(pow(g, phi // f, q) != 1 for f in factors):
            return g
    raise ValueError("no primitive root (q not prime?)")


@functools.lru_cache(maxsize=None)
def root_of_unity(q: int, order: int) -> int:
    """A primitive `order`-th root of unity mod prime q (requires order | q-1)."""
    if (q - 1) % order:
        raise ValueError(f"{order} does not divide q-1={q - 1}")
    g = primitive_root(q)
    w = pow(g, (q - 1) // order, q)
    # Sanity: primitive of exactly this order.
    assert pow(w, order, q) == 1 and pow(w, order // 2, q) != 1
    return w


def inv_mod(a: int, q: int) -> int:
    """a^-1 mod q for any modulus with gcd(a, q) == 1 (extended Euclid)."""
    return pow(a, -1, q)


def shoup(w: int, q: int) -> int:
    """Shoup precomputed companion: floor(w * 2^32 / q).  Requires w < q < 2^31."""
    return (w << 32) // q


def mont_params(q: int):
    """Montgomery parameters for R = 2^32: (qprime = -q^-1 mod 2^32, R mod q, R^2 mod q)."""
    qprime = (-inv_mod(q, 1 << 32)) % (1 << 32)
    r_mod_q = (1 << 32) % q
    r2_mod_q = (1 << 64) % q
    return qprime, r_mod_q, r2_mod_q


# ---------------------------------------------------------------------------
# Host-side vectorized oracle ops (numpy, int64 intermediates are exact
# because q < 2^31 => products < 2^62)
# ---------------------------------------------------------------------------


def np_mulmod(a, b, q: int):
    return (np.asarray(a, np.int64) * np.asarray(b, np.int64)) % q


def np_addmod(a, b, q: int):
    return (np.asarray(a, np.int64) + np.asarray(b, np.int64)) % q


def np_submod(a, b, q: int):
    return (np.asarray(a, np.int64) - np.asarray(b, np.int64)) % q


def np_powmod(base: int, exps, q: int):
    exps = np.asarray(exps, np.int64)
    out = np.empty_like(exps)
    flat = exps.reshape(-1)
    res = out.reshape(-1)
    for i, e in enumerate(flat):  # host-side precompute only; not perf critical
        res[i] = pow(int(base), int(e), q)
    return out


def powers_of(w: int, n: int, q: int) -> np.ndarray:
    """[w^0, w^1, ..., w^(n-1)] mod q as uint32."""
    out = np.empty(n, np.uint32)
    acc = 1
    for i in range(n):
        out[i] = acc
        acc = acc * w % q
    return out


def bit_reverse_indices(n: int) -> np.ndarray:
    """Permutation p with p[i] = bit-reversal of i in log2(n) bits."""
    bits = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    rev = np.zeros_like(idx)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


# ---------------------------------------------------------------------------
# Device-side (jnp) uint32 16-bit-limb arithmetic.
# These are shared by kernels/ref.py (oracle) and kernels/*.py (Pallas bodies):
# the SAME code traces into both, so the kernel-vs-ref comparison checks the
# tiling/scheduling, while these primitives are checked against python ints.
# ---------------------------------------------------------------------------

_U16 = np.uint32(0xFFFF)


def _u32(x):
    # Python/numpy scalars stay numpy scalars: they fold into the jaxpr as
    # literals, so Pallas kernel bodies don't capture array constants.
    if isinstance(x, (int, np.integer)):
        return np.uint32(x)
    return jnp.asarray(x, jnp.uint32)


def mulhi_u32(a, b):
    """High 32 bits of the 64-bit product of two uint32 vectors.

    Schoolbook with 16-bit limbs; every intermediate fits uint32:
      a*b = p_hh*2^32 + (p_lh + p_hl)*2^16 + p_ll
      hi  = p_hh + (p_lh>>16) + (p_hl>>16)
            + ((p_ll>>16) + (p_lh&0xFFFF) + (p_hl&0xFFFF)) >> 16
    """
    a = _u32(a)
    b = _u32(b)
    a_lo, a_hi = a & _U16, a >> 16
    b_lo, b_hi = b & _U16, b >> 16
    p_ll = a_lo * b_lo
    p_lh = a_lo * b_hi
    p_hl = a_hi * b_lo
    p_hh = a_hi * b_hi
    mid = (p_ll >> 16) + (p_lh & _U16) + (p_hl & _U16)  # < 3*2^16, no overflow
    return p_hh + (p_lh >> 16) + (p_hl >> 16) + (mid >> 16)


def mullo_u32(a, b):
    """Low 32 bits of the product (uint32 multiply wraps)."""
    return _u32(a) * _u32(b)


def addmod_u32(a, b, q):
    """(a + b) mod q for a,b in [0,q), q < 2^31."""
    q = _u32(q)
    s = _u32(a) + _u32(b)
    return jnp.where(s >= q, s - q, s)


def submod_u32(a, b, q):
    """(a - b) mod q for a,b in [0,q)."""
    q = _u32(q)
    d = _u32(a) + q - _u32(b)
    return jnp.where(d >= q, d - q, d)


def shoup_mulmod_u32(a, w, w_shoup, q):
    """a * w mod q with precomputed w_shoup = floor(w*2^32/q).

    This is the twiddle multiplication in the butterfly: one mulhi (the
    approximate quotient), two mullo, one conditional subtract.  The paper's
    CU realizes the same operation with Montgomery; Shoup is the standard
    choice when one operand is a precomputed constant.
    """
    q = _u32(q)
    quot = mulhi_u32(a, w_shoup)
    r = mullo_u32(a, w) - mullo_u32(quot, q)  # in [0, 2q) mod 2^32
    return jnp.where(r >= q, r - q, r)


def mont_mul_u32(a, b, q, qprime):
    """Montgomery product REDC(a*b): returns a*b*2^-32 mod q, inputs in [0,q).

    Faithful analogue of the paper's CU ModMult (Montgomery, arbitrary q).
    """
    q = _u32(q)
    qprime = _u32(qprime)
    t_lo = mullo_u32(a, b)
    t_hi = mulhi_u32(a, b)
    m = mullo_u32(t_lo, qprime)
    mq_hi = mulhi_u32(m, q)
    # t_lo + (m*q)_lo == 0 mod 2^32 by construction; carry iff t_lo != 0.
    carry = (t_lo != np.uint32(0)).astype(jnp.uint32)
    r = t_hi + mq_hi + carry  # < 2q
    return jnp.where(r >= q, r - q, r)


def to_mont_u32(a, q, qprime, r2_mod_q):
    return mont_mul_u32(a, _u32(r2_mod_q), q, qprime)


def from_mont_u32(a, q, qprime):
    return mont_mul_u32(a, _u32(1), q, qprime)


def mulmod_u32(a, b, q, qprime, r2_mod_q):
    """General a*b mod q via Montgomery round-trip (for variable x variable)."""
    return mont_mul_u32(mont_mul_u32(a, b, q, qprime), _u32(r2_mod_q), q, qprime)
