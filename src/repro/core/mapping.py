"""Row-centric NTT→PIM mapping (paper §III/§IV-B) + functional execution.

The memory controller (MC) model turns one NTT invocation into a DRAM
command stream.  Commands:

  Act(row)                    row activate (implies precharge of open row)
  ColRead(row, atom, buf)     atom: row buffer -> atom buffer `buf` (CU-read)
  ColWrite(row, atom, buf)    atom buffer -> row buffer (CU-write)
  C1(buf, base)               intra-atom NTT: log(Na) fused stages (Alg. 1)
  C2(bufs_u, bufs_v, ...)     vectorized inter-atom butterfly (Alg. 2);
                              grouped over G=len(bufs_u) atom pairs so the
                              scheduler can exploit same-row grouping (§V)
  WordLoad/WordStore/BUWord   word-granular path used when Nb==1 (§III-B:
                              "two loads ... two stores per BU operation")

Twiddles: the hardware generates twiddles on the fly from (w0, r_w) per
command (§IV-A).  Functionally we resolve them from the NttContext tables
using the *global word offset* each command touches; the MC would program
(w0, r_w) so that the generated sequence equals exactly these table values
(per-block resets are parameter re-loads, which the command encoding
supports — see DESIGN.md §2, changed-assumption #1).

Three regimes (§IV-B): stage stride t (in words)
  t < Na          intra-atom  -> folded into C1
  Na <= t < R     intra-row   -> C2, all accesses hit the open row
  t >= R          inter-row   -> C2 with intermittent Acts; with Nb >= 4
                  the mapper groups G = Nb//2 atom pairs per row switch,
                  eliminating activations (§V "pipelining ... reduced
                  number of row activations").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt as ntt_ref
from repro.core.pim_config import PimConfig


# --------------------------------------------------------------------------
# Command IR
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Act:
    row: int


@dataclasses.dataclass(frozen=True)
class ColRead:
    row: int
    atom: int
    buf: int


@dataclasses.dataclass(frozen=True)
class ColWrite:
    row: int
    atom: int
    buf: int


@dataclasses.dataclass(frozen=True)
class C1:
    buf: int
    base: int  # global word offset of the atom (for twiddle resolution)
    gs: bool   # butterfly type: GS (inverse orientation) or CT (forward)
    stages_lo: int  # first stage index handled (0-based, in stride order)
    stages_hi: int  # one past last


@dataclasses.dataclass(frozen=True)
class C2:
    bufs_u: tuple[int, ...]
    bufs_v: tuple[int, ...]
    bases_u: tuple[int, ...]  # global word offsets of the u-atoms
    stride: int               # butterfly stride in words
    gs: bool


@dataclasses.dataclass(frozen=True)
class WordLoad:
    row: int
    col_word: int
    reg: int


@dataclasses.dataclass(frozen=True)
class WordStore:
    row: int
    col_word: int
    reg: int


@dataclasses.dataclass(frozen=True)
class BUWord:
    base_u: int  # global word offset of operand u
    stride: int
    gs: bool


@dataclasses.dataclass(frozen=True)
class CMul:
    """Pointwise Montgomery multiply of two atom buffers: u <- u * v mod q.

    Used for the NTT-domain element-wise product of eq. (1); same CU
    datapath as C2 (ModMult lane per element), no butterfly add/sub.
    """

    buf_u: int
    buf_v: int


@dataclasses.dataclass(frozen=True)
class Mark:
    """Phase marker (no hardware effect) — lets the timer attribute time."""

    name: str


Command = Act | ColRead | ColWrite | C1 | C2 | CMul | WordLoad | WordStore | BUWord | Mark


# --------------------------------------------------------------------------
# Stage plan helpers
# --------------------------------------------------------------------------

#: Count of `RowCentricMapper.commands()` materializations since import.
#: The session layer (`repro.pimsys.session`) compiles each mapper stream
#: once per `CompiledPlan`; tests snapshot this counter around a repeated
#: `run()` to prove the cached plan performs zero mapper regeneration.
MAPPER_GENERATIONS = 0


def mapper_generations() -> int:
    """Current value of the module-wide mapper-generation counter."""
    return MAPPER_GENERATIONS


def stage_strides(n: int, forward: bool) -> list[int]:
    """Butterfly strides in execution order.

    inverse/GS orientation (paper Alg. 1-2): 1, 2, ..., N/2
    forward/CT orientation:                  N/2, ..., 2, 1
    """
    s = [1 << i for i in range(int(math.log2(n)))]
    return s[::-1] if forward else s


def twiddle_index(n: int, stride: int, global_offset: int) -> int:
    """Index into the brv twiddle table for the block containing offset.

    For both orientations, the stage with stride t has blocks of 2t
    elements and block B uses table[h + B] with h = n/(2t).
    """
    h = n // (2 * stride)
    return h + global_offset // (2 * stride)


def cu_twiddle_indices(cfg: PimConfig, n: int, cmd) -> tuple[int, ...] | None:
    """Global twiddle-table indices one CU op's (w0, r_w) parameter
    program resolves, or None for ops without a generator program
    (CMul's pointwise operands, non-CU commands).

    THE single definition of program identity: the session's functional
    `twiddle_param_stream` and the engine's parameter-cache keys
    (`pimsys.engine.param_program_key`) both derive from it, so the
    replayed values and the cached residency can never disagree.  `n`
    is the GLOBAL transform size (sharded local streams resolve the
    full table through their shifted bases).  Stage-h prefixing makes
    index tuples disjoint across strides (index = h + B with B < h),
    so the tuple alone identifies the stage geometry.
    """
    cls = cmd.__class__
    if cls is C2:
        return tuple(twiddle_index(n, cmd.stride, b) for b in cmd.bases_u)
    if cls is C1:
        Na = cfg.atom_words
        strides = stage_strides(Na, not cmd.gs)[cmd.stages_lo:cmd.stages_hi]
        return tuple(twiddle_index(n, t, cmd.base + k)
                     for t in strides for k in range(0, Na, 2 * t))
    if cls is BUWord:
        return (twiddle_index(n, cmd.stride, cmd.base_u),)
    return None


# --------------------------------------------------------------------------
# The mapper (memory controller model)
# --------------------------------------------------------------------------


class RowCentricMapper:
    """Generates the command stream for one negacyclic NTT of size n.

    Layout: coefficient i lives at word i of a contiguous region starting
    at `base_row` (row = base_row + i // R, atom = (i % R) // Na).
    The polynomial is in bit-reversed order for the inverse orientation
    and natural order for the forward one (paper: CPU does bit reversal).

    `twiddle_base` offsets every emitted twiddle base (C1/C2/BUWord) by a
    constant *global* word offset without moving the data: a size-n stream
    with twiddle_base = b*n resolves its twiddles as words [b*n, (b+1)*n)
    of a larger transform, which is exactly the local pass of bank b in a
    sharded size-(B*n) NTT (`repro.pimsys.sharded`).  The MC realizes it
    by programming shifted (w0, r_w) parameters; the command count and
    memory traffic are untouched, so twiddle_base = 0 streams are
    bit-identical to the unsharded mapper's.
    """

    def __init__(self, cfg: PimConfig, n: int, forward: bool = False, base_row: int = 0,
                 twiddle_base: int = 0):
        if n & (n - 1):
            raise ValueError("n must be a power of two")
        self.cfg = cfg
        self.n = n
        self.forward = forward
        self.base_row = base_row
        self.twiddle_base = twiddle_base
        self.Na = cfg.atom_words
        self.R = cfg.row_words
        if cfg.num_buffers >= 2:
            self.G = cfg.num_buffers // 2  # atom pairs per C2 group
        else:
            self.G = 0

    # -- address helpers ----------------------------------------------------
    def row_of(self, word: int) -> int:
        return self.base_row + word // self.R

    def atom_of(self, word: int) -> int:
        return (word % self.R) // self.Na

    def _act(self, out: list, row: int):
        """Emit Act only when switching rows (an MC never re-activates)."""
        if getattr(self, "_open_row", None) != row:
            out.append(Act(row))
            self._open_row = row

    # -- emission -----------------------------------------------------------
    def commands(self) -> list[Command]:
        global MAPPER_GENERATIONS
        MAPPER_GENERATIONS += 1
        self._open_row = None
        out: list[Command] = []
        strides = stage_strides(self.n, self.forward)
        intra_atom = [t for t in strides if t < self.Na]
        intra_row = [t for t in strides if self.Na <= t < self.R]
        inter_row = [t for t in strides if t >= self.R]

        if self.forward:
            # CT: large strides first (mirror of the paper's Fig 4 order).
            self._emit_inter_row(out, inter_row)
            out.append(Mark("intra"))
            self._emit_row_blocks(out, intra_row, intra_atom, c1_first=False)
        else:
            out.append(Mark("intra"))
            self._emit_row_blocks(out, intra_row, intra_atom, c1_first=True)
            self._emit_inter_row(out, inter_row)
        return out

    # -- phase 1: independent row-sized blocks (vertical split, Fig 4) ------
    def _emit_row_blocks(self, out, intra_row, intra_atom, c1_first: bool):
        n_rows = max(1, self.n // self.R)
        words_per_block = min(self.n, self.R)
        atoms_per_block = words_per_block // self.Na
        for blk in range(n_rows):
            row = self.base_row + blk
            self._act(out, row)
            blk_base = blk * self.R
            if c1_first:
                self._emit_c1_pass(out, row, blk_base, atoms_per_block, intra_atom)
                self._emit_intra_row(out, row, blk_base, atoms_per_block, intra_row)
            else:
                self._emit_intra_row(out, row, blk_base, atoms_per_block, intra_row)
                self._emit_c1_pass(out, row, blk_base, atoms_per_block, intra_atom)

    def _emit_c1_pass(self, out, row, blk_base, atoms, intra_atom):
        """Software-pipelined read -> C1 -> write per atom (§V, Fig 6b).

        The MC emits reads up to Nb atoms ahead; with one buffer the
        schedule degenerates to the serial read/compute/write chain.
        """
        if not intra_atom:
            return
        lo, hi = 0, len(intra_atom)
        nb = max(1, self.cfg.num_buffers)
        depth = nb
        for a in range(min(depth, atoms)):  # prologue
            out.append(ColRead(row, a, a % nb))
        for a in range(atoms):
            buf = a % nb
            out.append(C1(buf, self.twiddle_base + blk_base + a * self.Na,
                          gs=not self.forward, stages_lo=lo, stages_hi=hi))
            out.append(ColWrite(row, a, buf))
            nxt = a + depth
            if nxt < atoms:
                out.append(ColRead(row, nxt, nxt % nb))

    def _emit_intra_row(self, out, row, blk_base, atoms, intra_row):
        for t in intra_row:
            if self.cfg.num_buffers >= 2:
                self._emit_c2_stage_intra(out, row, blk_base, atoms, t)
            else:
                self._emit_word_serial_stage(out, [t], blk_base, min(self.n, self.R))

    def _emit_c2_stage_intra(self, out, row, blk_base, atoms, t):
        """Intra-row C2s: atom u pairs with atom u + t/Na inside the open row.

        Buffer pairs rotate across consecutive C2s (software pipelining):
        with Nb buffers, Nb//2 butterfly C2s can be in flight — reads of
        C2 #k+1 overlap compute/writes of C2 #k (paper §V, Fig 6b).
        """
        ta = t // self.Na  # stride in atoms
        pairs = [u for u in range(atoms) if (u // ta) % 2 == 0]
        D = max(1, self.G)  # pipeline depth = Nb // 2 buffer pairs

        def slot_bufs(g):
            slot = g % D
            return 2 * slot, 2 * slot + 1

        for g in range(min(D, len(pairs))):  # prologue reads
            bu, bv = slot_bufs(g)
            out.append(ColRead(row, pairs[g], bu))
            out.append(ColRead(row, pairs[g] + ta, bv))
        for g, u_atom in enumerate(pairs):
            bu, bv = slot_bufs(g)
            base = self.twiddle_base + blk_base + u_atom * self.Na
            out.append(C2((bu,), (bv,), (base,), t, gs=not self.forward))
            out.append(ColWrite(row, u_atom, bu))
            out.append(ColWrite(row, u_atom + ta, bv))
            nxt = g + D
            if nxt < len(pairs):
                nbu, nbv = slot_bufs(nxt)
                out.append(ColRead(row, pairs[nxt], nbu))
                out.append(ColRead(row, pairs[nxt] + ta, nbv))

    # -- phase 2: inter-row stages (stage-by-stage, §IV-B) -------------------
    def _emit_inter_row(self, out, strides):
        for t in strides:
            out.append(Mark(f"inter:{t}"))
            if self.cfg.num_buffers >= 2:
                self._emit_c2_stage_inter(out, t)
            else:
                self._emit_word_serial_stage(out, [t], 0, self.n)

    def _emit_c2_stage_inter(self, out, t):
        """Inter-row stage at stride t >= R.

        Row r pairs with row r + t/R.  For each row pair, process the
        atoms_per_row atom pairs in groups of G = Nb//2: read G u-atoms
        under one activation of r_u, switch to r_v, read G v-atoms,
        compute, write the v results while r_v is open (buffer hits),
        switch back to r_u, write u results + read the next G u-atoms
        under the same activation.  2 Acts per group instead of 2 per
        atom pair — the §V activation-grouping effect.
        """
        tr = t // self.R  # stride in rows
        n_rows = self.n // self.R
        G = max(1, self.G)
        apr = self.cfg.atoms_per_row
        for r_u_idx in range(n_rows):
            if (r_u_idx // tr) % 2 != 0:
                continue
            r_v_idx = r_u_idx + tr
            row_u = self.base_row + r_u_idx
            row_v = self.base_row + r_v_idx
            for g0 in range(0, apr, G):
                atoms = list(range(g0, min(g0 + G, apr)))
                self._act(out, row_u)
                bufs_u, bufs_v, bases = [], [], []
                for i, a in enumerate(atoms):
                    bu = (2 * i) % self.cfg.num_buffers
                    bv = (2 * i + 1) % self.cfg.num_buffers
                    out.append(ColRead(row_u, a, bu))
                    bufs_u.append(bu)
                    bufs_v.append(bv)
                    bases.append(self.twiddle_base + r_u_idx * self.R + a * self.Na)
                self._act(out, row_v)
                for i, a in enumerate(atoms):
                    out.append(ColRead(row_v, a, bufs_v[i]))
                out.append(C2(tuple(bufs_u), tuple(bufs_v), tuple(bases), t, gs=not self.forward))
                # v results written while row_v is open: buffer hits.
                for i, a in enumerate(atoms):
                    out.append(ColWrite(row_v, a, bufs_v[i]))
                # u results need the row switched back.
                self._act(out, row_u)
                for i, a in enumerate(atoms):
                    out.append(ColWrite(row_u, a, bufs_u[i]))

    # -- Nb == 1 degenerate path (§III-B) ------------------------------------
    def _emit_word_serial_stage(self, out, strides, blk_base, span):
        """Word-granular butterflies via the CU's two scalar registers.

        Every BU: two loads + two stores; loads/stores are column accesses
        into the open row; crossing rows forces activations ("about half
        of them require row activation").
        """
        for t in strides:
            for blk in range(blk_base, blk_base + span, 2 * t):
                for j in range(t):
                    u = blk + j
                    v = u + t
                    row_u, row_v = self.row_of(u), self.row_of(v)
                    self._act(out, row_u)
                    out.append(WordLoad(row_u, u % self.R, 0))
                    self._act(out, row_v)
                    out.append(WordLoad(row_v, v % self.R, 1))
                    out.append(BUWord(self.twiddle_base + u, t, gs=not self.forward))
                    out.append(WordStore(row_v, v % self.R, 1))
                    self._act(out, row_u)
                    out.append(WordStore(row_u, u % self.R, 0))


# --------------------------------------------------------------------------
# Functional executor — "verify the functionality of our NTT function as
# executed" (paper §VI-A, the DRAMsim3 two-way check)
# --------------------------------------------------------------------------


class FunctionalBank:
    """Executes a command stream against a memory image, bit-exactly."""

    def __init__(self, cfg: PimConfig, ctx: ntt_ref.NttContext, forward: bool):
        self.cfg = cfg
        self.ctx = ctx
        self.forward = forward
        self.mem = np.zeros((cfg.rows_per_bank, cfg.row_words), np.uint32)
        self.bufs = np.zeros((max(1, cfg.num_buffers), cfg.atom_words), np.uint32)
        self.regs = np.zeros(2, np.uint32)
        self.open_row: int | None = None
        self.table = ctx.psi_brv if forward else ctx.psi_inv_brv

    # twiddle for stage stride t, block containing global offset
    def _tw(self, stride: int, offset: int) -> int:
        return int(self.table[twiddle_index(self.ctx.n, stride, offset)])

    def _bu(self, a: int, b: int, w: int, gs: bool) -> tuple[int, int]:
        q = self.ctx.q
        if gs:
            return (a + b) % q, (a - b) * w % q
        wb = b * w % q
        return (a + wb) % q, (a - wb) % q

    def load_poly(self, a: np.ndarray, base_row: int = 0):
        R = self.cfg.row_words
        n = a.shape[0]
        rows = max(1, n // R)
        for r in range(rows):
            chunk = a[r * R : (r + 1) * R]
            self.mem[base_row + r, : chunk.shape[0]] = chunk

    def read_poly(self, n: int, base_row: int = 0) -> np.ndarray:
        R = self.cfg.row_words
        rows = max(1, n // R)
        out = [self.mem[base_row + r, : min(n, R)] for r in range(rows)]
        return np.concatenate(out)[:n]

    def run(self, commands: Iterable[Command]):
        cfg, Na = self.cfg, self.cfg.atom_words
        q = self.ctx.q
        for cmd in commands:
            if isinstance(cmd, Act):
                self.open_row = cmd.row
            elif isinstance(cmd, ColRead):
                assert self.open_row == cmd.row, "buffer conflict: row not open"
                self.bufs[cmd.buf] = self.mem[cmd.row, cmd.atom * Na : (cmd.atom + 1) * Na]
            elif isinstance(cmd, ColWrite):
                assert self.open_row == cmd.row, "buffer conflict: row not open"
                self.mem[cmd.row, cmd.atom * Na : (cmd.atom + 1) * Na] = self.bufs[cmd.buf]
            elif isinstance(cmd, C1):
                self._run_c1(cmd)
            elif isinstance(cmd, C2):
                self._run_c2(cmd)
            elif isinstance(cmd, CMul):
                u = self.bufs[cmd.buf_u].astype(np.int64)
                v = self.bufs[cmd.buf_v].astype(np.int64)
                self.bufs[cmd.buf_u] = (u * v % q).astype(np.uint32)
            elif isinstance(cmd, WordLoad):
                assert self.open_row == cmd.row
                self.regs[cmd.reg] = self.mem[cmd.row, cmd.col_word]
            elif isinstance(cmd, WordStore):
                assert self.open_row == cmd.row
                self.mem[cmd.row, cmd.col_word] = self.regs[cmd.reg]
            elif isinstance(cmd, BUWord):
                w = self._tw(cmd.stride, cmd.base_u)
                a, b = self._bu(int(self.regs[0]), int(self.regs[1]), w, cmd.gs)
                self.regs[0], self.regs[1] = a, b
            elif isinstance(cmd, Mark):
                pass
            else:  # pragma: no cover
                raise TypeError(cmd)

    def _run_c1(self, cmd: C1):
        """Alg. 1: log(Na) butterfly stages inside one atom buffer."""
        Na = self.cfg.atom_words
        x = self.bufs[cmd.buf].astype(np.int64)
        strides = stage_strides(Na, self.forward)[cmd.stages_lo : cmd.stages_hi]
        for t in strides:
            for k in range(0, Na, 2 * t):
                w = self._tw(t, cmd.base + k)
                for j in range(k, k + t):
                    a, b = self._bu(int(x[j]), int(x[j + t]), w, cmd.gs)
                    x[j], x[j + t] = a, b
        self.bufs[cmd.buf] = x.astype(np.uint32)

    def _run_c2(self, cmd: C2):
        """Alg. 2: Na-way vectorized butterfly between buffer pairs."""
        q = self.ctx.q
        for bu, bv, base in zip(cmd.bufs_u, cmd.bufs_v, cmd.bases_u):
            u = self.bufs[bu].astype(np.int64)
            v = self.bufs[bv].astype(np.int64)
            w = self._tw(cmd.stride, base)
            if cmd.gs:
                nu = (u + v) % q
                nv = (u - v) * w % q
            else:
                wv = v * w % q
                nu = (u + wv) % q
                nv = (u - wv) % q
            self.bufs[bu] = nu.astype(np.uint32)
            self.bufs[bv] = nv.astype(np.uint32)


# --------------------------------------------------------------------------
# Public API: run a full NTT through the functional PIM model
# --------------------------------------------------------------------------


def pim_ntt(
    a: np.ndarray,
    ctx: ntt_ref.NttContext,
    cfg: PimConfig | None = None,
    forward: bool = False,
    scale_n_inv: bool = True,
) -> tuple[np.ndarray, list[Command]]:
    """Execute a negacyclic NTT on the functional PIM bank model.

    forward=False (paper orientation): input bit-reversed-domain, GS
    butterflies, output natural — the inverse NTT (scaled by 1/N unless
    scale_n_inv=False; the scaling is one extra vectorized pass that the
    host or CU performs; MeNTT-style comparisons exclude it).
    """
    cfg = cfg or PimConfig()
    n = a.shape[0]
    if n < cfg.atom_words:
        raise ValueError("n must be at least one atom")
    mapper = RowCentricMapper(cfg, n, forward=forward)
    cmds = mapper.commands()
    bank = FunctionalBank(cfg, ctx, forward=forward)
    bank.load_poly(np.asarray(a, np.uint32))
    bank.run(cmds)
    out = bank.read_poly(n)
    if not forward and scale_n_inv:
        out = np.asarray(mm.np_mulmod(out, ctx.n_inv, ctx.q), np.uint32)
    return out, cmds
