"""NTT-PIM architecture + timing parameters (paper Table I, HBM2E-based)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PimConfig:
    """Architecture and timing parameters of one PIM bank.

    Timing parameters are in DRAM cycles at `dram_clock_mhz` (Table I);
    DRAM latencies are fixed in *ns* when the CU clock is scaled (the
    paper's Fig 8 protocol: "the absolute latency of DRAM memory access
    time (in ns) is kept constant").
    """

    # -- architecture (Table I) --------------------------------------------
    atom_bytes: int = 32            # DRAM atom
    word_bytes: int = 4             # 32-bit coefficients
    atoms_per_row: int = 32         # "# of columns per row"
    rows_per_bank: int = 32768
    num_banks: int = 1
    num_buffers: int = 2            # Nb, including the primary (GSA)

    # -- device level (repro.pimsys; beyond the paper's single bank) --------
    # One shared command/address bus per channel; ranks on a channel share
    # that bus (HBM pseudo-channel style), banks within a rank are the
    # paper's independent NTT-PIM banks.
    num_channels: int = 1
    num_ranks: int = 1

    # -- DRAM timing in cycles at dram_clock_mhz (Table I) ------------------
    CL: int = 14
    tCCD: int = 2
    tRP: int = 14
    tRAS: int = 34
    tRCD: int = 14
    tWR: int = 16
    dram_clock_mhz: float = 1200.0

    # -- CU (paper §VI-B: "latency of C1 and C2 is 15 and 10 cycles") -------
    c1_latency: int = 15
    c2_latency: int = 10
    bu_word_latency: int = 6        # single-word BU via scalar regs (Nb=1 path)
    param_load_cycles: int = 4      # (w0, r_w) via global buffer per C1/C2,
    #                                 16-bit chunks "in multiple cycles" (§IV-A)
    cu_clock_mhz: float = 1200.0    # scaled in the Fig 8 experiment

    # -- device-side twiddle-parameter cache (repro.pimsys.engine) ----------
    # LRU cache of recently-used (w0, r_w) parameter programs at each
    # bank's CU (the §V "per-application buffer" idea applied to the
    # per-CU-op parameter stream that sets the multibank bus knee): a
    # miss streams the full `param_load_cycles` beats over the shared
    # bus, a hit pays a single re-select beat.  0 = no cache (the seed
    # timing model, charged flat per CU op).
    param_cache_entries: int = 0

    # -- rank-level timing (repro.pimsys.engine.RankState) ------------------
    # DRAM rank constraints in cycles at `dram_clock_mhz`, shared by the
    # banks of one rank: tFAW (at most 4 ACTs per rank in any tFAW
    # window), tRRD (ACT-to-ACT within a rank), and tRTW/tWTR data-bus
    # turnaround when consecutive same-rank column accesses switch
    # direction.  All default to 0 — the seed model's idealized rank,
    # kept as the differential anchor (banks=1 and the committed golden
    # cycle counts are bit-identical by construction).  HBM2E-class
    # values to enable them: tFAW=24, tRRD=4, tRTW=8, tWTR=5.
    tFAW: int = 0
    tRRD: int = 0
    tRTW: int = 0
    tWTR: int = 0

    # -- refresh (DRAMsim3 models it; approximated as a stall window) -------
    tREFI_ns: float = 3900.0
    tRFC_ns: float = 260.0

    # -- inter-bank exchange (repro.pimsys.sharded) -------------------------
    # A sharded NTT moves atoms between banks over the per-channel shared
    # bus: one atom (Na words) crosses as a burst of `xfer_beats_per_atom`
    # bus beats (paired ColRead on the source / ColWrite on the target);
    # crossing a channel boundary additionally costs `channel_hop_cycles`
    # of hop latency (both channels' buses are held for the burst).
    xfer_beats_per_atom: int = 4
    channel_hop_cycles: int = 12

    # -- observability (repro.pimsys.telemetry) -----------------------------
    # Opt-in command/phase tracing.  Off by default: engines then carry
    # `tracer=None` and the issue loops pay a single `is None` test, so
    # the committed `engine_speed` floor is unaffected.  On, session
    # runs attach a `TelemetryHandle` to `RunResult.telemetry` with the
    # full per-command/per-phase timeline (Perfetto-exportable).  A bool
    # keeps the config hashable (it stays a valid plan-cache key); the
    # flag does not alter timing, only recording.
    telemetry: bool = False

    @property
    def atom_words(self) -> int:  # Na
        return self.atom_bytes // self.word_bytes

    @property
    def row_words(self) -> int:  # R
        return self.atoms_per_row * self.atom_words

    @property
    def dram_ns(self) -> float:
        return 1e3 / self.dram_clock_mhz

    @property
    def cu_ns(self) -> float:
        return 1e3 / self.cu_clock_mhz

    def with_(self, **kw) -> "PimConfig":
        return dataclasses.replace(self, **kw)


#: Default configuration used throughout the paper's evaluation.
DEFAULT_PIM = PimConfig()


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Per-op energy constants (nJ).

    `literature` uses HBM2-class per-bank numbers (row activate+precharge,
    column access terminating at the atom buffer — i.e. no chip I/O — and a
    32-lane modular-arithmetic CU at 65 nm).  The paper's Table III energy
    unit/accounting is not fully specified, so benchmarks also report a
    least-squares fit of these three constants to the paper's own (N, Nb)
    energy table; see benchmarks/table3_comparison.py.
    """

    e_act: float = 0.909       # nJ per ACT(+PRE) of a 1KB row (HBM2-class)
    e_col: float = 0.053       # nJ per 32B column access stopping at P/S
    e_cu: float = 0.020        # nJ per C1/C2 (<=12 pipelined 32b mod-ops)
    e_word: float = 0.004      # nJ per word load/store micro-op

    def energy_nj(self, stats: dict) -> float:
        return (
            self.e_act * stats.get("act", 0)
            + self.e_col * (stats.get("col_read", 0) + stats.get("col_write", 0))
            + self.e_cu * (stats.get("c1", 0) + stats.get("c2", 0) + stats.get("cmul", 0))
            + self.e_word
            * (stats.get("word_load", 0) + stats.get("word_store", 0) + stats.get("bu_word", 0))
        )
