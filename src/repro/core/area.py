"""Area / power model of the NTT-PIM compute unit (paper Table II).

The paper synthesizes the CU (fully-pipelined Montgomery BU + registers +
crossbar) at Samsung 65 nm and estimates atom-buffer SRAM with CACTI 7.0.
Without the foundry PDK we reproduce the *model structure*:

    area(Nb) = A_cu + A_buf_port * (Nb - 1)

(the primary buffer is the pre-existing GSA, hence Nb - 1 added SRAM
buffers; each added buffer also adds crossbar ports, folded into the
per-buffer coefficient).  The coefficients are calibrated once against
the paper's own four Table II points, and the calibration residual is
reported by the benchmark — i.e. we verify the paper's claimed scaling
is consistent with its own architecture description, and extrapolate
beyond Nb = 6.
"""
from __future__ import annotations

import numpy as np

#: Table II (mm^2, Samsung 65 nm logic + CACTI 7.0 buffers)
BANK_AREA_MM2 = 4.2208
NEWTON_AREA_MM2 = 0.0474
PAPER_TABLE2 = {1: 0.0213, 2: 0.0232, 4: 0.0263, 6: 0.0285}


def fit_area_model() -> tuple[float, float, float]:
    """Least-squares (A_cu, A_buf_port) + max |residual| vs Table II."""
    nbs = np.array(sorted(PAPER_TABLE2), float)
    areas = np.array([PAPER_TABLE2[int(n)] for n in nbs])
    X = np.stack([np.ones_like(nbs), nbs - 1], axis=1)
    coef, *_ = np.linalg.lstsq(X, areas, rcond=None)
    resid = np.abs(X @ coef - areas).max()
    return float(coef[0]), float(coef[1]), float(resid)


def cu_area_mm2(num_buffers: int) -> float:
    a_cu, a_buf, _ = fit_area_model()
    return a_cu + a_buf * (num_buffers - 1)


def area_overhead_pct(num_buffers: int) -> float:
    return 100.0 * cu_area_mm2(num_buffers) / BANK_AREA_MM2


def newton_overhead_pct() -> float:
    return 100.0 * NEWTON_AREA_MM2 / BANK_AREA_MM2
