"""RLWE polynomial multiplication (paper eq. 1) on the PIM bank model:

    a * b = INTT( NTT(a) ⊙ NTT(b) )            in Z_q[X]/(X^N + 1)

Layout: a at base_row ra, b at rb.  Three command phases:
  1. forward NTT of a (in place), forward NTT of b (in place)
  2. pointwise pass: stream atom pairs through CMul (a <- a ⊙ b)
  3. inverse NTT of a (in place) + 1/N scaling pass

Because the forward emits bit-reversed order and the pointwise product is
element-wise, no bit-reversal commands are needed anywhere (§II-B).

Bank-level parallelism: `polymul_batch` runs independent products on
separate banks through the device-level controller (`repro.pimsys`),
which arbitrates the per-channel shared command bus — near-linear until
the bus saturates (§I / §VII).
"""
from __future__ import annotations

import numpy as np

from repro.core import ntt as ntt_ref
from repro.core.mapping import (
    Act,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    RowCentricMapper,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import TimingResult


def pointwise_commands(cfg: PimConfig, n: int, row_a: int, row_b: int) -> list[Command]:
    """Stream both polynomials through CMul, a <- a ⊙ b, atom by atom.

    Uses buffer pairs with the same software-pipelining discipline as the
    butterfly stages; rows alternate, so with Nb >= 4 the mapper groups
    G = Nb//2 atoms per row switch.
    """
    out: list[Command] = [Mark("pointwise")]
    Na, R, apr = cfg.atom_words, cfg.row_words, cfg.atoms_per_row
    n_rows = max(1, n // R)
    atoms_last = (min(n, R)) // Na
    G = max(1, cfg.num_buffers // 2)
    for r in range(n_rows):
        atoms = apr if n >= R else atoms_last
        for g0 in range(0, atoms, G):
            grp = list(range(g0, min(g0 + G, atoms)))
            out.append(Act(row_a + r))
            for i, atm in enumerate(grp):
                out.append(ColRead(row_a + r, atm, 2 * i))
            out.append(Act(row_b + r))
            for i, atm in enumerate(grp):
                out.append(ColRead(row_b + r, atm, 2 * i + 1))
            for i in range(len(grp)):
                out.append(CMul(2 * i, 2 * i + 1))
            out.append(Act(row_a + r))
            for i, atm in enumerate(grp):
                out.append(ColWrite(row_a + r, atm, 2 * i))
    # deduplicate consecutive Acts to the same row
    dedup: list[Command] = []
    open_row = None
    for c in out:
        if isinstance(c, Act):
            if c.row == open_row:
                continue
            open_row = c.row
        dedup.append(c)
    return dedup


def scaling_commands(cfg: PimConfig, n: int, row_a: int) -> list[Command]:
    """1/N scaling after the inverse NTT: one CMul pass against a constant.

    Hardware-wise the CU multiplies by the scalar n_inv from its parameter
    register; we model it as a CMul-latency pass per atom (no second read).
    """
    out: list[Command] = [Mark("scale")]
    Na, R, apr = cfg.atom_words, cfg.row_words, cfg.atoms_per_row
    n_rows = max(1, n // R)
    atoms_last = min(n, R) // Na
    nb = max(1, cfg.num_buffers)
    for r in range(n_rows):
        out.append(Act(row_a + r))
        atoms = apr if n >= R else atoms_last
        for atm in range(atoms):
            buf = atm % nb
            out.append(ColRead(row_a + r, atm, buf))
            out.append(CMul(buf, buf))  # timed like a scalar multiply pass
            out.append(ColWrite(row_a + r, atm, buf))
    return out


def polymul_phases(cfg: PimConfig, n: int, row_a: int = 0,
                   row_b: int | None = None) -> tuple[dict[str, list[Command]], int]:
    """The canonical polymul phase layout, in execution order.

    Single source of truth for both the flat timed stream
    (`polymul_commands`) and the session's per-phase functional execution
    (`repro.pimsys.session` compiles the dict into its `CompiledPlan`).
    Returns `(phases, row_b)`; concatenating the dict values in insertion
    order IS the timed command stream.
    """
    R = cfg.row_words
    rows = max(1, n // R)
    row_b = row_b if row_b is not None else row_a + rows
    phases = {
        "fwd_a": RowCentricMapper(cfg, n, forward=True, base_row=row_a).commands(),
        "fwd_b": RowCentricMapper(cfg, n, forward=True, base_row=row_b).commands(),
        "pointwise": pointwise_commands(cfg, n, row_a, row_b),
        "inv_a": RowCentricMapper(cfg, n, forward=False, base_row=row_a).commands(),
        "scale": scaling_commands(cfg, n, row_a),
    }
    return phases, row_b


def polymul_commands(cfg: PimConfig, n: int, row_a: int = 0, row_b: int | None = None):
    phases, row_b = polymul_phases(cfg, n, row_a, row_b)
    return [c for cmds in phases.values() for c in cmds], row_b


def pim_polymul(
    a: np.ndarray,
    b: np.ndarray,
    ctx: ntt_ref.NttContext,
    cfg: PimConfig | None = None,
) -> tuple[np.ndarray, TimingResult]:
    """Functional + timed polynomial multiplication on one bank.

    Legacy shim over `repro.pimsys.session.PimSession` (compile once,
    run many); bit-identical values, cycles, and command lists.
    """
    from repro.pimsys.session import PimSession, PolymulOp, warn_legacy

    warn_legacy("pim_polymul", "run(compile(PolymulOp(n)), a, b)")
    sess = PimSession(cfg)
    r = sess.run(sess.compile(PolymulOp(a.shape[0])), a, b, ctx=ctx)
    return r.value, r.timing


def pim_ntt_sharded(
    a: np.ndarray,
    ctx: ntt_ref.NttContext,
    cfg: PimConfig | None = None,
    banks: int = 2,
    forward: bool = False,
    scale_n_inv: bool = True,
    topo=None,
):
    """Execute one negacyclic NTT sharded over `banks` banks, bit-exactly.

    The four-step split of `repro.pimsys.sharded`: each bank runs its
    N/banks-point local `RowCentricMapper` stream (shifted twiddle bases)
    on its own `FunctionalBank`, and the cross-bank stages apply the
    shared-twiddle column butterflies between bank images.  Same
    orientation/scaling conventions as `pim_ntt`; at banks=1 the two are
    command-for-command identical.  Returns `(out, plan)` — time the
    plan with `plan.simulate()`.

    Legacy shim over `repro.pimsys.session.PimSession`; the returned
    plan is the compiled artifact's `ShardedNttPlan`.
    """
    from repro.pimsys.session import PimSession, ShardedNttOp, warn_legacy

    warn_legacy("pim_ntt_sharded", "run(compile(ShardedNttOp(n, banks)), a)")
    a = np.asarray(a, np.uint32)
    sess = PimSession(cfg, topo=topo)
    plan = sess.compile(ShardedNttOp(a.shape[0], banks, forward=forward,
                                     scale_n_inv=scale_n_inv))
    r = sess.run(plan, a, ctx=ctx, time=False)
    return r.value, plan.sharded_plan


def polymul_batch(n: int, batch: int, cfg: PimConfig | None = None, policy: str = "rr"):
    """Time `batch` independent products on the device-level controller.

    One product per bank, banks contending on their channel's shared
    command bus; requests beyond `cfg` topology capacity (num_channels x
    num_ranks x num_banks) queue FIFO.  Returns the closed-loop
    `repro.pimsys.SchedulerResult` (latency percentiles, throughput,
    device stats).  Timing only — for functional output use `pim_polymul`.

    Legacy shim over `repro.pimsys.session.PimSession`.
    """
    from repro.pimsys.session import BatchOp, PimSession, PolymulOp, warn_legacy

    warn_legacy("polymul_batch", "run(compile(BatchOp(PolymulOp(n), batch)))")
    sess = PimSession(cfg, policy=policy)
    return sess.run(sess.compile(BatchOp(PolymulOp(n), batch))).timing
