"""RLWE polynomial multiplication (paper eq. 1) on the PIM bank model:

    a * b = INTT( NTT(a) ⊙ NTT(b) )            in Z_q[X]/(X^N + 1)

Layout: a at base_row ra, b at rb.  Three command phases:
  1. forward NTT of a (in place), forward NTT of b (in place)
  2. pointwise pass: stream atom pairs through CMul (a <- a ⊙ b)
  3. inverse NTT of a (in place) + 1/N scaling pass

Because the forward emits bit-reversed order and the pointwise product is
element-wise, no bit-reversal commands are needed anywhere (§II-B).

Bank-level parallelism: `polymul_batch` runs independent products on
separate banks through the device-level controller (`repro.pimsys`),
which arbitrates the per-channel shared command bus — near-linear until
the bus saturates (§I / §VII).
"""
from __future__ import annotations

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt as ntt_ref
from repro.core.mapping import (
    Act,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    FunctionalBank,
    Mark,
    RowCentricMapper,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import BankTimer, TimingResult


def pointwise_commands(cfg: PimConfig, n: int, row_a: int, row_b: int) -> list[Command]:
    """Stream both polynomials through CMul, a <- a ⊙ b, atom by atom.

    Uses buffer pairs with the same software-pipelining discipline as the
    butterfly stages; rows alternate, so with Nb >= 4 the mapper groups
    G = Nb//2 atoms per row switch.
    """
    out: list[Command] = [Mark("pointwise")]
    Na, R, apr = cfg.atom_words, cfg.row_words, cfg.atoms_per_row
    n_rows = max(1, n // R)
    atoms_last = (min(n, R)) // Na
    G = max(1, cfg.num_buffers // 2)
    for r in range(n_rows):
        atoms = apr if n >= R else atoms_last
        for g0 in range(0, atoms, G):
            grp = list(range(g0, min(g0 + G, atoms)))
            out.append(Act(row_a + r))
            for i, atm in enumerate(grp):
                out.append(ColRead(row_a + r, atm, 2 * i))
            out.append(Act(row_b + r))
            for i, atm in enumerate(grp):
                out.append(ColRead(row_b + r, atm, 2 * i + 1))
            for i in range(len(grp)):
                out.append(CMul(2 * i, 2 * i + 1))
            out.append(Act(row_a + r))
            for i, atm in enumerate(grp):
                out.append(ColWrite(row_a + r, atm, 2 * i))
    # deduplicate consecutive Acts to the same row
    dedup: list[Command] = []
    open_row = None
    for c in out:
        if isinstance(c, Act):
            if c.row == open_row:
                continue
            open_row = c.row
        dedup.append(c)
    return dedup


def scaling_commands(cfg: PimConfig, n: int, row_a: int) -> list[Command]:
    """1/N scaling after the inverse NTT: one CMul pass against a constant.

    Hardware-wise the CU multiplies by the scalar n_inv from its parameter
    register; we model it as a CMul-latency pass per atom (no second read).
    """
    out: list[Command] = [Mark("scale")]
    Na, R, apr = cfg.atom_words, cfg.row_words, cfg.atoms_per_row
    n_rows = max(1, n // R)
    atoms_last = min(n, R) // Na
    nb = max(1, cfg.num_buffers)
    for r in range(n_rows):
        out.append(Act(row_a + r))
        atoms = apr if n >= R else atoms_last
        for atm in range(atoms):
            buf = atm % nb
            out.append(ColRead(row_a + r, atm, buf))
            out.append(CMul(buf, buf))  # timed like a scalar multiply pass
            out.append(ColWrite(row_a + r, atm, buf))
    return out


def polymul_commands(cfg: PimConfig, n: int, row_a: int = 0, row_b: int | None = None):
    R = cfg.row_words
    rows = max(1, n // R)
    row_b = row_b if row_b is not None else row_a + rows
    fwd_a = RowCentricMapper(cfg, n, forward=True, base_row=row_a).commands()
    fwd_b = RowCentricMapper(cfg, n, forward=True, base_row=row_b).commands()
    point = pointwise_commands(cfg, n, row_a, row_b)
    inv_a = RowCentricMapper(cfg, n, forward=False, base_row=row_a).commands()
    scale = scaling_commands(cfg, n, row_a)
    return fwd_a + fwd_b + point + inv_a + scale, row_b


def pim_polymul(
    a: np.ndarray,
    b: np.ndarray,
    ctx: ntt_ref.NttContext,
    cfg: PimConfig | None = None,
) -> tuple[np.ndarray, TimingResult]:
    """Functional + timed polynomial multiplication on one bank."""
    cfg = cfg or PimConfig()
    n = a.shape[0]
    cmds, row_b = polymul_commands(cfg, n)

    # functional execution needs per-phase butterfly orientation: the
    # FunctionalBank resolves twiddles by direction, so run phase-wise.
    bank_f = FunctionalBank(cfg, ctx, forward=True)
    bank_f.load_poly(np.asarray(a, np.uint32), base_row=0)
    bank_f.load_poly(np.asarray(b, np.uint32), base_row=row_b)
    fwd_a = RowCentricMapper(cfg, n, forward=True, base_row=0).commands()
    fwd_b = RowCentricMapper(cfg, n, forward=True, base_row=row_b).commands()
    bank_f.run(fwd_a)
    bank_f.run(fwd_b)
    bank_f.run(pointwise_commands(cfg, n, 0, row_b))
    bank_i = FunctionalBank(cfg, ctx, forward=False)
    bank_i.mem = bank_f.mem  # share the memory image
    bank_i.run(RowCentricMapper(cfg, n, forward=False, base_row=0).commands())
    out = bank_i.read_poly(n)
    out = np.asarray(mm.np_mulmod(out, ctx.n_inv, ctx.q), np.uint32)

    timing = BankTimer(cfg).simulate(cmds)
    return out, timing


def pim_ntt_sharded(
    a: np.ndarray,
    ctx: ntt_ref.NttContext,
    cfg: PimConfig | None = None,
    banks: int = 2,
    forward: bool = False,
    scale_n_inv: bool = True,
    topo=None,
):
    """Execute one negacyclic NTT sharded over `banks` banks, bit-exactly.

    The four-step split of `repro.pimsys.sharded`: each bank runs its
    N/banks-point local `RowCentricMapper` stream (shifted twiddle bases)
    on its own `FunctionalBank`, and the cross-bank stages apply the
    shared-twiddle column butterflies between bank images.  Same
    orientation/scaling conventions as `pim_ntt`; at banks=1 the two are
    command-for-command identical.  Returns `(out, plan)` — time the
    plan with `plan.simulate()`.
    """
    from repro.pimsys.sharded import ShardedNttPlan

    cfg = cfg or PimConfig()
    a = np.asarray(a, np.uint32)
    plan = ShardedNttPlan(cfg, a.shape[0], banks, forward=forward, topo=topo)
    out = plan.run_functional(a, ctx)
    if not forward and scale_n_inv:
        out = np.asarray(mm.np_mulmod(out, ctx.n_inv, ctx.q), np.uint32)
    return out, plan


def polymul_batch(n: int, batch: int, cfg: PimConfig | None = None, policy: str = "rr"):
    """Time `batch` independent products on the device-level controller.

    One product per bank, banks contending on their channel's shared
    command bus; requests beyond `cfg` topology capacity (num_channels x
    num_ranks x num_banks) queue FIFO.  Returns the closed-loop
    `repro.pimsys.SchedulerResult` (latency percentiles, throughput,
    device stats).  Timing only — for functional output use `pim_polymul`.
    """
    from repro.pimsys.scheduler import PolymulJob, RequestScheduler

    cfg = cfg or PimConfig()
    sched = RequestScheduler(cfg, policy=policy)
    return sched.run_closed_loop([PolymulJob(n)] * batch)
