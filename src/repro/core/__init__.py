"""The paper's contribution: NTT algorithms, row-centric PIM mapping,
cycle-level simulation, area/energy models."""
