"""Reference NTT algorithms (host oracles + batched jnp implementations).

Two flavours are provided:

* **cyclic** NTT  X[k] = sum_j a[j] w^{jk} mod q  (w a primitive N-th root)
  — matches the textbook DFT-over-Z_q and the O(N^2) oracle.

* **negacyclic** ψ-merged NTT pair (Longa–Naehrig style): forward is
  Cooley–Tukey (natural order in → bit-reversed out, strides N/2..1),
  inverse is Gentleman–Sande (bit-reversed in → natural out, strides
  1..N/2).  ``INTT(NTT(a) ⊙ NTT(b))`` is negacyclic convolution, i.e.
  multiplication in Z_q[X]/(X^N+1) — the RLWE workload of the paper —
  with **no explicit bit reversal anywhere**, which is the paper's §II-B
  observation ("bit reversal can be avoided altogether when all
  NTT-domain operations are element-wise").

The paper's Algorithms 1–2 use the GS butterfly with increasing strides
(= our inverse dataflow, mirrored for the forward pass).  The stride
*set* {1, 2, ..., N/2} — which is what the row-centric mapping cares
about — is identical in both directions.

All stage loops operate on the LAST axis; leading axes are batch
("bank-level parallelism" in the paper).
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro.core import modmath as mm

# ---------------------------------------------------------------------------
# Twiddle context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash —
# make_context is lru_cached, so equal (q, n) share one instance and jit
# static-arg caching works despite the unhashable numpy table fields.
class NttContext:
    """Precomputed tables for a (q, n) negacyclic NTT.

    psi_brv[i]      = psi^brv(i)        (forward stage twiddles, slice [m:2m])
    psi_inv_brv[i]  = psi^-brv(i)       (inverse stage twiddles, slice [h:2h])
    *_shoup         = floor(w * 2^32 / q) companions for device-side Shoup mult
    """

    q: int
    n: int
    psi: int
    psi_inv: int
    n_inv: int
    psi_brv: np.ndarray
    psi_brv_shoup: np.ndarray
    psi_inv_brv: np.ndarray
    psi_inv_brv_shoup: np.ndarray
    n_inv_shoup: int
    qprime: int  # -q^-1 mod 2^32 (Montgomery)
    r2_mod_q: int  # 2^64 mod q

    @property
    def omega(self) -> int:
        return self.psi * self.psi % self.q


@functools.lru_cache(maxsize=None)
def make_context(q: int, n: int) -> NttContext:
    if n & (n - 1):
        raise ValueError("n must be a power of two")
    psi = mm.root_of_unity(q, 2 * n)
    psi_inv = mm.inv_mod(psi, q)
    n_inv = mm.inv_mod(n, q)
    brv = mm.bit_reverse_indices(n)
    psi_pows = mm.powers_of(psi, n, q)
    psi_inv_pows = mm.powers_of(psi_inv, n, q)
    psi_brv = psi_pows[brv].astype(np.uint32)
    psi_inv_brv = psi_inv_pows[brv].astype(np.uint32)
    sh = np.vectorize(lambda w: mm.shoup(int(w), q), otypes=[np.uint32])
    qprime, _, r2 = mm.mont_params(q)
    return NttContext(
        q=q,
        n=n,
        psi=psi,
        psi_inv=psi_inv,
        n_inv=n_inv,
        psi_brv=psi_brv,
        psi_brv_shoup=sh(psi_brv),
        psi_inv_brv=psi_inv_brv,
        psi_inv_brv_shoup=sh(psi_inv_brv),
        n_inv_shoup=mm.shoup(n_inv, q),
        qprime=qprime,
        r2_mod_q=r2,
    )


# ---------------------------------------------------------------------------
# O(N^2) oracles (numpy; small N only)
# ---------------------------------------------------------------------------


def naive_cyclic_ntt(a: np.ndarray, q: int, omega: int) -> np.ndarray:
    a = np.asarray(a, np.int64)
    n = a.shape[-1]
    jk = (np.arange(n)[:, None] * np.arange(n)[None, :]) % n
    w_pows = mm.powers_of(omega, n, q).astype(np.int64)
    mat = w_pows[jk]  # [k, j] = w^{jk}
    # Reduce each product mod q BEFORE summing (a plain matmul would
    # overflow int64 for n >= 4), then sum residues (< n * 2^31 << 2^63).
    prods = (a[..., None, :] * mat) % q  # [..., k, j]
    return np.asarray(prods.sum(axis=-1) % q, np.uint32)


def naive_negacyclic_ntt(a: np.ndarray, ctx: NttContext) -> np.ndarray:
    """X[k] = sum_j a[j] psi^j w^{jk}  (natural-order output)."""
    scaled = mm.np_mulmod(a, mm.powers_of(ctx.psi, ctx.n, ctx.q), ctx.q)
    return naive_cyclic_ntt(scaled, ctx.q, ctx.omega)


def schoolbook_negacyclic(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    """a*b mod (X^N + 1) by O(N^2) schoolbook — polymul oracle."""
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    n = a.shape[-1]
    out = np.zeros(n, np.int64)
    for i in range(n):
        prod = a[i] * b % q
        wrap = n - i
        out[i:] = (out[i:] + prod[:wrap]) % q
        out[:i] = (out[:i] - prod[wrap:]) % q  # X^N = -1
    return np.asarray(out % q, np.uint32)


# ---------------------------------------------------------------------------
# Stage plans (shared by numpy/jnp refs, the PIM mapper and the TPU kernel)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One butterfly stage over the last axis.

    blocks   : number of independent blocks (each has one twiddle)
    stride   : distance between butterfly partners
    tw_lo    : twiddle table slice start (table[tw_lo : tw_lo + blocks])
    gs       : True = Gentleman–Sande butterfly (a+b, (a-b)*w),
               False = Cooley–Tukey (a + w*b, a - w*b)
    """

    blocks: int
    stride: int
    tw_lo: int
    gs: bool


def forward_stages(n: int) -> list[Stage]:
    """CT forward, natural in -> bit-reversed out; strides N/2, N/4, ..., 1."""
    stages = []
    t, m = n, 1
    while m < n:
        t //= 2
        stages.append(Stage(blocks=m, stride=t, tw_lo=m, gs=False))
        m *= 2
    return stages


def inverse_stages(n: int) -> list[Stage]:
    """GS inverse, bit-reversed in -> natural out; strides 1, 2, ..., N/2.

    This is the paper's Algorithm 1/2 dataflow orientation (m increasing).
    """
    stages = []
    t, m = 1, n
    while m > 1:
        h = m // 2
        stages.append(Stage(blocks=h, stride=t, tw_lo=h, gs=True))
        t *= 2
        m //= 2
    return stages


def _np_stage(a: np.ndarray, stage: Stage, table: np.ndarray, q: int) -> np.ndarray:
    """Apply one stage over the last axis (numpy int64 exact)."""
    lead = a.shape[:-1]
    n = a.shape[-1]
    tw = table[stage.tw_lo : stage.tw_lo + stage.blocks].astype(np.int64)
    x = a.reshape(*lead, stage.blocks, 2, stage.stride).astype(np.int64)
    u, v = x[..., 0, :], x[..., 1, :]
    w = tw[:, None]
    if stage.gs:
        out0 = (u + v) % q
        out1 = (u - v) * w % q
    else:
        wv = v * w % q
        out0 = (u + wv) % q
        out1 = (u - wv) % q
    out = np.stack([out0, out1], axis=-2) % q
    return np.asarray(out.reshape(*lead, n), np.uint32)


def ntt_forward_np(a: np.ndarray, ctx: NttContext) -> np.ndarray:
    """Negacyclic forward NTT, natural in -> bit-reversed out."""
    x = np.asarray(a, np.uint32)
    for st in forward_stages(ctx.n):
        x = _np_stage(x, st, ctx.psi_brv, ctx.q)
    return x


def ntt_inverse_np(a: np.ndarray, ctx: NttContext) -> np.ndarray:
    """Negacyclic inverse NTT, bit-reversed in -> natural out (scaled by 1/N)."""
    x = np.asarray(a, np.uint32)
    for st in inverse_stages(ctx.n):
        x = _np_stage(x, st, ctx.psi_inv_brv, ctx.q)
    return np.asarray(mm.np_mulmod(x, ctx.n_inv, ctx.q), np.uint32)


def polymul_negacyclic_np(a, b, ctx: NttContext) -> np.ndarray:
    """a*b in Z_q[X]/(X^N+1) via eq. (1) of the paper."""
    ah = ntt_forward_np(a, ctx)
    bh = ntt_forward_np(b, ctx)
    return ntt_inverse_np(mm.np_mulmod(ah, bh, ctx.q), ctx)


# -- cyclic wrappers (match the naive DFT oracle) ---------------------------


def cyclic_ntt_np(a: np.ndarray, q: int, n: int | None = None) -> np.ndarray:
    """Cyclic NTT (natural in -> natural out); equals naive_cyclic_ntt.

    Implemented through the negacyclic machinery: since
    NTT_neg(a)[k] = sum_j a[j] psi^j w^{jk}, scaling the input by psi^{-j}
    gives the plain cyclic transform; the forward pass emits bit-reversed
    order, which we undo at the end.
    """
    a = np.asarray(a, np.uint32)
    n = n or a.shape[-1]
    ctx = make_context(q, n)
    psi_inv_pows = mm.powers_of(ctx.psi_inv, n, q)
    scaled = np.asarray(mm.np_mulmod(a, psi_inv_pows, q), np.uint32)
    brv = mm.bit_reverse_indices(n)
    out = ntt_forward_np(scaled, ctx)
    inv_perm = np.argsort(brv)
    return out[..., inv_perm]


# ---------------------------------------------------------------------------
# jnp batched implementation (uint32 limb arithmetic — used as kernels oracle)
# ---------------------------------------------------------------------------


def _jnp_stage(x, stage: Stage, table, table_shoup, q: int):
    lead = x.shape[:-1]
    n = x.shape[-1]
    tw = jnp.asarray(table[stage.tw_lo : stage.tw_lo + stage.blocks])
    tw_sh = jnp.asarray(table_shoup[stage.tw_lo : stage.tw_lo + stage.blocks])
    xr = x.reshape(*lead, stage.blocks, 2, stage.stride)
    u, v = xr[..., 0, :], xr[..., 1, :]
    w = tw[:, None]
    w_sh = tw_sh[:, None]
    if stage.gs:
        out0 = mm.addmod_u32(u, v, q)
        out1 = mm.shoup_mulmod_u32(mm.submod_u32(u, v, q), w, w_sh, q)
    else:
        wv = mm.shoup_mulmod_u32(v, w, w_sh, q)
        out0 = mm.addmod_u32(u, wv, q)
        out1 = mm.submod_u32(u, wv, q)
    return jnp.stack([out0, out1], axis=-2).reshape(*lead, n)


def ntt_forward_jnp(a, ctx: NttContext):
    x = jnp.asarray(a, jnp.uint32)
    for st in forward_stages(ctx.n):
        x = _jnp_stage(x, st, ctx.psi_brv, ctx.psi_brv_shoup, ctx.q)
    return x


def ntt_inverse_jnp(a, ctx: NttContext):
    x = jnp.asarray(a, jnp.uint32)
    for st in inverse_stages(ctx.n):
        x = _jnp_stage(x, st, ctx.psi_inv_brv, ctx.psi_inv_brv_shoup, ctx.q)
    n_inv = jnp.uint32(ctx.n_inv)
    n_inv_sh = jnp.uint32(ctx.n_inv_shoup)
    return mm.shoup_mulmod_u32(x, n_inv, n_inv_sh, ctx.q)


def polymul_negacyclic_jnp(a, b, ctx: NttContext):
    ah = ntt_forward_jnp(a, ctx)
    bh = ntt_forward_jnp(b, ctx)
    qprime, _, r2 = ctx.qprime, None, ctx.r2_mod_q
    prod = mm.mulmod_u32(ah, bh, ctx.q, qprime, r2)
    return ntt_inverse_jnp(prod, ctx)


# ---------------------------------------------------------------------------
# Four-step (transpose) decomposition — the TPU-friendly inter-row alternative
# ---------------------------------------------------------------------------


def four_step_cyclic_np(a: np.ndarray, q: int, n1: int, n2: int) -> np.ndarray:
    """Cyclic NTT of size n1*n2 as: columns-NTT(n2), twiddle, rows-NTT(n1), T.

    Input natural order with n = i1*n2 + i2 ... we use the standard
    decomposition with input read as a (n1 x n2) row-major matrix:
      X[k2*n1 + k1] = NTT1_{n1, rows->k1}( w_N^{j1*k2} * NTT2_{n2, cols j1} )
    """
    n = n1 * n2
    a = np.asarray(a, np.uint32).reshape(n1, n2)
    # step 1: size-n1 NTT down each column (axis 0)
    step1 = cyclic_ntt_np(a.T, q, n1)  # shape (n2, n1), rows are columns of a
    # step 2: twiddle w_N^{j... } — indices (k1, j2)
    w = mm.root_of_unity(q, n)
    k1 = np.arange(n1)[None, :]
    j2 = np.arange(n2)[:, None]
    tw = mm.np_powmod(w, (k1 * j2) % n, q)
    step2 = mm.np_mulmod(step1, tw, q)
    # step 3: size-n2 NTT along rows of the (n2, n1) matrix's other axis:
    step3 = cyclic_ntt_np(step2.T, q, n2)  # (n1, n2)
    # step 4: output X[k2*n1 + k1] -> transpose to natural order
    return np.asarray(step3.T.reshape(n), np.uint32)
