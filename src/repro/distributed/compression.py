"""Gradient compression for the cross-pod (DCN) all-reduce.

At 2+ pods the gradient all-reduce crosses the data-center network,
which is an order of magnitude slower than ICI.  We provide:

  * `ef_compress / ef_decompress` — int8 quantization with per-tensor
    scale and an error-feedback residual (the standard EF-SGD trick that
    keeps convergence unbiased over time);
  * `compressed_psum` — a shard_map-compatible psum that quantizes to
    int8, sums in int32 (exact), and dequantizes; wire bytes drop 4x vs
    fp32 / 2x vs bf16;
  * `hierarchical_grad_sync` — reduce in full precision over the
    intra-pod 'data' axis first, then compressed over 'pod' (gradient
    magnitudes shrink after intra-pod averaging, improving quantization
    SNR).

Off by default; enabled per-run via TrainLoopConfig.compress_grads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_compress(g, residual):
    """(g + residual) -> int8 code + scale, new residual."""
    target = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(target)) / 127.0, 1e-12)
    code = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    decoded = code.astype(jnp.float32) * scale
    return code, scale, target - decoded


def ef_decompress(code, scale):
    return code.astype(jnp.float32) * scale


def compressed_psum(g, axis_name: str):
    """int8-quantized psum over `axis_name` (for use inside shard_map).

    The int32 accumulation is exact; quantization error is the only loss
    and is bounded by scale/2 per element.  Scales are max-combined
    across participants so all ranks decode identically.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0, 1e-12)
    scale = jax.lax.pmax(scale, axis_name)
    code = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(code.astype(jnp.int32), axis_name)
    return summed.astype(jnp.float32) * scale


def hierarchical_grad_sync(grads, intra_axis: str = "data", inter_axis: str = "pod"):
    """Full-precision pmean intra-pod, compressed psum across pods.

    For use inside shard_map(train_step) when gradients are computed
    per-device; under plain pjit the partitioner owns the all-reduce and
    this path is bypassed (documented trade-off in DESIGN.md §5)."""

    def sync(g):
        g = jax.lax.pmean(g, intra_axis)
        npods = jax.lax.axis_size(inter_axis)
        return compressed_psum(g, inter_axis) / npods

    return jax.tree.map(sync, grads)
