"""Sharding rules: parameter/optimizer/cache/batch PartitionSpecs.

Parallelism map (DESIGN.md §5):
  data axes ("pod", "data")  : DP for activations + FSDP (ZeRO-3) for
                               params/optimizer state
  model axis ("model")       : TP for attention heads & MLP hidden, EP
                               for MoE experts, sequence-sharding for
                               long-context KV caches

Rules are name+shape based and *divisibility-checked*: an axis that does
not divide the dimension is dropped (replicated) rather than producing
an invalid sharding — e.g. mamba2-780m's 48 SSD heads shard over
model=16, but a 12-head whisper config falls back to replication.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_NAME_RE = re.compile(r"\['([^']+)'\]")


def _leaf_name(path: str) -> str:
    names = _NAME_RE.findall(path)
    return names[-1] if names else path


def dp_axes(mesh: Mesh):
    """The combined data-parallel (FSDP) axes present in the mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _fits(mesh: Mesh, axes, dim: int) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0


def _sanitize(mesh: Mesh, spec: P, shape) -> P:
    out = []
    for axes, dim in zip(spec, shape):
        out.append(axes if _fits(mesh, axes, dim) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules (applied to path strings from tree_flatten_with_path)
# ---------------------------------------------------------------------------


def _param_spec(path: str, ndim: int, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    dp = dp if dp else None

    def stacked(*spec):
        """Block params carry a leading (reps,) stack dim."""
        return P(None, *spec) if "blocks" in path else P(*spec)

    leaf = _leaf_name(path)
    if "embed" in path and ndim == 2:
        # vocab over FSDP (big dim), d over model: keeps the gather output's
        # batch dim free to follow the tokens' data sharding.
        return P(dp, "model")
    if "lm_head" in path:
        return P(dp, "model")  # d FSDP-gathered at use, vocab over TP
    if leaf in ("wq", "wk", "wv"):
        return stacked(dp, "model")
    if leaf == "wo" and "mixer" in path or leaf == "wo" and "cross" in path:
        return stacked("model", dp)
    if leaf == "router":
        return stacked(dp, None)
    if leaf in ("wi", "wg"):
        if ndim - ("blocks" in path) == 3:  # MoE (E, D, F): experts over model
            return stacked("model", dp, None)
        return stacked(dp, "model")
    if leaf == "wo":  # ffn down-projection
        if ndim - ("blocks" in path) == 3:  # MoE (E, F, D)
            return stacked("model", None, dp)
        return stacked("model", dp)
    if leaf == "in_proj":
        return stacked(dp, "model")
    if leaf == "out_proj":
        return stacked("model", dp)
    if leaf == "conv_w":
        return stacked(None, "model")
    if leaf in ("a_log", "skip_d", "dt_bias"):
        return stacked("model")
    # norms, biases, scalars: replicate (beyond the stack dim)
    return stacked(*([None] * (ndim - ("blocks" in path))))


def param_shardings(mesh: Mesh, params_shape) -> dict:
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree."""

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        spec = _param_spec(pstr, leaf.ndim, mesh)
        spec = _sanitize(mesh, P(*spec, *([None] * (leaf.ndim - len(spec)))), leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_shardings(mesh: Mesh, opt_shape, params_shape=None) -> dict:
    """Optimizer moments follow their parameter's sharding (same shapes).

    Adafactor's factored vectors drop the factored-out dim from the
    parameter spec: vr = spec[:-1], vc = spec[:-2] + spec[-1:] — without
    this, a 1T-param MoE's row factors would replicate at ~TB scale."""

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        leaf_name = _leaf_name(pstr)
        if leaf_name == "vr":
            spec = _param_spec(pstr, leaf.ndim + 1, mesh)
            spec = P(*(tuple(spec) + (None,) * (leaf.ndim + 1 - len(spec)))[:-1])
        elif leaf_name == "vc":
            full = _param_spec(pstr, leaf.ndim + 1, mesh)
            full = tuple(full) + (None,) * (leaf.ndim + 1 - len(full))
            spec = P(*(full[:-2] + full[-1:]))
        else:
            spec = _param_spec(pstr, leaf.ndim, mesh)
            spec = P(*(tuple(spec) + (None,) * (leaf.ndim - len(spec)))[: leaf.ndim])
        return NamedSharding(mesh, _sanitize(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, opt_shape)


# ---------------------------------------------------------------------------
# batch / activation / cache rules
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_shape) -> dict:
    dp = dp_axes(mesh) or None

    def one(path, leaf):
        spec = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, _sanitize(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape) -> list:
    """KV caches: batch over DP; cache LENGTH over model (sequence
    sharding — kv-head counts (8) don't divide model=16, and length
    sharding keeps the 32k/500k caches within per-device HBM; XLA inserts
    the partial-softmax all-reduce).  SSM states: heads over model."""
    dp = dp_axes(mesh) or None

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if "conv" in pstr:  # (reps, B, W-1, xbc)
            spec = P(None, dp, None, "model")
        elif "state" in pstr:  # (reps, B, H, P, N)
            spec = P(None, dp, "model", None, None)
        elif leaf.ndim == 5:
            spec = P(None, dp, "model", None, None)
        else:  # (reps, B, L, KV, hd) attn / cross caches
            spec = P(None, dp, "model", None, None)
        spec = P(*spec[: leaf.ndim])
        return NamedSharding(mesh, _sanitize(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def named(mesh: Mesh, spec: P, shape) -> NamedSharding:
    """Divisibility-sanitized NamedSharding for an explicit spec."""
    spec = P(*spec[: len(shape)], *([None] * max(0, len(shape) - len(spec))))
    return NamedSharding(mesh, _sanitize(mesh, spec, shape))


def logits_spec(mesh: Mesh) -> P:
    dp = dp_axes(mesh) or None
    return P(dp, None, "model")


# ---------------------------------------------------------------------------
# in-graph activation constraints (no-ops when no mesh is active: CPU tests)
# ---------------------------------------------------------------------------

_ROLES = {
    # role -> spec builder given (mesh, ndim)
    "tokens_act": lambda dp: P(dp, None, None),
    "logits": lambda dp: P(dp, None, "model"),
    "moe_buffer": lambda dp: P("model", dp, None),
    "moe_hidden": lambda dp: P("model", dp, None),
    # local-dispatch MoE: (blocks, E, cap, d) — blocks over DP, experts over
    # model; building this from block-local tokens is ONE all-to-all.
    "moe_buffer_local": lambda dp: P(dp, "model", None, None),
    "moe_hidden_local": lambda dp: P(dp, "model", None, None),
    "moe_tokens_local": lambda dp: P(dp, None, None),
}


def _ambient_mesh() -> Mesh | None:
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover
        return None


def maybe_constrain(x, role: str):
    """with_sharding_constraint(x, role-spec) if a mesh is ambient.

    Divisibility-sanitized like the parameter rules; silently a no-op in
    single-device (test) runs so model code stays mesh-agnostic."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    dp = dp_axes(mesh) or None
    spec = _ROLES[role](dp)
    spec = P(*spec[: x.ndim], *([None] * max(0, x.ndim - len(spec))))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _sanitize(mesh, spec, x.shape))
    )
