"""Device-level topology of an NTT-PIM memory system.

The paper evaluates one bank and predicts near-linear multi-bank speedup
(§VII); `repro.pimsys` models the layer above: a device is

    channels × ranks × banks_per_rank

where every channel owns ONE shared command/address bus (the contention
resource of `core.pimsim.simulate_multibank`'s analytic bound) and banks
are the paper's row-centric NTT-PIM banks.  The address mapper follows
the HBM-PIMulator convention of channel-interleaving consecutive
resources so independent jobs spread across buses first.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple

from repro.core.pim_config import PimConfig


class BankAddress(NamedTuple):
    """Physical location of one bank: (channel, rank, bank-in-rank)."""

    channel: int
    rank: int
    bank: int


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """channels × ranks × banks_per_rank, parameterized from `PimConfig`."""

    channels: int = 1
    ranks: int = 1
    banks_per_rank: int = 1

    def __post_init__(self):
        for name in ("channels", "ranks", "banks_per_rank"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")

    @classmethod
    def from_config(cls, cfg: PimConfig) -> "DeviceTopology":
        return cls(
            channels=cfg.num_channels,
            ranks=cfg.num_ranks,
            banks_per_rank=max(1, cfg.num_banks),
        )

    # -- sizes ---------------------------------------------------------------
    @property
    def banks_per_channel(self) -> int:
        return self.ranks * self.banks_per_rank

    @property
    def total_banks(self) -> int:
        return self.channels * self.banks_per_channel

    # -- flat id <-> physical address (channel-interleaved) ------------------
    def address_of(self, flat: int) -> BankAddress:
        """Flat bank id -> (channel, rank, bank).

        Channel bits are the LOW bits (HBM-PIMulator-style interleaving):
        consecutive flat ids land on different channels, so a scheduler
        filling banks in order naturally balances the per-channel buses.
        """
        if not 0 <= flat < self.total_banks:
            raise IndexError(f"bank id {flat} out of range [0, {self.total_banks})")
        ch = flat % self.channels
        within = flat // self.channels
        return BankAddress(ch, within // self.banks_per_rank, within % self.banks_per_rank)

    def flat_of(self, addr: BankAddress) -> int:
        if not (0 <= addr.channel < self.channels
                and 0 <= addr.rank < self.ranks
                and 0 <= addr.bank < self.banks_per_rank):
            raise IndexError(f"{addr} out of range for {self}")
        within = addr.rank * self.banks_per_rank + addr.bank
        return within * self.channels + addr.channel

    def banks(self) -> Iterator[BankAddress]:
        """All bank addresses in flat-id (channel-interleaved) order."""
        for flat in range(self.total_banks):
            yield self.address_of(flat)

    def local_id(self, addr: BankAddress) -> int:
        """Bank index within its channel (the controller's bank key)."""
        return addr.rank * self.banks_per_rank + addr.bank

    def flat_from_local(self, channel: int, local: int) -> int:
        """Inverse of (address_of, local_id): channel + in-channel id -> flat."""
        return local * self.channels + channel

    def channel_of(self, flat: int) -> int:
        """Channel owning flat bank id (the bus a transfer to/from it holds)."""
        return self.address_of(flat).channel

    def describe(self) -> str:
        return (f"{self.channels}ch x {self.ranks}rk x {self.banks_per_rank}ba "
                f"= {self.total_banks} banks "
                f"({self.banks_per_channel}/channel bus)")
