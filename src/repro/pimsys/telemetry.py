"""Device telemetry: command spans, Perfetto export, windowed metrics.

NTT-PIM's performance story is a *timeline* story — in-place updates and
multi-buffer pipelining win by overlapping row activations, column
bursts, and CU ops — but counters only say *how much*, never *when*.
This module adds the missing axis as an opt-in, zero-overhead-when-off
layer over the whole issue hierarchy:

  * `Tracer` — a passive record sink.  Engines hold `tracer=None` by
    default and guard every append with one `is not None` check, so the
    hot loop (`benchmarks/engine_speed.py` floors it) pays nothing when
    telemetry is off.  Enabled via `PimConfig.telemetry` (session runs)
    or `ServicePolicy.telemetry` (service dispatch).  Three record
    families: per-command issue events (channel/bank track, bus-wait and
    hazard-stall attribution, param-cache hit/miss), per-phase spans
    (local NTT passes, exchange stages), and per-request lifecycle spans
    (queue/coalesce wait -> execute, tagged with qos and request id).
  * `TelemetryHandle` — the result-side view, attached to
    `RunResult.telemetry` / `SchedulerResult.telemetry`.  Exports the
    Chrome trace-event JSON dialect (banks and buses as tracks, requests
    as async spans — loads in Perfetto / `chrome://tracing`) and a
    compact JSONL dialect for large runs, and answers reconciliation
    queries (`command_totals` vs `StatsRegistry`, `request_breakdown`
    for the critical-path report).
  * `WindowedSeries` / `Reservoir` — tumbling-window time series (queue
    depth per class, bus utilization per channel, param-cache hit rate,
    bank occupancy, admission rejects) and a deterministic reservoir
    sample for percentile summaries; `device_series` derives the
    device-side series from a finished tracer, and the scheduler
    attaches them to `StatsRegistry` so `summary()` carries the
    timeline.

`scripts/report_telemetry.py` renders an exported trace as a text
report: per-request critical-path breakdown plus top-stall attribution.
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import IO, Mapping

# synthetic track pids of the Chrome trace export (real channels are
# small non-negative ints, so these can never collide)
PHASE_PID = 900000
REQUEST_PID = 900001
BUS_TID = 255  # per-channel bus track (bank tids are small)

# command-class name -> StatsRegistry per-bank counter key
STAT_KEY = {
    "Act": "act",
    "ColRead": "col_read",
    "ColWrite": "col_write",
    "C1": "c1",
    "C2": "c2",
    "CMul": "cmul",
    "WordLoad": "word_load",
    "WordStore": "word_store",
    "BUWord": "bu_word",
}

# param-cache codes, mirroring engine._P_NONE/_P_MISS/_P_HIT
_CODE_NAME = {1: "miss", 2: "hit"}


class Tracer:
    """Passive telemetry sink the engines append to when enabled.

    Records are plain tuples appended by the hot loop (no method-call
    overhead where it matters):

      commands       (channel, bank, name, gate, grant, start, done,
                      param_ns, code) — one per issued command.  `gate`
                      is dispatch visibility, `grant` the bus grant, so
                      `grant - gate` is bus wait and `start - grant` the
                      rank/bank hazard stall (incl. parameter beats).
      bursts         (ch_src, ch_dst, start, end) — inter-bank atom
                      bursts over the shared bus(es).
      phases         (track, name, start, end) — local passes, exchange
                      stages, `BankTimer` Mark segments.
      request_spans  (rid, qos, name, start, end) — request lifecycle.
      request_events (rid, qos, name, t) — instants (admission rejects).
    """

    __slots__ = ("commands", "bursts", "phases", "request_spans",
                 "request_events", "meta")

    def __init__(self):
        self.commands: list[tuple] = []
        self.bursts: list[tuple] = []
        self.phases: list[tuple] = []
        self.request_spans: list[tuple] = []
        self.request_events: list[tuple] = []
        self.meta: dict = {}

    # cold-path helpers (the hot loop appends to the lists directly)
    def phase(self, track: str, name: str, start: float, end: float) -> None:
        self.phases.append((track, name, start, end))

    def request_span(self, rid: int, qos: str, name: str,
                     start: float, end: float) -> None:
        self.request_spans.append((rid, qos, name, start, end))

    def request_event(self, rid: int, qos: str, name: str, t: float) -> None:
        self.request_events.append((rid, qos, name, t))

    def __len__(self) -> int:
        return (len(self.commands) + len(self.bursts) + len(self.phases)
                + len(self.request_spans) + len(self.request_events))


# --------------------------------------------------------------------------
# Windowed time-series metrics
# --------------------------------------------------------------------------


class WindowedSeries:
    """Tumbling-window aggregation of timestamped samples.

    Aggregations: ``mean`` (sample mean per window — hit rates,
    attainment), ``sum`` (event counts — rejects), ``max`` (peak queue
    depth), ``occupancy`` (busy-time accumulated via `record_span`,
    divided by the window length — bus/bank utilization in [0, 1+]).
    """

    AGGS = ("mean", "sum", "max", "occupancy")

    __slots__ = ("window_ns", "agg", "_buckets")

    def __init__(self, window_ns: float, agg: str = "mean"):
        if window_ns <= 0:
            raise ValueError("window_ns must be positive")
        if agg not in self.AGGS:
            raise ValueError(f"agg must be one of {self.AGGS}, got {agg!r}")
        self.window_ns = float(window_ns)
        self.agg = agg
        # mean: [sum, count]; sum/occupancy: float; max: float
        self._buckets: dict[int, object] = {}

    def record(self, t_ns: float, value: float = 1.0) -> None:
        w = int(t_ns // self.window_ns)
        b = self._buckets
        if self.agg == "mean":
            acc = b.get(w)
            if acc is None:
                b[w] = [value, 1]
            else:
                acc[0] += value
                acc[1] += 1
        elif self.agg == "max":
            cur = b.get(w)
            if cur is None or value > cur:
                b[w] = value
        else:  # sum / occupancy accumulate
            b[w] = b.get(w, 0.0) + value

    def record_span(self, start_ns: float, end_ns: float) -> None:
        """Accumulate a busy interval, split across window boundaries
        (``occupancy``/``sum`` aggregations)."""
        if end_ns <= start_ns:
            return
        win = self.window_ns
        w = int(start_ns // win)
        t = start_ns
        b = self._buckets
        while t < end_ns:
            edge = (w + 1) * win
            seg = min(end_ns, edge) - t
            b[w] = b.get(w, 0.0) + seg
            t, w = edge, w + 1

    def points(self) -> list[tuple[float, float]]:
        """Sorted [(window_start_ns, value), ...]."""
        out = []
        for w in sorted(self._buckets):
            acc = self._buckets[w]
            if self.agg == "mean":
                v = acc[0] / acc[1]
            elif self.agg == "occupancy":
                v = acc / self.window_ns
            else:
                v = acc
            out.append((w * self.window_ns, float(v)))
        return out

    def points_us(self) -> list[list[float]]:
        """JSON-friendly [[window_start_us, value], ...]."""
        return [[t / 1e3, v] for t, v in self.points()]

    def __len__(self) -> int:
        return len(self._buckets)


class Reservoir:
    """Fixed-size deterministic reservoir sample with percentiles.

    Reservoir sampling with a private xorshift32 stream (no global RNG
    state, no `random` import) so repeated runs summarize identically.
    """

    __slots__ = ("k", "n", "values", "_state")

    def __init__(self, k: int = 256, seed: int = 0x9E3779B9):
        if k < 1:
            raise ValueError("k must be >= 1")
        self.k = k
        self.n = 0
        self.values: list[float] = []
        self._state = (seed & 0xFFFFFFFF) or 1

    def _rand(self) -> int:
        x = self._state
        x ^= (x << 13) & 0xFFFFFFFF
        x ^= x >> 17
        x ^= (x << 5) & 0xFFFFFFFF
        self._state = x
        return x

    def add(self, value: float) -> None:
        self.n += 1
        if len(self.values) < self.k:
            self.values.append(float(value))
        else:
            j = self._rand() % self.n
            if j < self.k:
                self.values[j] = float(value)

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile of the sample (q in [0, 100])."""
        if not self.values:
            return 0.0
        xs = sorted(self.values)
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def __len__(self) -> int:
        return len(self.values)


def device_series(tracer: Tracer, window_ns: float) -> dict[str, WindowedSeries]:
    """Derive the device-side windowed series from a finished tracer.

    Returns ``bus_occupancy/ch<c>`` per channel (command + parameter +
    burst beats on the shared bus), ``param_hit_rate`` (mean of hit=1 /
    miss=0 per window), and ``bank_occupancy`` (command-busy time summed
    over banks, normalized per bank — can exceed 1 transiently because
    the pipelined bank engine overlaps CU and column work).
    """
    dram_ns = float(tracer.meta.get("dram_ns", 0.0))
    bus: dict[int, WindowedSeries] = {}
    hits = WindowedSeries(window_ns, "mean")
    bank_busy = WindowedSeries(window_ns, "occupancy")
    banks = set()

    def bus_of(ch: int) -> WindowedSeries:
        s = bus.get(ch)
        if s is None:
            s = bus[ch] = WindowedSeries(window_ns, "occupancy")
        return s

    for ch, bank, _name, _gate, _grant, s, done, param_ns, code in tracer.commands:
        # the command holds the bus for its parameter beats + one beat
        bus_of(ch).record_span(s - param_ns, s + dram_ns)
        bank_busy.record_span(s, done)
        banks.add((ch, bank))
        if code:
            hits.record(s, 1.0 if code == 2 else 0.0)
    for ch_src, ch_dst, s, end in tracer.bursts:
        bus_of(ch_src).record_span(s, end)
        if ch_dst != ch_src:
            bus_of(ch_dst).record_span(s, end)

    out: dict[str, WindowedSeries] = {
        f"bus_occupancy/ch{ch}": s for ch, s in sorted(bus.items())
    }
    if len(hits):
        out["param_hit_rate"] = hits
    if len(bank_busy) and banks:
        # normalize the per-device busy sum to a per-bank occupancy
        norm = WindowedSeries(window_ns, "occupancy")
        n_banks = len(banks)
        for w, acc in bank_busy._buckets.items():
            norm._buckets[w] = acc / n_banks
        out["bank_occupancy"] = norm
    return out


# --------------------------------------------------------------------------
# Export: Chrome trace-event JSON (Perfetto) + JSONL dialect
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TelemetryHandle:
    """Result-side view of one run's tracer (`RunResult.telemetry` /
    `SchedulerResult.telemetry`)."""

    tracer: Tracer

    # -- reconciliation views ------------------------------------------------
    def command_totals(self) -> dict[tuple[int, int], dict]:
        """Per-(channel, bank): command counts by stats key + busy ns.

        The reconciliation view: with telemetry on, these counts equal
        the `StatsRegistry` per-bank command counters for the same run
        (asserted in `tests/test_telemetry.py`).
        """
        out: dict[tuple[int, int], dict] = defaultdict(
            lambda: {"commands": 0, "busy_ns": 0.0})
        for ch, bank, name, _g, _gr, s, done, _pn, _c in self.tracer.commands:
            d = out[(ch, bank)]
            key = STAT_KEY.get(name, name)
            d[key] = d.get(key, 0) + 1
            d["commands"] += 1
            d["busy_ns"] += done - s
        return dict(out)

    def request_breakdown(self) -> list[dict]:
        """Per-request lifecycle span table, sorted by request id.

        Each row: rid, qos, per-span durations (ns), end-to-end total,
        and `attributed` — the fraction of the total covered by named
        spans (the report script's >= 95% acceptance gate).
        """
        spans: dict[int, dict] = {}
        for rid, qos, name, start, end in self.tracer.request_spans:
            row = spans.setdefault(
                rid, {"rid": rid, "qos": qos, "spans": {},
                      "t0": start, "t1": end})
            row["spans"][name] = row["spans"].get(name, 0.0) + (end - start)
            if start < row["t0"]:
                row["t0"] = start
            if end > row["t1"]:
                row["t1"] = end
        out = []
        for rid in sorted(spans):
            row = spans[rid]
            total = row["t1"] - row["t0"]
            covered = sum(row["spans"].values())
            out.append({
                "rid": rid,
                "qos": row["qos"],
                "spans": row["spans"],
                "total_ns": total,
                "attributed": (covered / total) if total > 0 else 1.0,
            })
        return out

    def series(self, window_ns: float = 50_000.0) -> dict[str, WindowedSeries]:
        """Windowed device series (see `device_series`)."""
        return device_series(self.tracer, window_ns)

    # -- Chrome trace-event / Perfetto JSON ----------------------------------
    def chrome_trace(self) -> dict:
        """The trace as a Chrome trace-event document (Perfetto loads
        it).  Channels are processes, banks and the shared bus are their
        threads; phases and requests live on synthetic processes, with
        requests as async ("b"/"e") spans keyed by request id.
        """
        tr = self.tracer
        ev: list[dict] = []
        chans: set[int] = set()
        banks: set[tuple[int, int]] = set()
        bus_chans: set[int] = set()

        for ch, bank, name, gate, grant, s, done, param_ns, code in tr.commands:
            chans.add(ch)
            banks.add((ch, bank))
            args = {
                "bus_wait_us": (grant - gate) / 1e3,
                "stall_us": (s - grant) / 1e3,
            }
            if code:
                args["param"] = _CODE_NAME.get(code, str(code))
            if param_ns:
                args["param_us"] = param_ns / 1e3
            ev.append({"name": name, "cat": "cmd", "ph": "X",
                       "pid": ch, "tid": bank,
                       "ts": s / 1e3, "dur": (done - s) / 1e3, "args": args})
        for ch_src, ch_dst, s, end in tr.bursts:
            chans.add(ch_src)
            bus_chans.add(ch_src)
            ev.append({"name": "burst", "cat": "bus", "ph": "X",
                       "pid": ch_src, "tid": BUS_TID,
                       "ts": s / 1e3, "dur": (end - s) / 1e3,
                       "args": {"dst_channel": ch_dst}})
            if ch_dst != ch_src:
                chans.add(ch_dst)
                bus_chans.add(ch_dst)
                ev.append({"name": "burst", "cat": "bus", "ph": "X",
                           "pid": ch_dst, "tid": BUS_TID,
                           "ts": s / 1e3, "dur": (end - s) / 1e3,
                           "args": {"src_channel": ch_src}})

        tracks: dict[str, int] = {}
        for track, name, start, end in tr.phases:
            tid = tracks.setdefault(track, len(tracks))
            ev.append({"name": name, "cat": "phase", "ph": "X",
                       "pid": PHASE_PID, "tid": tid,
                       "ts": start / 1e3, "dur": (end - start) / 1e3,
                       "args": {}})
        for rid, qos, name, start, end in tr.request_spans:
            common = {"name": name, "cat": "request", "id": int(rid),
                      "pid": REQUEST_PID, "tid": 0}
            ev.append({**common, "ph": "b", "ts": start / 1e3,
                       "args": {"qos": qos}})
            ev.append({**common, "ph": "e", "ts": end / 1e3, "args": {}})
        for rid, qos, name, t in tr.request_events:
            ev.append({"name": name, "cat": "request", "ph": "i", "s": "g",
                       "pid": REQUEST_PID, "tid": 0, "ts": t / 1e3,
                       "args": {"rid": int(rid), "qos": qos}})

        # track naming metadata (processes, then threads)
        meta: list[dict] = []
        for ch in sorted(chans):
            meta.append({"name": "process_name", "ph": "M", "pid": ch,
                         "args": {"name": f"channel {ch}"}})
        for ch, bank in sorted(banks):
            meta.append({"name": "thread_name", "ph": "M", "pid": ch,
                         "tid": bank, "args": {"name": f"bank {bank}"}})
        for ch in sorted(bus_chans):
            meta.append({"name": "thread_name", "ph": "M", "pid": ch,
                         "tid": BUS_TID, "args": {"name": "bus"}})
        if tracks:
            meta.append({"name": "process_name", "ph": "M", "pid": PHASE_PID,
                         "args": {"name": "phases"}})
            for track, tid in tracks.items():
                meta.append({"name": "thread_name", "ph": "M",
                             "pid": PHASE_PID, "tid": tid,
                             "args": {"name": track}})
        if tr.request_spans or tr.request_events:
            meta.append({"name": "process_name", "ph": "M", "pid": REQUEST_PID,
                         "args": {"name": "requests"}})

        return {
            "traceEvents": meta + ev,
            "displayTimeUnit": "ns",
            "otherData": {"schema": "ntt-pim-telemetry-v1", **tr.meta},
        }

    def dumps(self) -> str:
        return json.dumps(self.chrome_trace(), separators=(",", ":"))

    def dump(self, f: IO[str] | str) -> None:
        """Write the Chrome trace-event JSON (open it in Perfetto)."""
        if isinstance(f, str):
            with open(f, "w") as fh:
                self.dump(fh)
            return
        json.dump(self.chrome_trace(), f, separators=(",", ":"))

    def dump_jsonl(self, f: IO[str] | str) -> None:
        """Compact JSONL dialect: one record per line, keyed by kind
        (``cmd`` / ``burst`` / ``phase`` / ``span`` / ``event`` /
        ``meta``) — the large-run format (no document-level nesting, so
        it streams)."""
        if isinstance(f, str):
            with open(f, "w") as fh:
                self.dump_jsonl(fh)
            return
        dump = json.dumps
        tr = self.tracer
        f.write(dump({"k": "meta", **tr.meta}, separators=(",", ":")) + "\n")
        for ch, bank, name, gate, grant, s, done, pn, code in tr.commands:
            f.write(dump({"k": "cmd", "ch": ch, "bank": bank, "op": name,
                          "gate": gate, "grant": grant, "s": s, "e": done,
                          "pn": pn, "code": code},
                         separators=(",", ":")) + "\n")
        for ch_src, ch_dst, s, end in tr.bursts:
            f.write(dump({"k": "burst", "src": ch_src, "dst": ch_dst,
                          "s": s, "e": end}, separators=(",", ":")) + "\n")
        for track, name, start, end in tr.phases:
            f.write(dump({"k": "phase", "track": track, "name": name,
                          "s": start, "e": end},
                         separators=(",", ":")) + "\n")
        for rid, qos, name, start, end in tr.request_spans:
            f.write(dump({"k": "span", "rid": rid, "qos": qos, "name": name,
                          "s": start, "e": end},
                         separators=(",", ":")) + "\n")
        for rid, qos, name, t in tr.request_events:
            f.write(dump({"k": "event", "rid": rid, "qos": qos, "name": name,
                          "t": t}, separators=(",", ":")) + "\n")


# --------------------------------------------------------------------------
# Trace validation (the smoke leg's JSON-schema check; no external deps)
# --------------------------------------------------------------------------

_PHASES_REQUIRING_DUR = ("X",)
_VALID_PH = ("X", "M", "b", "e", "i")


def validate_chrome_trace(doc: object) -> list[str]:
    """Structural validation of an exported Chrome trace document.

    Returns a list of human-readable violations (empty = valid).  This
    is the hand-rolled schema check `scripts/validate_trace.py` and the
    tests share — the container has no `jsonschema` package, and the
    dialect is small enough to check directly.
    """
    errs: list[str] = []
    if not isinstance(doc, Mapping):
        return ["top level must be a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents must be a list"]
    if "otherData" in doc and not isinstance(doc["otherData"], Mapping):
        errs.append("otherData must be an object")
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, Mapping):
            errs.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"{where}: ph must be one of {_VALID_PH}, got {ph!r}")
            continue
        if not isinstance(e.get("name"), str):
            errs.append(f"{where}: missing string name")
        if not isinstance(e.get("pid"), int):
            errs.append(f"{where}: missing integer pid")
        if ph == "M":
            if not isinstance(e.get("args"), Mapping):
                errs.append(f"{where}: metadata event needs args object")
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: ts must be a non-negative number")
        if ph in _PHASES_REQUIRING_DUR:
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: dur must be a non-negative number")
        if ph in ("b", "e") and not isinstance(e.get("id"), (int, str)):
            errs.append(f"{where}: async event needs an id")
        if len(errs) >= 20:
            errs.append("... (truncated)")
            break
    return errs
