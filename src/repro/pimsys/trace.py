"""Record / replay of device command traces (text, HBM-PIMulator style).

A trace is a line-oriented text artifact so benchmark workloads can be
versioned, diffed, and replayed bit-for-bit.  Grammar (one command per
line, `#` comments, blank lines ignored):

    <channel> <bank> <MNEMONIC> <args...>

mirroring HBM-PIMulator's ``R/W MEM [channel_id] [bank_id] [row_id]``
frontend convention of addressing every line by its physical target.
Mnemonics cover the full `core.mapping` command IR:

    ACT  row                      row activate
    RD   row atom buf             column read into atom buffer
    WR   row atom buf             column write from atom buffer
    C1   buf base gs lo hi        intra-atom fused NTT stages
    C2   u,.. v,.. base,.. stride gs   grouped inter-atom butterfly
    CMUL u v                      pointwise Montgomery multiply
    LDW  row col reg              word load  (Nb==1 path)
    STW  row col reg              word store (Nb==1 path)
    BUW  base stride gs           word-granular butterfly
    MARK name                     phase marker (no hardware effect)

Replay drives `pimsys.controller.Device`, so a recorded workload rides
the same arbitration/timing model as a live one.  Scheduler-level
reproducibility (arrival processes) comes from seeds; the trace pins the
*command-level* workload.
"""
from __future__ import annotations

import io
from collections import defaultdict
from typing import IO, Mapping

from repro.core.mapping import (
    Act,
    BUWord,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    WordLoad,
    WordStore,
)
from repro.core.pim_config import PimConfig
from repro.pimsys.controller import Device
from repro.pimsys.topology import DeviceTopology

TRACE_HEADER = "# ntt-pim trace v1: <channel> <bank> <op> <args...>"

Streams = Mapping[tuple[int, int], list[Command]]


def _ints(xs) -> str:
    return ",".join(str(x) for x in xs)


def format_command(cmd: Command) -> str:
    if isinstance(cmd, Act):
        return f"ACT {cmd.row}"
    if isinstance(cmd, ColRead):
        return f"RD {cmd.row} {cmd.atom} {cmd.buf}"
    if isinstance(cmd, ColWrite):
        return f"WR {cmd.row} {cmd.atom} {cmd.buf}"
    if isinstance(cmd, C1):
        return f"C1 {cmd.buf} {cmd.base} {int(cmd.gs)} {cmd.stages_lo} {cmd.stages_hi}"
    if isinstance(cmd, C2):
        return (f"C2 {_ints(cmd.bufs_u)} {_ints(cmd.bufs_v)} "
                f"{_ints(cmd.bases_u)} {cmd.stride} {int(cmd.gs)}")
    if isinstance(cmd, CMul):
        return f"CMUL {cmd.buf_u} {cmd.buf_v}"
    if isinstance(cmd, WordLoad):
        return f"LDW {cmd.row} {cmd.col_word} {cmd.reg}"
    if isinstance(cmd, WordStore):
        return f"STW {cmd.row} {cmd.col_word} {cmd.reg}"
    if isinstance(cmd, BUWord):
        return f"BUW {cmd.base_u} {cmd.stride} {int(cmd.gs)}"
    if isinstance(cmd, Mark):
        return f"MARK {cmd.name}"
    raise TypeError(cmd)


def parse_command(op: str, args: list[str]) -> Command:
    if op == "ACT":
        return Act(int(args[0]))
    if op == "RD":
        return ColRead(int(args[0]), int(args[1]), int(args[2]))
    if op == "WR":
        return ColWrite(int(args[0]), int(args[1]), int(args[2]))
    if op == "C1":
        return C1(int(args[0]), int(args[1]), bool(int(args[2])),
                  int(args[3]), int(args[4]))
    if op == "C2":
        tup = lambda s: tuple(int(x) for x in s.split(","))
        return C2(tup(args[0]), tup(args[1]), tup(args[2]),
                  int(args[3]), bool(int(args[4])))
    if op == "CMUL":
        return CMul(int(args[0]), int(args[1]))
    if op == "LDW":
        return WordLoad(int(args[0]), int(args[1]), int(args[2]))
    if op == "STW":
        return WordStore(int(args[0]), int(args[1]), int(args[2]))
    if op == "BUW":
        return BUWord(int(args[0]), int(args[1]), bool(int(args[2])))
    if op == "MARK":
        return Mark(args[0])
    raise ValueError(f"unknown trace mnemonic {op!r}")


# --------------------------------------------------------------------------
# record / replay
# --------------------------------------------------------------------------


def dump_trace(streams: Streams, f: IO[str] | str) -> None:
    """Write per-(channel, bank) command streams as a text trace.

    Lines keep per-bank program order; banks are emitted in address
    order (replay re-buckets by the leading channel/bank columns, so the
    interleaving of *lines* across banks carries no timing meaning).
    """
    if isinstance(f, str):
        with open(f, "w") as fh:
            dump_trace(streams, fh)
        return
    f.write(TRACE_HEADER + "\n")
    for (ch, bank) in sorted(streams):
        for cmd in streams[(ch, bank)]:
            f.write(f"{ch} {bank} {format_command(cmd)}\n")


def load_trace(f: IO[str] | str) -> dict[tuple[int, int], list[Command]]:
    if isinstance(f, str):
        with open(f) as fh:
            return load_trace(fh)
    streams: dict[tuple[int, int], list[Command]] = defaultdict(list)
    for lineno, line in enumerate(f, 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split()
        if len(parts) < 3:
            raise ValueError(f"trace line {lineno}: expected '<ch> <bank> <op> ...'")
        ch, bank, op = int(parts[0]), int(parts[1]), parts[2]
        streams[(ch, bank)].append(parse_command(op, parts[3:]))
    return dict(streams)


def loads_trace(text: str) -> dict[tuple[int, int], list[Command]]:
    return load_trace(io.StringIO(text))


def dumps_trace(streams: Streams) -> str:
    buf = io.StringIO()
    dump_trace(streams, buf)
    return buf.getvalue()


def replay_trace(cfg: PimConfig, streams: Streams, policy: str = "rr",
                 param_traces: Mapping[tuple[int, int], object] | None = None,
                 ) -> Device:
    """Build a Device large enough for the trace, enqueue, and drain it.

    The text format records commands, not twiddle values, so a replay
    cannot rederive the device-side parameter cache's residency (that
    needs the GLOBAL transform size behind each stream's (w0, r_w)
    bases).  When the recording ran with `param_cache_entries > 0`,
    pass `param_traces` to reproduce the recorded timing exactly: the
    per-stream `engine.param_beat_trace` results keyed like `streams`,
    which is what `session.CompiledPlan.param_trace_streams()` returns
    for the plan that produced the recording.  Without it the replay
    charges the flat seed-model `param_load_cycles` per CU op (exact
    for default configs, conservative otherwise).
    """
    channels = max((ch for ch, _ in streams), default=0) + 1
    banks = max((b for _, b in streams), default=0) + 1
    topo = DeviceTopology(channels=channels, ranks=1, banks_per_rank=banks)
    dev = Device(cfg, topo, policy=policy)
    for (ch, bank), cmds in sorted(streams.items()):
        trace = param_traces.get((ch, bank)) if param_traces is not None else None
        dev.channels[ch].enqueue(bank, cmds, param_trace=trace)
    dev.drain()
    return dev
