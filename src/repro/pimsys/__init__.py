"""`repro.pimsys` — device-level PIM memory system (beyond the paper).

The paper models one NTT-PIM bank; this package models the device around
it, fronted by ONE compile/execute API:

    from repro.pimsys import PimSession, PolymulOp

    sess = PimSession(PimConfig(num_buffers=4, num_channels=2, num_banks=4))
    plan = sess.compile(PolymulOp(1024))     # frozen: commands, placement,
                                             # twiddle-parameter streams
    r = sess.run(plan, a, b)                 # RunResult: value/timing/stats/trace
    svc = sess.service(ServicePolicy(weight_latency=8.0, batch_window_us=10.0))
    futs = svc.submit_poisson(plan, count=64, rate_per_us=0.1)  # open loop
    [f.result() for f in svc.as_completed(futs)]   # simulated-time order

`session` is the entry layer: declarative op specs (`NttOp`,
`InverseNttOp`, `PolymulOp`, `ShardedNttOp`, `BatchOp`) compile once into
memoized `CompiledPlan`s — the paper's precomputed (w0, r_w) parameter
streams made explicit — and run many times, mirroring how the MC amortizes
trace generation over replay.  `service` is the serving layer:
`DeviceService.submit(plan, qos=..., deadline_us=...) -> PimFuture` over a
policy-driven dispatcher (QoS classes with weighted priority aging,
bounded-queue + token-bucket admission control, window-based coalescing of
same-plan arrivals into gang issues, per-request SLO accounting).  Beneath
them sit `topology` (channels × ranks × banks), `controller` (per-channel
command-bus arbitration over `core.pimsim.BankEngine`), `scheduler` (the
dispatcher: legacy FIFO loop + `run_service`, gang-scheduled sharded
jobs), `sharded` (four-step split of one NTT across banks/channels),
`trace` (text record/replay), `stats` (device-wide counters, bus
utilization, energy, per-class service counters), `telemetry`
(opt-in command/phase/request tracing via `PimConfig.telemetry` or
`ServicePolicy.telemetry`: Perfetto-exportable `TelemetryHandle` on
`RunResult`/`SchedulerResult`, tumbling-window series in
`StatsRegistry.summary()`), and `fastpath` (the compiled vectorized
timing backend: `PimSession.run(plan, backend="fastpath")` and
`ServicePolicy(backend="fastpath")` — bit-identical single-run timing
without the interpreted event loop, `verify`/`verify_stream` as the
differential oracle).

The pre-session entry points (`core.pimsim.simulate_ntt`,
`simulate_multibank`, `simulate_ntt_sharded`, `core.polymul.pim_polymul`,
`pim_ntt_sharded`, `polymul_batch`) and now `PimSession.submit()` remain
as deprecated shims — bit-identical in values, cycles, and command lists.
"""
from repro.pimsys.controller import ChannelController, Completion, Device
from repro.pimsys.engine import (
    ChannelEngine,
    DeviceEngine,
    RankState,
    param_beat_trace,
    replay_gang,
)
from repro.pimsys.fastpath import (
    FastpathMismatch,
    GangResult,
    LoweredPlan,
    evaluate_gang,
    lower_commands,
    lower_plan,
)
from repro.pimsys.fastpath import verify as fastpath_verify
from repro.pimsys.fastpath import verify_stream
from repro.pimsys.scheduler import (
    DEFAULT_POLICY,
    QOS_CLASSES,
    STATUS_COMPLETED,
    STATUS_REJECTED,
    GangJob,
    NttJob,
    PolymulJob,
    RequestScheduler,
    SchedulerResult,
    ServicePolicy,
    ServiceRequest,
    ShardedNttJob,
    job_commands,
)
from repro.pimsys.service import (
    DeviceService,
    PimFuture,
    ServedRequest,
)
from repro.pimsys.session import (
    BatchOp,
    CompiledPlan,
    InverseNttOp,
    NttOp,
    OpHandler,
    PimSession,
    PolymulOp,
    RunResult,
    ShardedNttOp,
    TraceHandle,
    op_handler,
    register_op_handler,
    twiddle_param_stream,
)
from repro.pimsys.sharded import (
    ExchangePair,
    ExchangeStage,
    ShardedNttPlan,
    ShardedTimingResult,
)
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.telemetry import (
    Reservoir,
    TelemetryHandle,
    Tracer,
    WindowedSeries,
    validate_chrome_trace,
)
from repro.pimsys.topology import BankAddress, DeviceTopology
from repro.pimsys.trace import dump_trace, dumps_trace, load_trace, loads_trace, replay_trace

__all__ = [
    "BankAddress",
    "BatchOp",
    "ChannelController",
    "ChannelEngine",
    "CompiledPlan",
    "Completion",
    "DEFAULT_POLICY",
    "Device",
    "DeviceEngine",
    "DeviceService",
    "DeviceTopology",
    "ExchangePair",
    "ExchangeStage",
    "FastpathMismatch",
    "GangJob",
    "GangResult",
    "InverseNttOp",
    "LoweredPlan",
    "NttJob",
    "NttOp",
    "OpHandler",
    "PimFuture",
    "PimSession",
    "PolymulJob",
    "PolymulOp",
    "QOS_CLASSES",
    "RankState",
    "RequestScheduler",
    "Reservoir",
    "RunResult",
    "STATUS_COMPLETED",
    "STATUS_REJECTED",
    "SchedulerResult",
    "ServedRequest",
    "ServicePolicy",
    "ServiceRequest",
    "ShardedNttJob",
    "ShardedNttOp",
    "ShardedNttPlan",
    "ShardedTimingResult",
    "StatsRegistry",
    "TelemetryHandle",
    "TraceHandle",
    "Tracer",
    "WindowedSeries",
    "dump_trace",
    "dumps_trace",
    "evaluate_gang",
    "fastpath_verify",
    "job_commands",
    "load_trace",
    "loads_trace",
    "lower_commands",
    "lower_plan",
    "op_handler",
    "param_beat_trace",
    "register_op_handler",
    "replay_gang",
    "replay_trace",
    "verify_stream",
    "twiddle_param_stream",
    "validate_chrome_trace",
]
