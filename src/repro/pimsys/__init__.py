"""`repro.pimsys` — device-level PIM memory system (beyond the paper).

The paper models one NTT-PIM bank; this package models the device around
it, fronted by ONE compile/execute API:

    from repro.pimsys import PimSession, PolymulOp

    sess = PimSession(PimConfig(num_buffers=4, num_channels=2, num_banks=4))
    plan = sess.compile(PolymulOp(1024))     # frozen: commands, placement,
                                             # twiddle-parameter streams
    r = sess.run(plan, a, b)                 # RunResult: value/timing/stats/trace
    sess.submit(plan, count=64, rate_per_us=0.1)   # queued open-loop traffic

`session` is the entry layer: declarative op specs (`NttOp`,
`InverseNttOp`, `PolymulOp`, `ShardedNttOp`, `BatchOp`) compile once into
memoized `CompiledPlan`s — the paper's precomputed (w0, r_w) parameter
streams made explicit — and run many times, mirroring how the MC amortizes
trace generation over replay.  Beneath it sit `topology` (channels ×
ranks × banks), `controller` (per-channel command-bus arbitration over
`core.pimsim.BankEngine`), `scheduler` (request queue + closed/open-loop
injection, gang-scheduled sharded jobs), `sharded` (four-step split of
one NTT across banks/channels), `trace` (text record/replay), and `stats`
(device-wide counters, bus utilization, energy).

The pre-session entry points (`core.pimsim.simulate_ntt`,
`simulate_multibank`, `simulate_ntt_sharded`, `core.polymul.pim_polymul`,
`pim_ntt_sharded`, `polymul_batch`) remain as deprecated shims over a
session, bit-identical in values, cycles, and command lists.
"""
from repro.pimsys.controller import ChannelController, Completion, Device
from repro.pimsys.engine import (
    ChannelEngine,
    DeviceEngine,
    RankState,
    param_beat_trace,
)
from repro.pimsys.scheduler import (
    NttJob,
    PolymulJob,
    RequestScheduler,
    SchedulerResult,
    ShardedNttJob,
    job_commands,
)
from repro.pimsys.session import (
    BatchOp,
    CompiledPlan,
    InverseNttOp,
    NttOp,
    PimSession,
    PolymulOp,
    RunResult,
    ShardedNttOp,
    TraceHandle,
    twiddle_param_stream,
)
from repro.pimsys.sharded import (
    ExchangePair,
    ExchangeStage,
    ShardedNttPlan,
    ShardedTimingResult,
)
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import BankAddress, DeviceTopology
from repro.pimsys.trace import dump_trace, dumps_trace, load_trace, loads_trace, replay_trace

__all__ = [
    "BankAddress",
    "BatchOp",
    "ChannelController",
    "ChannelEngine",
    "CompiledPlan",
    "Completion",
    "Device",
    "DeviceEngine",
    "DeviceTopology",
    "ExchangePair",
    "ExchangeStage",
    "InverseNttOp",
    "NttJob",
    "NttOp",
    "PimSession",
    "PolymulJob",
    "PolymulOp",
    "RankState",
    "RequestScheduler",
    "RunResult",
    "SchedulerResult",
    "ShardedNttJob",
    "ShardedNttOp",
    "ShardedNttPlan",
    "ShardedTimingResult",
    "StatsRegistry",
    "TraceHandle",
    "dump_trace",
    "dumps_trace",
    "job_commands",
    "load_trace",
    "loads_trace",
    "param_beat_trace",
    "replay_trace",
    "twiddle_param_stream",
]
