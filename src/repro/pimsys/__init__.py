"""`repro.pimsys` — device-level PIM memory system (beyond the paper).

The paper models one NTT-PIM bank; this package models the device around
it: `topology` (channels × ranks × banks), `controller` (per-channel
command-bus arbitration over `core.pimsim.BankEngine`), `scheduler`
(request queue + closed/open-loop injection), `trace` (text record /
replay), and `stats` (device-wide counters, bus utilization, energy).
"""
from repro.pimsys.controller import ChannelController, Completion, Device
from repro.pimsys.scheduler import (
    NttJob,
    PolymulJob,
    RequestScheduler,
    SchedulerResult,
    ShardedNttJob,
    job_commands,
)
from repro.pimsys.sharded import (
    ExchangePair,
    ExchangeStage,
    ShardedNttPlan,
    ShardedTimingResult,
)
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import BankAddress, DeviceTopology
from repro.pimsys.trace import dump_trace, dumps_trace, load_trace, loads_trace, replay_trace

__all__ = [
    "BankAddress",
    "ChannelController",
    "Completion",
    "Device",
    "DeviceTopology",
    "ExchangePair",
    "ExchangeStage",
    "NttJob",
    "PolymulJob",
    "RequestScheduler",
    "SchedulerResult",
    "ShardedNttJob",
    "ShardedNttPlan",
    "ShardedTimingResult",
    "StatsRegistry",
    "dump_trace",
    "dumps_trace",
    "job_commands",
    "load_trace",
    "loads_trace",
    "replay_trace",
]
