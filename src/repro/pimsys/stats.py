"""Stats registry for the PIM memory system.

Aggregates the per-bank command counters that `core.pimsim.BankEngine`
produces, plus per-channel bus occupancy, into device-level views:
per-bank, per-channel, and whole-device rollups, bus utilization, and
energy via `core.pim_config.EnergyModel` (the same accounting as
`TimingResult.energy_nj`, so single-bank numbers agree with the paper
benchmarks).
"""
from __future__ import annotations

from collections import defaultdict

from repro.core.pim_config import EnergyModel


def merge_counts(dst: dict, src: dict) -> dict:
    for k, v in src.items():
        dst[k] = dst.get(k, 0) + v
    return dst


class StatsRegistry:
    """Counters keyed by (channel, bank-within-channel)."""

    def __init__(self, channels: int | None = None):
        # declared channel universe (the topology's channel count); a
        # channel exists even before any traffic lands on it, so span
        # stretches and summaries must cover silent channels too
        self._channels = channels or 0
        self._bank: dict[tuple[int, int], dict] = defaultdict(dict)
        self._bus_busy_ns: dict[int, float] = defaultdict(float)
        self._bus_span_ns: dict[int, float] = defaultdict(float)
        self._device: dict = {}
        self._service: dict[tuple[str, str], int] = {}
        self._series: dict[str, object] = {}

    # -- recording -----------------------------------------------------------
    def add_bank(self, channel: int, bank: int, counters: dict) -> None:
        merge_counts(self._bank[(channel, bank)], counters)

    def add_service(self, qos: str, key: str, count: int = 1) -> None:
        """Service-layer counters keyed by QoS class: submissions,
        per-reason rejections (`rejected_queue_full`, `rejected_rate_limited`)
        — the admission-control view `run_service` records."""
        self._service[(qos, key)] = self._service.get((qos, key), 0) + count

    def add_bus(self, channel: int, busy_ns: float, span_ns: float) -> None:
        self._bus_busy_ns[channel] += busy_ns
        self._bus_span_ns[channel] = max(self._bus_span_ns[channel], span_ns)

    def add_device(self, counters: dict) -> None:
        """Counters with no per-bank home (e.g. the sharded exchange's
        `xfer_atoms` / `xfer_hops` inter-bank bursts)."""
        merge_counts(self._device, counters)

    def attach_series(self, name: str, series) -> None:
        """Attach a windowed time series (`telemetry.WindowedSeries`) so
        `summary()` carries the timeline next to the counters."""
        self._series[name] = series

    def extend_span(self, span_ns: float) -> None:
        """Stretch every channel's observation window to `span_ns`.

        Covers the declared channel universe (see `channels()`), not
        just channels that already recorded bus traffic — a silent
        channel's utilization is a true 0.0 over the run's span, not an
        undefined 0/0 that stays zero after traffic arrives later.
        """
        for ch in self.channels():
            self._bus_span_ns[ch] = max(self._bus_span_ns[ch], span_ns)

    # -- views ---------------------------------------------------------------
    def bank_counts(self, channel: int, bank: int) -> dict:
        return dict(self._bank.get((channel, bank), {}))

    def channel_counts(self, channel: int) -> dict:
        out: dict = {}
        for (ch, _), c in self._bank.items():
            if ch == channel:
                merge_counts(out, c)
        return out

    def device_counts(self) -> dict:
        out: dict = {}
        for c in self._bank.values():
            merge_counts(out, c)
        merge_counts(out, self._device)
        return out

    def service_counts(self, qos: str | None = None) -> dict:
        """Service-layer counters: `{key: count}` for one QoS class, or
        `{(qos, key): count}` over every class."""
        if qos is None:
            return dict(self._service)
        return {k: v for (c, k), v in self._service.items() if c == qos}

    def channels(self) -> list[int]:
        """Every known channel: the declared universe (constructor
        `channels=` from the topology) unioned with any channel that has
        recorded bank or bus activity."""
        seen = {ch for ch, _ in self._bank} | set(self._bus_busy_ns)
        seen.update(range(self._channels))
        return sorted(seen)

    def bus_busy_ns(self, channel: int) -> float:
        return self._bus_busy_ns.get(channel, 0.0)

    def bus_utilization(self, channel: int) -> float:
        span = self._bus_span_ns.get(channel, 0.0)
        if span <= 0.0:
            return 0.0
        return min(1.0, self._bus_busy_ns[channel] / span)

    def energy_nj(self, model: EnergyModel | None = None) -> float:
        return (model or EnergyModel()).energy_nj(self.device_counts())

    def param_hit_rate(self, channel: int | None = None,
                       bank: int | None = None) -> float:
        """Hit rate of the device-side twiddle-parameter cache
        (`PimConfig.param_cache_entries`): hits / (hits + misses) over
        the whole device, one channel, or one bank.  `bank` addresses a
        bank WITHIN a channel and therefore requires `channel` on a
        multi-channel registry (it defaults to the sole channel 0
        otherwise).  0.0 when the cache is disabled (no tracked
        accesses)."""
        if bank is not None:
            if channel is None:
                chans = self.channels()
                if len(chans) > 1:
                    raise ValueError(
                        "bank= addresses a bank within a channel; pass "
                        f"channel= too (registry spans channels {chans})")
                channel = chans[0] if chans else 0
            c = self.bank_counts(channel, bank)
        elif channel is not None:
            c = self.channel_counts(channel)
        else:
            c = self.device_counts()
        hits = c.get("param_hit", 0)
        total = hits + c.get("param_miss", 0)
        return hits / total if total else 0.0

    def diff(self, other: "StatsRegistry") -> dict:
        """Structured difference against another registry — empty when
        the two agree on every per-bank counter, device counter, and
        per-channel bus occupancy.  The fastpath differential tests use
        this to report WHICH counter diverged instead of a bare
        dict-inequality failure; `refresh` is still compared (the
        backends are bit-identical on a shared timeline)."""
        out: dict = {}
        banks = set(self._bank) | set(other._bank)
        for key in sorted(banks):
            a = self._bank.get(key, {})
            b = other._bank.get(key, {})
            if a != b:
                keys = set(a) | set(b)
                out[f"bank{key}"] = {
                    k: (a.get(k), b.get(k))
                    for k in sorted(keys) if a.get(k) != b.get(k)
                }
        if self._device != other._device:
            keys = set(self._device) | set(other._device)
            out["device"] = {
                k: (self._device.get(k), other._device.get(k))
                for k in sorted(keys)
                if self._device.get(k) != other._device.get(k)
            }
        chans = set(self._bus_busy_ns) | set(other._bus_busy_ns)
        for ch in sorted(chans):
            a, b = self.bus_busy_ns(ch), other.bus_busy_ns(ch)
            if a != b:
                out[f"bus{ch}"] = (a, b)
        return out

    #: per-bank counters that are derived metrics, not issued commands
    NON_COMMAND_KEYS = ("bu_ops", "refresh", "param_hit", "param_miss")

    def summary(self, model: EnergyModel | None = None) -> dict:
        """Flat dict for reports / benchmark `emit` lines."""
        dev = self.device_counts()
        per_ch = {
            ch: {
                "bus_utilization": self.bus_utilization(ch),
                "commands": sum(
                    v for k, v in self.channel_counts(ch).items()
                    if k not in self.NON_COMMAND_KEYS
                ),
            }
            for ch in self.channels()
        }
        out = {
            "device_counts": dev,
            "energy_nj": self.energy_nj(model),
            "per_channel": per_ch,
        }
        if self._service:
            out["service"] = {
                f"{qos}/{key}": v for (qos, key), v in sorted(self._service.items())
            }
        if self._series:
            out["timeseries"] = {
                name: s.points_us() for name, s in sorted(self._series.items())
            }
        return out
