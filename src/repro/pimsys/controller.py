"""Per-channel memory controller: thin driver of `repro.pimsys.engine`.

`ChannelController` and `Device` are the established device-facing names
for the channel and device layers of the hierarchical resource engine;
since the engine refactor they ARE those layers — one command-issue path
(`engine.ChannelEngine` / `engine.DeviceEngine`: shared-bus arbitration
→ `RankState` tFAW/turnaround windows → `core.pimsim.BankEngine` bank
hazards → CU), not a parallel implementation.  With one bank the grant
sequence degenerates to program order and the timing is bit-identical to
`BankTimer` by construction; see the engine module docstring for the
layering, arbitration policies, and the device-side twiddle-parameter
cache model.
"""
from __future__ import annotations

from repro.pimsys.engine import (
    POLICIES,
    ChannelEngine,
    Completion,
    DeviceEngine,
)

__all__ = ["POLICIES", "ChannelController", "Completion", "Device"]


class ChannelController(ChannelEngine):
    """One command/address bus shared by bank ports (`ChannelEngine`)."""

    __slots__ = ()


class Device(DeviceEngine):
    """A full PIM device: one `ChannelController` per channel
    (`DeviceEngine`)."""

    __slots__ = ()
