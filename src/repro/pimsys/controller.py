"""Per-channel memory controller: many NTT-PIM banks, ONE command bus.

`core.pimsim.BankTimer` times a single bank with an implicit private bus.
At the device level all banks in a channel share one command/address bus
(and NTT-PIM streams (w0, r_w) twiddle parameters over it per CU op,
§IV-A), so the controller must *arbitrate*: each simulated step it grants
the bus to one bank and issues that bank's next command through the
bank's own `BankEngine` — the exact hazard/resource model of the paper's
single-bank simulator.  With one bank the grant sequence degenerates to
program order and the timing is bit-identical to `BankTimer`.

Arbitration policies:
  rr      round-robin over banks whose head command is ready at the
          earliest grant time (fair, FCFS-like)
  ready   ready-first (FR-FCFS flavor): grant the bank whose head command
          would *start* soonest given its internal hazards, so a bank
          stalled on tRAS/CU latency does not block a ready neighbor

Causality note: commands become visible to the arbiter at their `gate`
time (job dispatch time), so open-loop traffic injected by the scheduler
contends only with commands that actually coexist with it.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.core.mapping import Command, Mark
from repro.core.pimsim import BankEngine
from repro.core.pim_config import PimConfig
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import DeviceTopology

POLICIES = ("rr", "ready")

_INF = math.inf


@dataclasses.dataclass(frozen=True)
class Completion:
    """A job's last command finished on `channel`/`bank` at `done` ns."""

    job_id: object
    channel: int
    bank: int
    done: float


class _Job:
    __slots__ = ("remaining", "max_done")

    def __init__(self):
        self.remaining = 0
        self.max_done = 0.0


class ChannelController:
    """One command/address bus shared by `bank` ports, cycle-level."""

    def __init__(self, cfg: PimConfig, channel_id: int = 0, policy: str = "rr"):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        self.cfg = cfg
        self.channel_id = channel_id
        self.policy = policy
        self.bus_free = 0.0
        self.bus_busy_ns = 0.0
        self.engines: list[BankEngine] = []
        self.queues: list[deque] = []  # entries: (cmd, gate, job_id)
        self._jobs: dict[object, _Job] = {}
        self._rr = 0  # last granted bank (round-robin pointer)
        self.issued = 0

    # -- construction --------------------------------------------------------
    def add_bank(self, pipelined: bool = True) -> int:
        self.engines.append(BankEngine(self.cfg, pipelined=pipelined))
        self.queues.append(deque())
        return len(self.engines) - 1

    def enqueue(self, bank: int, commands, gate: float = 0.0, job_id=None) -> None:
        """Queue a command stream on `bank`, visible to the arbiter at
        `gate` (dispatch time).  `Mark`s are phase annotations with no
        hardware effect and are dropped here, exactly as `BankTimer`
        ignores them."""
        q = self.queues[bank]
        job = None
        if job_id is not None:
            job = self._jobs.get(job_id)
            if job is None:
                job = self._jobs[job_id] = _Job()
        n = 0
        for cmd in commands:
            if isinstance(cmd, Mark):
                continue
            q.append((cmd, gate, job_id))
            n += 1
        if job is not None:
            job.remaining += n

    def occupy_bus(self, not_before: float, hold_ns: float) -> float:
        """Grant the shared bus for a non-command transaction (an inter-bank
        atom burst: the paired ColRead/ColWrite transfer a sharded NTT's
        exchange phase rides on — see `repro.pimsys.sharded`).  Returns the
        grant time; the bus is busy for `hold_ns` from there."""
        s = max(not_before, self.bus_free)
        self.bus_free = s + hold_ns
        self.bus_busy_ns += hold_ns
        return s

    def issue_direct(self, bank: int, cmd: Command,
                     not_before: float = 0.0) -> tuple[float, float]:
        """Issue one command on `bank` outside the queued arbitration path
        (the sharded exchange phase drives engines directly), with exactly
        the bus-grant bookkeeping `advance` applies.  Returns (start, done)."""
        eng = self.engines[bank]
        s, done = eng.issue(cmd, max(not_before, self.bus_free))
        self.bus_free = s + eng.t_bus
        self.bus_busy_ns += eng.bus_hold(cmd)
        self.issued += 1
        return s, done

    # -- arbitration ---------------------------------------------------------
    def _grant_time(self, bank: int) -> float:
        q = self.queues[bank]
        if not q:
            return _INF
        return max(self.bus_free, q[0][1])

    def next_grant(self) -> float:
        """Earliest time any queued command could be granted the bus."""
        g = _INF
        for b in range(len(self.queues)):
            g = min(g, self._grant_time(b))
        return g

    def _pick(self) -> int | None:
        n = len(self.queues)
        if self.policy == "rr":
            # Fair rotation over banks grantable at the earliest grant time.
            # Fast path: the first non-empty bank (cyclically after the last
            # grant) whose head gate <= bus_free is grantable at bus_free,
            # which is the minimum possible grant — O(1) amortized.
            bus = self.bus_free
            best, best_gate = None, _INF
            for off in range(1, n + 1):
                b = (self._rr + off) % n
                q = self.queues[b]
                if not q:
                    continue
                gate = q[0][1]
                if gate <= bus:
                    return b
                if gate < best_gate:
                    best, best_gate = b, gate
            return best  # None iff every queue is empty
        # ready-first: grant whichever grantable head would START soonest
        best, best_s = None, _INF
        for off in range(1, n + 1):
            b = (self._rr + off) % n
            g = self._grant_time(b)
            if math.isinf(g):
                continue
            s = self.engines[b].earliest_start(self.queues[b][0][0], g)
            if s < best_s:
                best, best_s = b, s
        return best

    # -- simulation ----------------------------------------------------------
    def advance(self, horizon: float = _INF) -> list[Completion] | None:
        """Grant the bus once and issue one command.

        Returns completions triggered by that issue ([] if none), or
        `None` if no queued command can be granted before `horizon`
        (the scheduler then injects the next arrival).
        """
        bank = self._pick()
        if bank is None:
            return None
        # Causality: the guard is on the CHOSEN bank's grant, not the global
        # minimum — the ready policy may pick a later-gated bank than the
        # earliest one, and issuing at/after `horizon` would advance the bus
        # past an arrival the scheduler has not injected yet.
        grant = max(self.bus_free, self.queues[bank][0][1])
        if grant >= horizon:
            return None
        cmd, gate, job_id = self.queues[bank].popleft()
        eng = self.engines[bank]
        s, done = eng.issue(cmd, grant)
        self.bus_free = s + eng.t_bus
        self.bus_busy_ns += eng.bus_hold(cmd)
        self._rr = bank
        self.issued += 1

        out: list[Completion] = []
        if job_id is not None:
            job = self._jobs[job_id]
            job.max_done = max(job.max_done, done)
            job.remaining -= 1
            if job.remaining == 0:
                out.append(Completion(job_id, self.channel_id, bank, job.max_done))
                del self._jobs[job_id]
        return out

    def drain(self) -> list[Completion]:
        """Run until every queue is empty; return all completions."""
        out: list[Completion] = []
        while True:
            evs = self.advance()
            if evs is None:
                return out
            out.extend(evs)

    # -- results -------------------------------------------------------------
    @property
    def makespan_ns(self) -> float:
        return max((e.end_t for e in self.engines), default=0.0)

    def bank_ns(self, bank: int) -> float:
        return self.engines[bank].end_t

    def record_stats(self, reg: StatsRegistry) -> None:
        for b, eng in enumerate(self.engines):
            reg.add_bank(self.channel_id, b, dict(eng.stats))
        reg.add_bus(self.channel_id, self.bus_busy_ns, self.makespan_ns)


class Device:
    """A full PIM device: one `ChannelController` per channel.

    Channels have independent buses, so they only interact through the
    scheduler's placement decisions; `advance` always steps the channel
    with the earliest grantable command to keep event order causal.
    """

    def __init__(self, cfg: PimConfig, topo: DeviceTopology | None = None,
                 policy: str = "rr", pipelined: bool = True):
        self.cfg = cfg
        self.topo = topo or DeviceTopology.from_config(cfg)
        self.channels = [
            ChannelController(cfg, channel_id=ch, policy=policy)
            for ch in range(self.topo.channels)
        ]
        for ctrl in self.channels:
            for _ in range(self.topo.banks_per_channel):
                ctrl.add_bank(pipelined=pipelined)

    def enqueue_flat(self, flat_bank: int, commands, gate: float = 0.0, job_id=None):
        addr = self.topo.address_of(flat_bank)
        self.channels[addr.channel].enqueue(
            self.topo.local_id(addr), commands, gate=gate, job_id=job_id)

    def advance(self, horizon: float = _INF) -> list[Completion] | None:
        best, best_g = None, _INF
        for ctrl in self.channels:
            g = ctrl.next_grant()
            if g < best_g:
                best, best_g = ctrl, g
        if best is None or best_g >= horizon:
            return None
        return best.advance(horizon)

    def drain(self) -> list[Completion]:
        out: list[Completion] = []
        for ctrl in self.channels:
            out.extend(ctrl.drain())
        return out

    @property
    def makespan_ns(self) -> float:
        return max(c.makespan_ns for c in self.channels)

    def stats(self) -> StatsRegistry:
        reg = StatsRegistry()
        for ctrl in self.channels:
            ctrl.record_stats(reg)
        return reg
