"""Unified compile/execute device API: `PimSession` (the tentpole layer).

The paper's row-centric mapping owes its efficiency to *precomputation*:
the memory controller derives each CU op's (w0, r_w) twiddle-parameter
stream once and replays it (§IV-A).  The repo historically re-derived
those streams on every call and exposed the device through six
uncoordinated entry points (`pim_polymul`, `pim_ntt_sharded`,
`simulate_ntt`, `simulate_multibank`, `simulate_ntt_sharded`,
`polymul_batch`).  This module makes compile-once/run-many the default
execution model:

    sess = PimSession(PimConfig(num_buffers=4, num_channels=2, num_banks=4))
    plan = sess.compile(PolymulOp(1024))        # frozen, reusable artifact
    r    = sess.run(plan, a, b)                 # functional + timed
    r.value, r.timing, r.stats, r.trace         # one unified result type
    sess.service().submit_poisson(plan, 64, 0.1)  # queued / open-loop futures

Three layers:

  * **op specs** — declarative, hashable descriptions of device work:
    `NttOp`, `InverseNttOp`, `PolymulOp`, `ShardedNttOp`, and the batched
    variant `BatchOp(op, count)`.
  * **`compile(op) -> CompiledPlan`** — a frozen artifact holding the
    command list(s), row/bank placement, the precomputed twiddle-parameter
    stream (one table index per CU op, the functional content of the MC's
    (w0, r_w) programs), the device-side parameter-cache residency trace
    (`param_trace`, charged identically by `BankTimer`, the channel
    engine, and the analytic bus bound when
    `PimConfig.param_cache_entries > 0`), and for sharded ops the
    `ShardedNttPlan` exchange schedule.  Plans are memoized in a
    session-level cache keyed by `(cfg, op)`; a second `compile` of an
    equal op returns the SAME object and a repeated `run` performs zero
    mapper regeneration (`core.mapping.mapper_generations` counts, tests
    assert).
  * **`run(plan, *inputs) -> RunResult`** — one result type unifying the
    functional output, `TimingResult` / `ShardedTimingResult` /
    `MultiBankResult` / `SchedulerResult`, a `StatsRegistry` snapshot, and
    an optional `TraceHandle` onto the `pimsys.trace` record/replay path.
    `service()` returns the `repro.pimsys.service.DeviceService` over
    this session — futures, QoS classes, admission control, batching;
    the deprecated `submit(plan, ...)` shims onto its default policy.

The legacy entry points remain available as thin shims over a session —
bit-identical in values, cycle counts, and command lists — and each emits
exactly one `DeprecationWarning` per call.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Mapping, Sequence

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt as ntt_ref
from repro.core.mapping import (
    Command,
    FunctionalBank,
    RowCentricMapper,
    cu_twiddle_indices,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import (
    BankTimer,
    MultiBankResult,
    TimingResult,
    analytic_multibank_bound,
)
from repro.core.polymul import polymul_phases
from repro.pimsys.controller import ChannelController
from repro.pimsys.fastpath import evaluate_gang, lower_plan, phase_breakdown
from repro.pimsys.scheduler import (
    NttJob,
    PolymulJob,
    RequestScheduler,
    SchedulerResult,
    ShardedNttJob,
)
from repro.pimsys.sharded import ShardedNttPlan, ShardedTimingResult
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.telemetry import TelemetryHandle, Tracer
from repro.pimsys.topology import DeviceTopology
from repro.pimsys.trace import dump_trace, dumps_trace


# --------------------------------------------------------------------------
# Op specs — declarative, hashable device work descriptions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NttOp:
    """One size-n negacyclic NTT on one bank.

    `forward=False` is the paper's orientation (GS butterflies, i.e. the
    inverse transform); `scale_n_inv` applies the host-side 1/N scaling
    on functional inverse runs, exactly as `core.mapping.pim_ntt` does.
    """

    n: int
    forward: bool = False
    scale_n_inv: bool = True


@dataclasses.dataclass(frozen=True)
class InverseNttOp:
    """Explicit-name alias for the inverse orientation.

    Compiles to the same plan-cache entry as `NttOp(n, forward=False)` —
    `compile(InverseNttOp(n)) is compile(NttOp(n))`.
    """

    n: int
    scale_n_inv: bool = True


@dataclasses.dataclass(frozen=True)
class PolymulOp:
    """One RLWE polynomial product: NTT(a), NTT(b), ⊙, INTT, scale."""

    n: int


@dataclasses.dataclass(frozen=True)
class ShardedNttOp:
    """ONE size-n NTT four-step-sharded over `banks` banks/channels.

    `placement` selects the sub-NTT -> bank map: "identity" (the
    channel-interleaved default) or "conflict"
    (`sharded.conflict_aware_flat_banks`: exchange partners on distinct
    channels at every stride).
    """

    n: int
    banks: int = 2
    forward: bool = False
    scale_n_inv: bool = True
    placement: str = "identity"


@dataclasses.dataclass(frozen=True)
class BatchOp:
    """`count` independent copies of `op` run bank-parallel.

    `BatchOp(NttOp(n), k)` reproduces the §VII multi-bank setting: k
    identical NTT streams contending on one channel's shared command bus
    (the `simulate_multibank` semantics, cross-checked against the
    analytic bus bound).  `BatchOp(PolymulOp(n), k)` is a closed-loop
    scheduler batch over the full topology (the `polymul_batch`
    semantics).
    """

    op: "Op"
    count: int


Op = NttOp | InverseNttOp | PolymulOp | ShardedNttOp | BatchOp


# --------------------------------------------------------------------------
# Op-handler registry — extension ops without session <-> subsystem cycles
# --------------------------------------------------------------------------


class OpHandler:
    """Compile/run protocol for an op family the session does not know.

    Subsystems (e.g. `repro.he`) register a handler per op class; the
    session consults the registry before its builtin isinstance chains,
    so extension ops flow through the same memoized `compile`, the same
    `run` signature, the same `RunResult`, and the same service priming
    (`CompiledPlan.prime_scheduler`) as the builtins — without the
    session importing the subsystem.
    """

    def canonical(self, op):
        """Normalize spelling variants (default: identity)."""
        return op

    def compile(self, sess: "PimSession", op) -> "CompiledPlan":
        raise NotImplementedError

    def run(self, sess: "PimSession", plan: "CompiledPlan", inputs, *,
            ctx=None, single=None, time=True, backend="engine") -> "RunResult":
        raise NotImplementedError

    def job(self, plan: "CompiledPlan"):
        """The scheduler job spec the plan executes as."""
        raise TypeError(f"no scheduler job for {type(plan.op).__name__}")

    def prime(self, plan: "CompiledPlan", sched: RequestScheduler) -> None:
        """Prime the scheduler for queued dispatch of this plan."""
        sched.prime(plan.job(), plan.commands, param_trace=plan.param_trace)


_OP_HANDLERS: dict[type, OpHandler] = {}


def register_op_handler(op_cls: type, handler: OpHandler) -> None:
    """Register `handler` for every op of exact type `op_cls`."""
    _OP_HANDLERS[op_cls] = handler


def op_handler(op) -> OpHandler | None:
    """The registered handler for `op`'s type, or None (a builtin op)."""
    return _OP_HANDLERS.get(type(op))


def _canonical(op: Op) -> Op:
    """Normalize spelling variants so they share one plan-cache entry."""
    h = op_handler(op)
    if h is not None:
        return h.canonical(op)
    if isinstance(op, InverseNttOp):
        return NttOp(op.n, forward=False, scale_n_inv=op.scale_n_inv)
    if isinstance(op, BatchOp):
        inner = _canonical(op.op)
        if not isinstance(inner, (NttOp, PolymulOp)):
            raise TypeError(
                f"BatchOp batches NttOp/PolymulOp, not {type(op.op).__name__}; "
                "sharded work gang-schedules through submit() instead")
        if op.count < 1:
            raise ValueError("BatchOp.count must be >= 1")
        return BatchOp(inner, op.count)
    return op


# --------------------------------------------------------------------------
# Twiddle-parameter streams — the (w0, r_w) programs, precomputed
# --------------------------------------------------------------------------


def twiddle_param_stream(cfg: PimConfig, n: int,
                         commands: Sequence[Command]) -> tuple[tuple[int, ...], ...]:
    """Per-CU-op twiddle table indices, in issue order.

    The hardware streams (w0, r_w) generator parameters over the command
    bus per C1/C2/BUWord (§IV-A); functionally each such program is the
    set of global twiddle-table indices the op resolves.  Precomputing the
    stream once per `CompiledPlan` is the paper's amortization: `run()`
    replays it without touching the mapper.  `n` is the GLOBAL transform
    size (a sharded local stream resolves against the full table via its
    shifted bases, so the same function covers both).
    """
    out: list[tuple[int, ...]] = []
    for cmd in commands:
        idx = cu_twiddle_indices(cfg, n, cmd)
        if idx is not None:
            out.append(idx)
    return tuple(out)


# --------------------------------------------------------------------------
# Compiled plans and run results
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceHandle:
    """Lazy handle onto the `pimsys.trace` text record/replay path."""

    streams: Mapping[tuple[int, int], list[Command]]

    def dumps(self) -> str:
        return dumps_trace(self.streams)

    def dump(self, path) -> None:
        dump_trace(self.streams, path)


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """Frozen, reusable execution artifact for one op under one config.

    Holds everything `run()` needs that does not depend on the input
    polynomials: the timed command list, per-phase functional streams,
    row/bank placement, the precomputed twiddle-parameter streams, and
    (sharded) the `ShardedNttPlan` with its exchange schedule.  Produced
    only by `PimSession.compile`, which memoizes by `(cfg, op)` — equal
    ops yield the identical object, so repeated runs regenerate nothing.
    """

    cfg: PimConfig
    op: Op
    commands: tuple[Command, ...]               # full timed stream ((); batch/sharded)
    phases: Mapping[str, tuple[Command, ...]]   # functional sub-streams by name
    placement: Mapping[str, object]             # row/bank placement decisions
    sharded_plan: ShardedNttPlan | None = None  # exchange schedule owner
    inner: "CompiledPlan | None" = None         # BatchOp: the replicated plan
    count: int = 1
    ext: object = dataclasses.field(            # handler-owned artifact
        default=None, repr=False, compare=False)
    _twiddle_cache: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _param_trace_cache: tuple = dataclasses.field(
        default=("unset",), init=False, repr=False, compare=False)

    @property
    def twiddle_params(self) -> tuple:
        """Per-CU-op (w0, r_w) index streams — the parameter programs the
        MC replays per run.  Derived from the frozen command stream(s),
        materialized once per plan on first access (timing-only runs
        never pay for it) and cached thereafter."""
        if self._twiddle_cache is None:
            if self.inner is not None:
                val = self.inner.twiddle_params
            elif self.sharded_plan is not None:
                val = tuple(
                    twiddle_param_stream(self.cfg, self.op.n, s)
                    for s in self.sharded_plan.local_streams())
            else:
                val = twiddle_param_stream(self.cfg, self.op.n, self.commands)
            object.__setattr__(self, "_twiddle_cache", val)
        return self._twiddle_cache

    @property
    def param_trace(self):
        """Per-CU-op (bus_beats, hit/miss) residency trace of the
        device-side twiddle-parameter cache (`engine.param_beat_trace`),
        or None when `cfg.param_cache_entries == 0`.  Precomputed once
        per plan — `run()` replays it with zero regeneration — and
        charged identically by `BankTimer`, the channel engine, and the
        analytic bus bound."""
        cached = self._param_trace_cache
        if cached == ("unset",):
            from repro.pimsys.engine import param_beat_trace

            if self.inner is not None:
                val = self.inner.param_trace
            elif self.sharded_plan is not None:
                # per-bank traces live on the sharded plan (used by its
                # simulate/analytic bound); surface them as a tuple
                val = tuple(self.sharded_plan.local_param_traces())
            else:
                val = param_beat_trace(self.cfg, self.op.n, self.commands)
            object.__setattr__(self, "_param_trace_cache", (val,))
            return val
        return cached[0]

    def job(self):
        """The `RequestScheduler` job spec this plan executes as."""
        op = self.op
        h = op_handler(op)
        if h is not None:
            return h.job(self)
        if isinstance(op, NttOp):
            return NttJob(op.n, forward=op.forward)
        if isinstance(op, PolymulOp):
            return PolymulJob(op.n)
        if isinstance(op, ShardedNttOp):
            return ShardedNttJob(op.n, banks=op.banks, forward=op.forward)
        raise TypeError(f"no scheduler job for {type(op).__name__}")

    def prime_scheduler(self, sched: RequestScheduler) -> None:
        """Prime `sched` so queued dispatch replays this frozen plan.

        Single-bank plans hand their command stream (and residency
        trace) to `RequestScheduler.prime`; sharded plans need nothing
        (the scheduler's sharded cache rebuilds from the job spec);
        handler ops delegate — gang ops prime their latency resolver.
        The ONE priming entry point `DeviceService.flush` calls.
        """
        h = op_handler(self.op)
        if h is not None:
            h.prime(self, sched)
            return
        job = self.job()
        if isinstance(job, ShardedNttJob):
            return
        sched.prime(job, self.commands, param_trace=self.param_trace)

    def trace_streams(self) -> dict[tuple[int, int], list[Command]] | None:
        """Statically placed command streams, or None when placement is
        dynamic (scheduler-routed batches have no layout to record)."""
        if self.sharded_plan is not None:
            return self.sharded_plan.trace_streams()
        if isinstance(self.op, BatchOp):
            if isinstance(self.op.op, NttOp):
                # the multibank path: `count` banks on one shared-bus channel
                return {(0, i): list(self.inner.commands) for i in range(self.count)}
            return None
        return {(0, 0): list(self.commands)}

    def param_trace_streams(self) -> dict[tuple[int, int], tuple] | None:
        """Cache-residency traces keyed like `trace_streams()` — exactly
        the mapping `pimsys.trace.replay_trace(param_traces=...)` takes
        to replay a cache-enabled recording bit-exactly.  None when the
        cache is disabled or the workload has no static placement."""
        if self.param_trace is None:
            return None
        if self.sharded_plan is not None:
            sp = self.sharded_plan
            traces = sp.local_param_traces()
            out = {}
            for b in range(sp.banks):
                addr = sp.topo.address_of(sp.flat_banks[b])
                out[(addr.channel, sp.topo.local_id(addr))] = traces[b]
            return out
        if isinstance(self.op, BatchOp):
            if isinstance(self.op.op, NttOp):
                return {(0, i): self.inner.param_trace for i in range(self.count)}
            return None
        return {(0, 0): self.param_trace}


@dataclasses.dataclass(frozen=True)
class RunResult:
    """One result type for every execution path.

    `value`  — functional output (None for timing-only runs)
    `timing` — `TimingResult` (single bank), `ShardedTimingResult`,
               `MultiBankResult` (BatchOp of NTTs) or `SchedulerResult`
               (BatchOp of polymuls / `submit`); None when `time=False`
    `stats`  — device-level `StatsRegistry` snapshot for the run
    `trace`  — `TraceHandle` onto the command-level workload, when the
               workload is statically placed (scheduler runs place
               dynamically and carry no trace)
    `telemetry` — `telemetry.TelemetryHandle` onto the run's recorded
               timeline when the session's `PimConfig.telemetry` (or the
               service's `ServicePolicy.telemetry`) is on; None otherwise
    """

    op: Op
    value: np.ndarray | None
    timing: TimingResult | ShardedTimingResult | MultiBankResult | SchedulerResult | None
    stats: StatsRegistry | None
    trace: TraceHandle | None
    telemetry: TelemetryHandle | None = None


# --------------------------------------------------------------------------
# Deprecation shim support
# --------------------------------------------------------------------------


def _trace(plan: CompiledPlan) -> TraceHandle | None:
    streams = plan.trace_streams()
    return TraceHandle(streams) if streams is not None else None


def warn_legacy(name: str, replacement: str) -> None:
    """Emit the single DeprecationWarning a legacy shim owes per call."""
    warnings.warn(
        f"{name} is a legacy shim; use repro.pimsys.session.PimSession "
        f"({replacement}) to compile once and run many",
        DeprecationWarning,
        stacklevel=3,
    )


# --------------------------------------------------------------------------
# The session
# --------------------------------------------------------------------------


class PimSession:
    """Compile/execute façade over the whole `repro.pimsys` stack.

    A session pins the device: `PimConfig`, `DeviceTopology`, arbitration
    `policy`, and the `pipelined` engine mode.  Everything derived from
    those — mapper command streams, twiddle-parameter streams, the
    one-bank baseline timing, scheduler command caches — is computed once
    and reused across `compile`/`run`/`submit` calls.
    """

    def __init__(self, cfg: PimConfig | None = None,
                 topo: DeviceTopology | None = None,
                 policy: str = "rr", pipelined: bool = True):
        self.cfg = cfg or PimConfig()
        self._explicit_topo = topo is not None
        self.topo = topo or DeviceTopology.from_config(self.cfg)
        self.policy = policy
        self.pipelined = pipelined
        self._plans: dict[tuple[PimConfig, Op], CompiledPlan] = {}
        self._lowered: dict[tuple[PimConfig, Op], object] = {}
        self.plan_hits = 0
        self.plan_misses = 0
        self._baselines: dict[tuple[int, bool], TimingResult] = {}
        self._contexts: dict[tuple[int, int], ntt_ref.NttContext] = {}
        self._sched: RequestScheduler | None = None
        self._service = None   # lazy default-policy DeviceService (service())
        self._shim_svc = None  # the submit()/run(BatchOp) shim's own service

    # -- shared caches -------------------------------------------------------
    def context(self, n: int, q: int = mm.DEFAULT_Q) -> ntt_ref.NttContext:
        """Session-cached `NttContext` (twiddle tables) for modulus q."""
        key = (q, n)
        ctx = self._contexts.get(key)
        if ctx is None:
            ctx = self._contexts[key] = ntt_ref.make_context(q, n)
        return ctx

    def baseline(self, n: int, forward: bool = False) -> TimingResult:
        """One-bank `BankTimer` reference timing, cached per (n, forward).

        This is the `single` baseline sharded/multibank speedups divide
        by; the session computes it once per size instead of once per
        sweep point.
        """
        key = (n, forward)
        hit = self._baselines.get(key)
        if hit is None:
            plan = self.compile(NttOp(n, forward=forward))
            hit = self._baselines[key] = BankTimer(
                self.cfg, pipelined=self.pipelined).simulate(
                    plan.commands, plan.param_trace)
        return hit

    # -- compile -------------------------------------------------------------
    def compile(self, op: Op) -> CompiledPlan:
        """Lower an op spec to a frozen `CompiledPlan`, memoized.

        The cache key is `(cfg, op)` after spelling normalization
        (`InverseNttOp(n)` and `NttOp(n)` share an entry); a hit returns
        the identical plan object.
        """
        op = _canonical(op)
        key = (self.cfg, op)
        plan = self._plans.get(key)
        if plan is not None:
            self.plan_hits += 1
            return plan
        self.plan_misses += 1
        plan = self._plans[key] = self._compile(op)
        return plan

    def _compile(self, op: Op) -> CompiledPlan:
        cfg = self.cfg
        h = op_handler(op)
        if h is not None:
            return h.compile(self, op)
        if isinstance(op, NttOp):
            cmds = tuple(RowCentricMapper(cfg, op.n, forward=op.forward).commands())
            return CompiledPlan(
                cfg=cfg, op=op, commands=cmds, phases={"ntt": cmds},
                placement={"base_row": 0,
                           "rows": max(1, op.n // cfg.row_words)},
            )
        if isinstance(op, PolymulOp):
            raw, row_b = polymul_phases(cfg, op.n)
            phases = {k: tuple(v) for k, v in raw.items()}
            cmds = tuple(c for p in phases.values() for c in p)
            return CompiledPlan(
                cfg=cfg, op=op, commands=cmds, phases=phases,
                placement={"row_a": 0, "row_b": row_b,
                           "rows": max(1, op.n // cfg.row_words)},
            )
        if isinstance(op, ShardedNttOp):
            sharded = ShardedNttPlan(
                cfg, op.n, op.banks, forward=op.forward,
                topo=self.topo if self._explicit_topo else None,
                placement=op.placement)
            locals_ = sharded.local_streams()
            return CompiledPlan(
                cfg=cfg, op=op, commands=(),
                phases={f"local:{b}": tuple(s) for b, s in enumerate(locals_)},
                placement={"flat_banks": sharded.flat_banks},
                sharded_plan=sharded,
            )
        if isinstance(op, BatchOp):
            inner = self.compile(op.op)
            return CompiledPlan(
                cfg=cfg, op=op, commands=inner.commands, phases=inner.phases,
                placement=inner.placement, inner=inner, count=op.count,
            )
        raise TypeError(f"cannot compile {op!r}")

    # -- run -----------------------------------------------------------------
    def run(self, plan: CompiledPlan | Op, *inputs: np.ndarray,
            ctx: ntt_ref.NttContext | None = None,
            single: TimingResult | None = None,
            time: bool = True, backend: str = "engine") -> RunResult:
        """Execute a compiled plan: functional when `*inputs` are given,
        timed unless `time=False`, both by default.

        `ctx` overrides the session's cached `NttContext` (needed for a
        non-default modulus); `single` overrides the cached one-bank
        baseline that `ShardedNttOp` / `BatchOp(NttOp)` speedups
        reference (meaningless — and ignored — for the other ops).
        `backend="fastpath"` times `NttOp` / `PolymulOp` /
        `BatchOp(NttOp)` through the compiled vectorized evaluator
        (`repro.pimsys.fastpath`) — bit-identical numbers without the
        interpreted per-command event loop.  Sharded ops, queued
        `BatchOp(PolymulOp)` traffic, and telemetry runs stay on the
        interpreted engine.
        """
        if backend not in ("engine", "fastpath"):
            raise ValueError(f"unknown backend {backend!r}; "
                             "expected 'engine' or 'fastpath'")
        if backend == "fastpath" and self.cfg.telemetry:
            raise ValueError(
                "backend='fastpath' records no per-command telemetry; "
                "disable cfg.telemetry or use backend='engine'")
        if not isinstance(plan, CompiledPlan):
            plan = self.compile(plan)
        if plan.cfg != self.cfg:
            raise ValueError("plan was compiled for a different PimConfig")
        op = plan.op
        h = op_handler(op)
        if h is not None:
            return h.run(self, plan, inputs, ctx=ctx, single=single,
                         time=time, backend=backend)
        if isinstance(op, NttOp):
            return self._run_ntt(plan, inputs, ctx, time, backend)
        if isinstance(op, PolymulOp):
            return self._run_polymul(plan, inputs, ctx, time, backend)
        if isinstance(op, ShardedNttOp):
            if backend == "fastpath":
                raise ValueError(
                    "backend='fastpath' does not support sharded plans: "
                    "the cross-bank exchange phase needs the interpreted "
                    "engine's per-command bus model; run ShardedNttOp "
                    "with backend='engine'")
            return self._run_sharded(plan, inputs, ctx, single, time)
        if isinstance(op, BatchOp):
            if inputs:
                raise ValueError("BatchOp runs are timing-only; run the "
                                 "inner plan for functional output")
            if not time:  # plan-validation only: skip the device simulation
                return RunResult(op=op, value=None, timing=None, stats=None,
                                 trace=_trace(plan))
            if isinstance(op.op, NttOp):
                return self._run_multibank(plan, single, backend)
            if backend == "fastpath":
                raise ValueError("backend='fastpath' cannot drive queued "
                                 "BatchOp(PolymulOp) traffic; use "
                                 "ServicePolicy(backend='fastpath') on the "
                                 "serving path instead")
            return self._submit(plan)
        raise TypeError(f"cannot run {op!r}")

    def _require(self, inputs, k: int, what: str):
        if len(inputs) != k:
            raise ValueError(f"{what} takes {k} input polynomial(s), got {len(inputs)}")

    def _ctx_for(self, n: int, ctx: ntt_ref.NttContext | None) -> ntt_ref.NttContext:
        ctx = ctx or self.context(n)
        if ctx.n != n:
            raise ValueError(f"context is for n={ctx.n}, op is n={n}")
        return ctx

    def _tracer(self) -> Tracer | None:
        """A fresh per-run `Tracer` when `cfg.telemetry` is on."""
        return Tracer() if self.cfg.telemetry else None

    def _lowered_for(self, plan: CompiledPlan):
        """Session-cached `LoweredPlan` for a compiled plan (keyed like
        the plan cache, so repeated fastpath runs lower zero commands)."""
        inner = plan.inner if plan.inner is not None else plan
        key = (self.cfg, inner.op)
        lp = self._lowered.get(key)
        if lp is None:
            lp = self._lowered[key] = lower_plan(self.cfg, inner)
        return lp

    def _fast_timing(self, plan: CompiledPlan) -> TimingResult:
        """One-bank fastpath timing, bit-identical to `BankTimer`."""
        lp = self._lowered_for(plan)
        g = evaluate_gang(lp, 1, pipelined=self.pipelined)
        return TimingResult(ns=float(g.bank_end_ns[0]),
                            stats=dict(g.counters[0]),
                            phase_ns=phase_breakdown(lp, g.dones[:, 0]))

    def _single_bank_result(self, op, value, timing, plan,
                            tracer: Tracer | None = None) -> RunResult:
        stats = None
        if timing is not None:
            stats = StatsRegistry()
            stats.add_bank(0, 0, dict(timing.stats))
        tel = TelemetryHandle(tracer) if tracer is not None else None
        return RunResult(op=op, value=value, timing=timing, stats=stats,
                         trace=_trace(plan), telemetry=tel)

    def _run_ntt(self, plan, inputs, ctx, time, backend="engine") -> RunResult:
        op, cfg = plan.op, self.cfg
        value = None
        if inputs:
            self._require(inputs, 1, "NttOp")
            a = np.asarray(inputs[0], np.uint32)
            if a.shape[0] != op.n:
                raise ValueError(f"input length {a.shape[0]} != n={op.n}")
            if op.n < cfg.atom_words:
                raise ValueError("n must be at least one atom")
            ctx = self._ctx_for(op.n, ctx)
            bank = FunctionalBank(cfg, ctx, forward=op.forward)
            bank.load_poly(a)
            bank.run(plan.commands)
            value = bank.read_poly(op.n)
            if not op.forward and op.scale_n_inv:
                value = np.asarray(mm.np_mulmod(value, ctx.n_inv, ctx.q), np.uint32)
        timing = None
        tracer = None
        if time:
            if backend == "fastpath":
                timing = self._fast_timing(plan)
            else:
                tracer = self._tracer()
                timing = BankTimer(cfg, pipelined=self.pipelined).simulate(
                    plan.commands, plan.param_trace, tracer=tracer)
        return self._single_bank_result(op, value, timing, plan, tracer)

    def _run_polymul(self, plan, inputs, ctx, time,
                     backend="engine") -> RunResult:
        op, cfg = plan.op, self.cfg
        value = None
        if inputs:
            self._require(inputs, 2, "PolymulOp")
            a = np.asarray(inputs[0], np.uint32)
            b = np.asarray(inputs[1], np.uint32)
            if a.shape[0] != op.n or b.shape[0] != op.n:
                raise ValueError(
                    f"input lengths ({a.shape[0]}, {b.shape[0]}) != n={op.n}")
            ctx = self._ctx_for(op.n, ctx)
            row_b = plan.placement["row_b"]
            # phase-wise functional execution: the FunctionalBank resolves
            # twiddles by direction (same discipline as legacy pim_polymul)
            bank_f = FunctionalBank(cfg, ctx, forward=True)
            bank_f.load_poly(a, base_row=0)
            bank_f.load_poly(b, base_row=row_b)
            bank_f.run(plan.phases["fwd_a"])
            bank_f.run(plan.phases["fwd_b"])
            bank_f.run(plan.phases["pointwise"])
            bank_i = FunctionalBank(cfg, ctx, forward=False)
            bank_i.mem = bank_f.mem  # share the memory image
            bank_i.run(plan.phases["inv_a"])
            value = bank_i.read_poly(op.n)
            value = np.asarray(mm.np_mulmod(value, ctx.n_inv, ctx.q), np.uint32)
        timing = None
        tracer = None
        if time:
            if backend == "fastpath":
                timing = self._fast_timing(plan)
            else:
                tracer = self._tracer()
                timing = BankTimer(cfg, pipelined=self.pipelined).simulate(
                    plan.commands, plan.param_trace, tracer=tracer)
        return self._single_bank_result(op, value, timing, plan, tracer)

    def _run_sharded(self, plan, inputs, ctx, single, time) -> RunResult:
        op = plan.op
        sharded = plan.sharded_plan
        value = None
        if inputs:
            self._require(inputs, 1, "ShardedNttOp")
            a = np.asarray(inputs[0], np.uint32)
            ctx = self._ctx_for(op.n, ctx)
            value = sharded.run_functional(a, ctx)
            if not op.forward and op.scale_n_inv:
                value = np.asarray(mm.np_mulmod(value, ctx.n_inv, ctx.q), np.uint32)
        timing = None
        stats = None
        tracer = None
        if time:
            tracer = self._tracer()
            timing = sharded.simulate(
                policy=self.policy,
                single=single or self.baseline(op.n, op.forward),
                pipelined=self.pipelined, tracer=tracer)
            stats = timing.stats
        return RunResult(op=op, value=value, timing=timing, stats=stats,
                         trace=_trace(plan),
                         telemetry=(TelemetryHandle(tracer)
                                    if tracer is not None else None))

    def _run_multibank(self, plan, single, backend="engine") -> RunResult:
        """`count` identical NTT streams on one shared-bus channel — the
        §VII multi-bank experiment, cross-checked against the analytic
        bus bound (bit-identical to legacy `simulate_multibank`).

        With `backend="fastpath"` the gang is timed by the vectorized
        evaluator instead of the interpreted `ChannelController` —
        same makespan, bus occupancy, and per-bank counters to the bit
        (rr arbitration only; telemetry already rejected in `run`)."""
        op: BatchOp = plan.op
        inner: NttOp = op.op
        cfg, banks = self.cfg, op.count
        single = single or self.baseline(inner.n, inner.forward)
        trace = plan.param_trace  # one device-side cache per bank, same stream
        if backend == "fastpath":
            return self._run_multibank_fast(plan, single, banks, trace)
        tracer = self._tracer()
        ctrl = ChannelController(cfg, policy=self.policy, tracer=tracer)
        for i in range(banks):
            ctrl.enqueue(ctrl.add_bank(pipelined=self.pipelined),
                         plan.inner.commands, job_id=i, param_trace=trace)
        ctrl.drain()
        latency = ctrl.makespan_ns
        analytic = analytic_multibank_bound(inner.n, banks, cfg, single,
                                            param_trace=trace)
        if latency < analytic - 1e-6:  # not an assert: must survive python -O
            raise RuntimeError(
                f"controller beat the analytic bus bound: {latency} < {analytic}")
        speedup = banks * single.ns / latency
        if tracer is not None:
            tracer.meta.setdefault("dram_ns", cfg.dram_ns)
        stats = StatsRegistry(channels=1)
        ctrl.record_stats(stats)
        timing = MultiBankResult(
            banks=banks,
            latency_ns=latency,
            speedup=speedup,
            efficiency=speedup / banks,
            bus_utilization=min(1.0, ctrl.bus_busy_ns / latency),
            analytic_latency_ns=analytic,
            policy=self.policy,
            param_hit_rate=stats.param_hit_rate(),
        )
        return RunResult(op=op, value=None, timing=timing, stats=stats,
                         trace=_trace(plan),
                         telemetry=(TelemetryHandle(tracer)
                                    if tracer is not None else None))

    def _run_multibank_fast(self, plan, single, banks, trace) -> RunResult:
        if self.policy != "rr":
            raise ValueError(
                f"backend='fastpath' models round-robin arbitration only; "
                f"policy={self.policy!r} needs backend='engine'")
        cfg = self.cfg
        inner: NttOp = plan.op.op
        lp = self._lowered_for(plan)
        g = evaluate_gang(lp, banks, pipelined=self.pipelined)
        latency = g.makespan_ns
        analytic = analytic_multibank_bound(inner.n, banks, cfg, single,
                                            param_trace=trace)
        if latency < analytic - 1e-6:
            raise RuntimeError(
                f"fastpath beat the analytic bus bound: {latency} < {analytic}")
        speedup = banks * single.ns / latency
        stats = StatsRegistry(channels=1)
        for b in range(banks):
            stats.add_bank(0, b, dict(g.counters[b]))
        stats.add_bus(0, g.bus_busy_ns, latency)
        timing = MultiBankResult(
            banks=banks,
            latency_ns=latency,
            speedup=speedup,
            efficiency=speedup / banks,
            bus_utilization=min(1.0, g.bus_busy_ns / latency),
            analytic_latency_ns=analytic,
            policy=self.policy,
            param_hit_rate=stats.param_hit_rate(),
        )
        return RunResult(op=plan.op, value=None, timing=timing, stats=stats,
                         trace=_trace(plan))

    # -- submit: queued / open-loop traffic through the device service -------
    def scheduler(self) -> RequestScheduler:
        """The session's persistent `RequestScheduler` (lazy).

        Persisting it lets the scheduler's command and sharded-gang
        caches compound across `submit` calls; results are unaffected
        (every run simulates on a fresh `Device`)."""
        if self._sched is None:
            self._sched = RequestScheduler(self.cfg, self.topo,
                                           policy=self.policy,
                                           pipelined=self.pipelined)
        return self._sched

    def service(self, policy=None):
        """A `DeviceService` over this session — the async serving API.

        With `policy=None` returns the session's persistent
        default-policy service (FIFO-equivalent dispatch, the parity
        anchor `submit()` shims onto); pass a `ServicePolicy` for a
        dedicated service with QoS weights, admission control, or
        batching."""
        from repro.pimsys.service import DeviceService

        if policy is not None:
            return DeviceService(self, policy=policy)
        if self._service is None:
            self._service = DeviceService(self)
        return self._service

    def submit(self, plan: CompiledPlan | Op, count: int = 1, *,
               rate_per_us: float | None = None, seed: int = 0) -> RunResult:
        """Deprecated shim: route `count` copies of a plan through the
        default-policy `DeviceService` (closed loop by default,
        `rate_per_us` for open-loop Poisson arrivals) — bit-identical
        to the pre-service FIFO scheduler path.  Use
        `session.service().submit(...)` / `submit_poisson(...)` for
        futures, QoS classes, admission control, and batching.
        """
        warn_legacy("PimSession.submit",
                    "service().submit / submit_poisson for futures and QoS")
        return self._submit(plan, count, rate_per_us=rate_per_us, seed=seed)

    def _submit(self, plan: CompiledPlan | Op, count: int = 1, *,
                rate_per_us: float | None = None, seed: int = 0,
                qos: str = "throughput",
                deadline_us: float | None = None) -> RunResult:
        """Warning-free service submission (the shim's body; also the
        internal path for `run(BatchOp(PolymulOp, k))` and the legacy
        entry-point shims)."""
        if not isinstance(plan, CompiledPlan):
            plan = self.compile(plan)
        if plan.cfg != self.cfg:
            raise ValueError("plan was compiled for a different PimConfig")
        if isinstance(plan.op, BatchOp):
            return dataclasses.replace(
                self._submit(plan.inner, count=count * plan.count,
                             rate_per_us=rate_per_us, seed=seed, qos=qos,
                             deadline_us=deadline_us),
                op=plan.op)
        if count < 1:  # legacy parity: an empty batch is a valid empty run
            res = self.scheduler().run_service([], seed=seed)
            return RunResult(op=plan.op, value=None, timing=res,
                             stats=res.stats, trace=None)
        # a dedicated service: the shim must not disturb (or trip over)
        # pending futures on the user-facing service() singleton
        if self._shim_svc is None:
            from repro.pimsys.service import DeviceService

            self._shim_svc = DeviceService(self)
        svc = self._shim_svc
        if rate_per_us is None:
            for _ in range(count):
                svc.submit(plan, qos=qos, deadline_us=deadline_us)
        else:
            svc.submit_poisson(plan, count, rate_per_us, qos=qos,
                               deadline_us=deadline_us, seed=seed)
        # retain=False: the shim hands the result straight back, so the
        # internal service must not accumulate epoch history
        res = svc.flush(retain=False)
        return RunResult(op=plan.op, value=None, timing=res, stats=res.stats,
                         trace=None, telemetry=res.telemetry)
