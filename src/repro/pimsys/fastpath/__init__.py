"""repro.pimsys.fastpath — compiled vectorized timing backend.

Lowers a `CompiledPlan`'s frozen command stream to dense numpy arrays
once (`lower_plan` / `lower_commands`) and evaluates homogeneous
multibank gangs as block-speculative array recurrences
(`evaluate_gang`) instead of the interpreted per-command event loop —
bit-identical results at a fraction of the cost, which is what lets
`benchmarks/serving.py --full` sweep millions of requests.

The interpreted engine stays the ground truth: `verify` /
`verify_stream` replay a workload through both and raise
`FastpathMismatch` on any divergence.  Session/serving entry points:
`PimSession.run(plan, backend="fastpath")` and
`ServicePolicy(backend="fastpath", verify_every=K)`.
"""
from .evaluate import (
    FastpathMismatch,
    GangResult,
    evaluate_gang,
    phase_breakdown,
    verify,
    verify_stream,
)
from .lowering import LoweredPlan, lower_commands, lower_plan

__all__ = [
    "FastpathMismatch",
    "GangResult",
    "LoweredPlan",
    "evaluate_gang",
    "lower_commands",
    "lower_plan",
    "phase_breakdown",
    "verify",
    "verify_stream",
]
