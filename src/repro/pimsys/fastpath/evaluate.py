"""Block-speculative vectorized evaluation of lowered command streams.

The interpreted hierarchy (`repro.pimsys.engine`) walks one command at a
time through a Python event loop.  For a *homogeneous gang* — `banks`
copies of one stream behind one shared command bus under the default
round-robin arbiter — the grant order is statically known: with every
queue non-empty and every head gated at t=0, `ChannelEngine._pick`
always grants the next bank cyclically, so round ``r`` issues command
``r`` on banks ``[1, 2, .., n-1, 0]`` and the whole schedule collapses
to array recurrences over the `LoweredPlan` arrays.

The evaluator exploits the workload's character: multibank gangs are
*bus-bound* (each command's dependencies usually resolve before the bus
grants), so it **speculates** K rounds at a time assuming the bus alone
binds every start:

1. one `cumsum` over interleaved ``[param_ns, t_bus]`` increments yields
   every speculative start/grant in the block (`np.cumsum` accumulates
   left-to-right, so the chain reproduces the interpreted engine's
   float adds bit-for-bit);
2. completion times follow elementwise: ``done = (s + add1) + add2``;
3. per-round dependency maxima gather from the provisional history via
   the lowered predecessor tables (`max` is exact in floating point, so
   gather-and-reduce order is free);
4. a round validates iff every bank's dependencies resolve at or before
   its grant AND no refresh window opens; the valid prefix commits, the
   first failing round replays through an exact scalar fallback, and
   speculation resumes after it.

Dep-bound streams (small gangs, the single-bank profile case) would
fail speculation every round, so a short failure streak flips the
evaluator into scalar bursts with periodic re-probes — the fallback IS
the interpreted recurrence, just over dense arrays, so results stay
bit-identical either way.  Refresh (`tREFI/tRFC`), the param-cache
hit/miss beat charges, write-recovery (`tWR`), the row-quiesce fence,
and the unpipelined serial barrier are all modeled exactly.

`backend="jax"` swaps the sequential bus chain for a jitted
`jax.lax.scan` (x64), keeping the same bit-exact left-fold semantics —
the seam the kernels lane (`src/repro/kernels/`) plugs into.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pim_config import PimConfig

from .lowering import LoweredPlan, P_HIT, P_MISS, lower_commands, lower_plan

__all__ = ["GangResult", "FastpathMismatch", "evaluate_gang",
           "phase_breakdown", "verify_stream", "verify"]

_NEG_INF = float("-inf")


class FastpathMismatch(RuntimeError):
    """Fastpath and interpreted-engine results disagree — a timing-model
    bug, raised by the differential oracle (`verify` / sampled serving
    verification), never by normal evaluation."""


@dataclasses.dataclass(frozen=True, eq=False)
class GangResult:
    """Timing of one homogeneous gang: `banks` copies of one stream on
    one shared-bus channel, bit-identical to the interpreted engine.

    `starts`/`dones` are (n_cmds, banks) — column b is bank b's per-round
    schedule in issue order (what a `telemetry.Tracer` would record).
    """

    banks: int
    makespan_ns: float
    bank_end_ns: np.ndarray      # (banks,) per-bank end_t
    bus_busy_ns: float           # shared-bus occupancy, arbiter bookkeeping
    counters: tuple              # per-bank stats dicts, BankEngine key rules
    starts: np.ndarray           # (n_cmds, banks) f8
    dones: np.ndarray            # (n_cmds, banks) f8
    fallback_rounds: int         # rounds replayed via the scalar path


def evaluate_gang(lowered: LoweredPlan, banks: int, *, pipelined: bool = True,
                  backend: str = "numpy", block: int = 96) -> GangResult:
    """Evaluate `banks` copies of a lowered stream on one shared bus.

    Reproduces `ChannelEngine` under the default round-robin arbiter
    (every stream enqueued at gate 0, drained to completion) exactly:
    same makespans, same per-command start/done floats, same stat
    counters.  `banks=1` additionally matches the paper's `BankTimer`.
    """
    if banks < 1:
        raise ValueError("evaluate_gang: banks must be >= 1")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"evaluate_gang: unknown backend {backend!r}")
    chain = _numpy_chain
    if backend == "jax":
        from .jax_backend import jax_chain
        chain = jax_chain

    lp = lowered
    C = lp.n_cmds
    n = banks
    if C == 0:
        return GangResult(banks=n, makespan_ns=0.0,
                          bank_end_ns=np.zeros(n), bus_busy_ns=0.0,
                          counters=tuple({} for _ in range(n)),
                          starts=np.zeros((0, n)), dones=np.zeros((0, n)),
                          fallback_rounds=0)
    if n == 1:
        # no arbitration: one flat native-float scan over the dense
        # tables beats both the vector path and the interpreted loop
        return _evaluate_single(lp, pipelined)

    # History arrays.  Rows [0, C) are per-round values; the tail rows
    # back the sentinel predecessor indices with neutral values so that
    # padded gathers reproduce the engine's zero initial state exactly:
    # done sentinel = 0.0, col sentinel -tCCD (+tCCD -> 0.0), act
    # sentinel -tRAS (+tRAS -> 0.0).
    S = np.zeros((C + 2, n))
    DONE = np.zeros((C + 1, n))
    S[C, :] = -lp.t_ccd
    S[C + 1, :] = -lp.t_ras

    bank_of_pos = (np.arange(n) + 1) % n    # grant position -> bank id
    refresh_ct = [0] * n
    wmax = np.full(n, _NEG_INF)     # write-recovery component of act_start_ok
    qui = np.full(n, _NEG_INF)      # row_quiesce running max
    B_state = 0.0                   # shared-bus free time
    t_bus, t_ccd, t_ras, t_wr = lp.t_bus, lp.t_ccd, lp.t_ras, lp.t_wr

    nref = [lp.trefi] * n           # python-float refresh clocks
    trfc, trefi = lp.trfc, lp.trefi
    # native-typed per-round tables so the exact fallback round pays no
    # numpy scalar extraction
    done_preds = lp.done_preds
    col_pred_l = lp.col_pred.tolist()
    act_pred_l = lp.act_pred.tolist()
    pn_l = lp.pn.tolist()
    a1_l = lp.add1.tolist()
    a2_l = lp.add2.tolist()
    dram_l = lp.dram.tolist()
    act_l = lp.act_mask.tolist()
    wr_l = lp.wr_mask.tolist()
    qui_l = lp.qui_mask.tolist()

    def exact_round(r: int, B: float) -> float:
        """Exact interpreted recurrence for one full arbitration round:
        per-bank dependency maxima gather vectorized (max reduction is
        exact in float, so order is free), then the short sequential bus
        scan over the n grant slots in native floats — every add in the
        same order the interpreted handlers perform it."""
        dep = DONE[done_preds[r]].max(axis=0)
        np.maximum(dep, S[col_pred_l[r]] + t_ccd, out=dep)
        np.maximum(dep, S[act_pred_l[r]] + t_ras, out=dep)
        if act_l[r]:
            np.maximum(dep, wmax, out=dep)
            np.maximum(dep, qui, out=dep)
        if not pipelined and r > 0:
            np.maximum(dep, DONE[r - 1], out=dep)
        dl = dep.tolist()
        pn = pn_l[r]
        a1 = a1_l[r]
        a2 = a2_l[r]
        is_dram = dram_l[r]
        s_row = [0.0] * n
        d_row = [0.0] * n
        for pos in range(n):
            b = pos + 1 if pos + 1 < n else 0
            d = dl[b]
            s = B if B >= d else d
            if is_dram and s >= nref[b]:
                nr = nref[b]
                while s >= nr:
                    refresh_ct[b] += 1
                    t = nr + trfc
                    if t > s:
                        s = t
                    nr += trefi
                nref[b] = nr
            s = s + pn
            s_row[b] = s
            d_row[b] = (s + a1) + a2
            B = s + t_bus
        S[r] = s_row
        DONE[r] = d_row
        if wr_l[r]:
            np.maximum(wmax, DONE[r] + t_wr, out=wmax)
        if qui_l[r]:
            np.maximum(qui, DONE[r], out=qui)
        return B

    fallback = 0
    streak = 0          # consecutive blocks that failed at their 1st round
    K_adapt = block     # block size tracks the recent valid-prefix length
    r = 0
    while r < C:
        if streak >= 2:
            # dep-bound regime: run an exact-round burst, then probe again
            stop = min(C, r + 64)
            while r < stop:
                B_state = exact_round(r, B_state)
                fallback += 1
                r += 1
            streak = 0
            continue
        K = min(K_adapt, C - r)
        sl = slice(r, r + K)

        # 1. speculative bus chain: starts assuming the bus alone binds
        vals = chain(B_state, lp.pn[sl], n, t_bus)
        S_b = np.empty((K, n))
        G_b = np.empty((K, n))
        S_b[:, bank_of_pos] = vals[1::2].reshape(K, n)
        G_b[:, bank_of_pos] = vals[0::2][:-1].reshape(K, n)

        # 2. provisional completion times into history
        S[sl] = S_b
        D_b = (S_b + lp.add1[sl, None]) + lp.add2[sl, None]
        DONE[sl] = D_b

        # 3. dependency maxima from the (provisional) history
        dep = DONE[lp.done_preds[sl]].max(axis=1)
        np.maximum(dep, S[lp.col_pred[sl]] + t_ccd, out=dep)
        np.maximum(dep, S[lp.act_pred[sl]] + t_ras, out=dep)
        wr_blk = lp.wr_mask[sl]
        qui_blk = lp.qui_mask[sl]
        act_blk = lp.act_mask[sl]
        contrib_w = np.where(wr_blk[:, None], D_b + t_wr, _NEG_INF)
        contrib_q = np.where(qui_blk[:, None], D_b, _NEG_INF)
        if act_blk.any():
            accw = np.maximum.accumulate(
                np.concatenate([wmax[None], contrib_w[:-1]]), axis=0)
            accq = np.maximum.accumulate(
                np.concatenate([qui[None], contrib_q[:-1]]), axis=0)
            wq = np.maximum(accw, accq)
            dep = np.where(act_blk[:, None], np.maximum(dep, wq), dep)
        if not pipelined:
            barr = np.empty((K, n))
            barr[0] = DONE[r - 1] if r > 0 else 0.0
            barr[1:] = D_b[:-1]
            np.maximum(dep, barr, out=dep)

        # 4. validate: deps resolved by grant time, no refresh window
        ok = (dep <= G_b).all(axis=1)
        ref_bad = (S_b >= np.asarray(nref)[None, :]).any(axis=1)
        ok &= ~(lp.dram[sl] & ref_bad)
        m = K if ok.all() else int(np.argmin(ok))
        # size the next block to the observed valid-prefix length, so a
        # marginal regime stops paying full-block cost for short commits
        K_adapt = (min(block, K_adapt * 2) if m == K
                   else max(8, min(K_adapt, 2 * max(m, 1))))

        # 5. commit the valid prefix, scalar-replay the failing round
        if m > 0:
            np.maximum(wmax, contrib_w[:m].max(axis=0), out=wmax)
            np.maximum(qui, contrib_q[:m].max(axis=0), out=qui)
            B_state = float(vals[2 * m * n])
            streak = 0
        r += m
        if m < K:
            if m == 0:
                streak += 1
            B_state = exact_round(r, B_state)
            fallback += 1
            r += 1

    starts = S[:C]
    dones = DONE[:C]
    bank_end = dones.max(axis=0)
    # the interpreted arbiter accumulates (param_ns + t_bus) per issue,
    # left to right; cumsum is the same left fold, so the total is exact
    bus_busy = float(np.cumsum(np.repeat(lp.bus_inc, n))[-1])

    counters = []
    for b in range(n):
        stats = {key: cnt for key, cnt in lp.class_counts}
        if lp.has_bu:
            stats["bu_ops"] = lp.bu_ops
        if lp.n_param_hit:
            stats["param_hit"] = lp.n_param_hit
        if lp.n_param_miss:
            stats["param_miss"] = lp.n_param_miss
        if refresh_ct[b]:
            stats["refresh"] = int(refresh_ct[b])
        counters.append(stats)

    return GangResult(banks=n, makespan_ns=float(bank_end.max()),
                      bank_end_ns=bank_end, bus_busy_ns=bus_busy,
                      counters=tuple(counters), starts=starts, dones=dones,
                      fallback_rounds=fallback)


def _evaluate_single(lp: LoweredPlan, pipelined: bool) -> GangResult:
    """banks=1 special case: no arbitration, so the schedule is one
    strict left fold — a native-float scan over the dense tables, every
    add/max in the interpreted `BankTimer` order."""
    C = lp.n_cmds
    preds = lp.done_preds.tolist()
    col_p = lp.col_pred.tolist()
    act_p = lp.act_pred.tolist()
    pn_l = lp.pn.tolist()
    a1_l = lp.add1.tolist()
    a2_l = lp.add2.tolist()
    dram_l = lp.dram.tolist()
    act_l = lp.act_mask.tolist()
    wr_l = lp.wr_mask.tolist()
    qui_l = lp.qui_mask.tolist()
    t_bus, t_ccd, t_ras, t_wr = lp.t_bus, lp.t_ccd, lp.t_ras, lp.t_wr
    trfc, trefi = lp.trfc, lp.trefi

    S0 = [0.0] * (C + 2)
    D0 = [0.0] * (C + 1)
    S0[C] = -t_ccd
    S0[C + 1] = -t_ras
    B = 0.0
    wm = qu = _NEG_INF
    nr = trefi
    refresh = 0
    barrier = 0.0
    end_t = 0.0
    for r in range(C):
        d = 0.0
        for p in preds[r]:
            v = D0[p]
            if v > d:
                d = v
        v = S0[col_p[r]] + t_ccd
        if v > d:
            d = v
        v = S0[act_p[r]] + t_ras
        if v > d:
            d = v
        if act_l[r]:
            if wm > d:
                d = wm
            if qu > d:
                d = qu
        if not pipelined and barrier > d:
            d = barrier
        s = B if B >= d else d
        if dram_l[r] and s >= nr:
            while s >= nr:
                refresh += 1
                t = nr + trfc
                if t > s:
                    s = t
                nr += trefi
        s = s + pn_l[r]
        done = (s + a1_l[r]) + a2_l[r]
        S0[r] = s
        D0[r] = done
        B = s + t_bus
        if done > end_t:
            end_t = done
        if not pipelined:
            barrier = done
        if wr_l[r]:
            w = done + t_wr
            if w > wm:
                wm = w
        if qui_l[r] and done > qu:
            qu = done

    stats = {key: cnt for key, cnt in lp.class_counts}
    if lp.has_bu:
        stats["bu_ops"] = lp.bu_ops
    if lp.n_param_hit:
        stats["param_hit"] = lp.n_param_hit
    if lp.n_param_miss:
        stats["param_miss"] = lp.n_param_miss
    if refresh:
        stats["refresh"] = refresh
    bus_busy = float(np.cumsum(lp.bus_inc)[-1]) if C else 0.0
    return GangResult(banks=1, makespan_ns=end_t,
                      bank_end_ns=np.array([end_t]), bus_busy_ns=bus_busy,
                      counters=(stats,),
                      starts=np.asarray(S0[:C])[:, None],
                      dones=np.asarray(D0[:C])[:, None],
                      fallback_rounds=0)


def _numpy_chain(b0: float, pn_blk: np.ndarray, n: int,
                 t_bus: float) -> np.ndarray:
    """Speculative bus chain ``[b0, s_1, B_1, s_2, B_2, ...]`` over K
    rounds x n banks: ``s = B_prev + param_ns``, ``B = s + t_bus``.
    `np.cumsum` is a strict left fold, so each value carries exactly the
    float adds the interpreted arbiter performs."""
    K = len(pn_blk)
    arr = np.empty(1 + 2 * K * n)
    arr[0] = b0
    arr[1::2] = np.repeat(pn_blk, n)
    arr[2::2] = t_bus
    return np.cumsum(arr)


def phase_breakdown(lowered: LoweredPlan, dones: np.ndarray) -> dict:
    """Reconstruct `BankTimer`-style `phase_ns` from a single-bank done
    column, replaying the Mark bookkeeping over the running end time."""
    run_end = np.maximum.accumulate(dones) if len(dones) else dones
    phase_ns: dict[str, float] = {}
    name, start = "intra", 0.0
    for pos, mark in lowered.marks:
        end_here = float(run_end[pos - 1]) if pos else 0.0
        phase_ns[name] = phase_ns.get(name, 0.0) + (end_here - start)
        name, start = mark, end_here
    end_t = float(run_end[-1]) if len(dones) else 0.0
    phase_ns[name] = phase_ns.get(name, 0.0) + (end_t - start)
    return phase_ns


# --------------------------------------------------------------------------
# Differential oracle — the interpreted engine stays the ground truth
# --------------------------------------------------------------------------


def verify_stream(cfg: PimConfig, commands, banks: int, *,
                  param_trace=None, pipelined: bool = True,
                  backend: str = "numpy") -> GangResult:
    """Replay one homogeneous gang through BOTH the fastpath and the
    interpreted `ChannelEngine`, asserting bit-identical makespans,
    per-bank stat counters, and bus occupancy.  Raises
    `FastpathMismatch` on any disagreement; returns the fastpath result.
    """
    from repro.pimsys.engine import replay_gang

    lp = lower_commands(cfg, commands, param_trace)
    g = evaluate_gang(lp, banks, pipelined=pipelined, backend=backend)
    eng = replay_gang(cfg, commands, banks, param_trace=param_trace,
                      pipelined=pipelined)
    if eng.makespan_ns != g.makespan_ns:
        raise FastpathMismatch(
            f"fastpath makespan {g.makespan_ns!r} != interpreted "
            f"{eng.makespan_ns!r} (banks={banks})")
    if eng.bus_busy_ns != g.bus_busy_ns:
        raise FastpathMismatch(
            f"fastpath bus_busy {g.bus_busy_ns!r} != interpreted "
            f"{eng.bus_busy_ns!r} (banks={banks})")
    for b in range(banks):
        ref = dict(eng.engines[b].stats)
        if ref != g.counters[b]:
            raise FastpathMismatch(
                f"fastpath stats diverge on bank {b}: {g.counters[b]!r} "
                f"!= interpreted {ref!r}")
        if eng.engines[b].end_t != float(g.bank_end_ns[b]):
            raise FastpathMismatch(
                f"fastpath end_t diverges on bank {b}: "
                f"{float(g.bank_end_ns[b])!r} != {eng.engines[b].end_t!r}")
    return g


def verify(plan, seed: int = 0, *, banks: int | None = None,
           pipelined: bool = True, backend: str = "numpy") -> float:
    """Differential oracle entry point: evaluate `plan` as a homogeneous
    gang through the fastpath AND the interpreted engine, assert equal
    makespans/stat counters, and return the makespan.  `seed` draws the
    gang width when `banks` is None — the sampled-verification hook the
    serving path and CI use."""
    if banks is None:
        banks = int(np.random.default_rng(seed).integers(1, 17))
    inner = plan.inner if plan.inner is not None else plan
    if inner.sharded_plan is not None or not inner.commands:
        raise ValueError("verify: plan has no homogeneous command stream")
    g = verify_stream(plan.cfg, inner.commands, banks,
                      param_trace=inner.param_trace, pipelined=pipelined,
                      backend=backend)
    return g.makespan_ns
