"""Optional jax backend for the fastpath bus chain.

The only sequential recurrence in the evaluator is the speculative bus
chain (everything else is elementwise / exact-max gathers), so the jax
backend swaps exactly that seam: a jitted `jax.lax.scan` in float64
(x64 scoped via `jax.experimental.enable_x64` so importing the backend
never mutates process-global jax config).
`lax.scan` is a strict left fold — the same add-by-add semantics as
`np.cumsum` — so results remain bit-identical to the interpreted
engine (asserted by `tests/test_fastpath_props.py` when jax is
importable).  This mirrors the kernels lane
(`src/repro/kernels/ntt.py`): scan for the sequential skeleton, fused
elementwise math around it, and keeps the two backends behind one
`evaluate_gang(..., backend=)` signature.

Import is lazy and gated: environments without the jax toolchain never
touch this module (`backend="numpy"` is the default everywhere).
"""
from __future__ import annotations

import numpy as np

try:  # pragma: no cover - exercised only where jax is installed
    import jax
    import jax.numpy as jnp

    HAS_JAX = True
except Exception:  # pragma: no cover
    jax = None
    jnp = None
    HAS_JAX = False

__all__ = ["HAS_JAX", "jax_chain"]


if HAS_JAX:

    @jax.jit
    def _scan_chain(b0, inc):
        def step(carry, x):
            nxt = carry + x
            return nxt, nxt

        _, vals = jax.lax.scan(step, b0, inc)
        return vals


def jax_chain(b0: float, pn_blk: np.ndarray, n: int,
              t_bus: float) -> np.ndarray:
    """`_numpy_chain` semantics on the jax backend: returns the
    ``[b0, s_1, B_1, ...]`` chain as a float64 numpy array."""
    if not HAS_JAX:  # pragma: no cover
        raise RuntimeError(
            "fastpath backend='jax' requested but jax is not importable; "
            "use backend='numpy'")
    K = len(pn_blk)
    inc = np.empty(2 * K * n)
    inc[0::2] = np.repeat(pn_blk, n)
    inc[1::2] = t_bus
    # x64 is scoped, never flipped globally: importing (or using) this
    # backend must not change dtype defaults for unrelated jax code in
    # the same process (jit re-traces under the scoped config)
    with jax.experimental.enable_x64():
        vals = np.asarray(_scan_chain(jnp.float64(b0), jnp.asarray(inc)))
    out = np.empty(1 + 2 * K * n)
    out[0] = b0
    out[1:] = vals
    return out
