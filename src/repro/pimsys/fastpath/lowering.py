"""Lowering: frozen command streams -> dense arrays (`LoweredPlan`).

NTT-PIM's schedules are static: a `CompiledPlan` is a frozen command
stream whose hazards are all *structural* — each dependency a command
waits on (`col_t`, `cu_t`, `row_usable_t`, `data_ready`/`buf_free` per
buffer, `reg_ready` per register) is last-written by a *fixed earlier
command index*, the same index on every bank of a homogeneous gang.
Lowering replays the stream once symbolically and materializes that
structure as dense numpy arrays:

``kind``/``dram``/masks
    per-command class code and class-membership masks (refresh-checked
    DRAM ops, Act rounds, write-recovery contributors, row-quiesce
    contributors).
``pn``/``code``/``bus_inc``
    per-command parameter-beat cost in ns (resolved from the plan's
    `param_trace` exactly as `ChannelEngine.enqueue` does — a cache hit
    pays the re-select beat, a miss the full `param_load_cycles`) and
    the bus occupancy increment `pn + t_bus`.
``add1``/``add2``
    completion constants so ``done = (s + add1) + add2`` reproduces each
    `BankEngine` handler's float operation order bit-for-bit.
``done_preds``/``col_pred``/``act_pred``
    predecessor command indices.  `done_preds` is a fixed-width table of
    indices whose *done* time the command waits on; `col_pred`/`act_pred`
    index the *start* time of the last column op (+``tCCD``) / last Act
    (+``tRAS``).  Padding rows use sentinel indices that the evaluator
    backs with neutral values, so gathers need no masking.

The evaluator (`repro.pimsys.fastpath.evaluate`) turns these arrays into
start/done schedules without touching Python command objects again.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.mapping import (
    Act,
    BUWord,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    WordLoad,
    WordStore,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import PARAM_OPS

__all__ = ["LoweredPlan", "lower_commands", "lower_plan"]

# command-kind codes (LoweredPlan.kind values, dense per-class dispatch)
K_ACT, K_COL_READ, K_COL_WRITE, K_C1, K_C2, K_CMUL = range(6)
K_WORD_LOAD, K_WORD_STORE, K_BU_WORD = 6, 7, 8

_KIND = {
    Act: K_ACT, ColRead: K_COL_READ, ColWrite: K_COL_WRITE,
    C1: K_C1, C2: K_C2, CMul: K_CMUL,
    WordLoad: K_WORD_LOAD, WordStore: K_WORD_STORE, BUWord: K_BU_WORD,
}
_STAT_KEY = ("act", "col_read", "col_write", "c1", "c2", "cmul",
             "word_load", "word_store", "bu_word")
# refresh-checked DRAM classes (CU ops never consult the refresh clock)
_DRAM = (True, True, True, False, False, False, True, True, False)
# classes whose issue contributes done+tWR to act_start_ok
_WR = (False, False, True, False, False, False, False, True, False)
# classes whose done feeds row_quiesce (read only by Act)
_QUI = (False, True, True, False, False, False, True, True, False)
# classes that update / wait on the column-command cadence (col_t)
_COL = (False, True, True, False, False, False, True, True, False)

# queue-entry param codes, mirrored from repro.pimsys.engine
P_NONE, P_MISS, P_HIT = 0, 1, 2


@dataclasses.dataclass(frozen=True, eq=False)
class LoweredPlan:
    """Dense-array form of one homogeneous command stream (Marks stripped).

    All arrays are indexed by *round* — the stream position after Mark
    stripping; round ``r`` is the r-th command every bank of a gang
    issues.  Sentinel predecessor indices: ``n_cmds`` rows of the
    evaluator's history arrays hold the neutral initial values (0.0 for
    done-type deps, ``-tCCD``/``-tRAS`` for the start-type deps so the
    padded term lands exactly on the engine's 0.0 initial state).
    """

    cfg: PimConfig
    n_cmds: int
    kind: np.ndarray        # (n_cmds,) int8, K_* codes
    dram: np.ndarray        # (n_cmds,) bool — refresh-checked rounds
    pn: np.ndarray          # (n_cmds,) f8 — parameter-beat ns (0 for non-CU)
    code: np.ndarray        # (n_cmds,) int8 — P_NONE / P_MISS / P_HIT
    add1: np.ndarray        # (n_cmds,) f8 — done = (s + add1) + add2
    add2: np.ndarray        # (n_cmds,) f8
    bus_inc: np.ndarray     # (n_cmds,) f8 — pn + t_bus, the bus occupancy
    done_preds: np.ndarray  # (n_cmds, T) int32 — wait-on-done indices
    col_pred: np.ndarray    # (n_cmds,) int32 — last col op (start + tCCD)
    act_pred: np.ndarray    # (n_cmds,) int32 — last Act (start + tRAS)
    act_mask: np.ndarray    # (n_cmds,) bool — Act rounds (read wr/quiesce)
    wr_mask: np.ndarray     # (n_cmds,) bool — contribute done+tWR
    qui_mask: np.ndarray    # (n_cmds,) bool — contribute done to quiesce
    class_counts: tuple     # ((stat_key, count), ...) for classes present
    bu_ops: int             # total butterfly ops per bank
    has_bu: bool            # any C1/C2/BUWord issued (bu_ops key exists)
    n_param_hit: int
    n_param_miss: int
    marks: tuple            # ((round_index, phase_name), ...) in order
    # timing constants, precomputed exactly as BankEngine.__init__ does
    t_bus: float
    t_ccd: float
    t_ras: float
    t_wr: float
    trefi: float
    trfc: float


def lower_commands(
    cfg: PimConfig,
    commands: Sequence[Command],
    param_trace: Sequence[tuple[int, int]] | None = None,
) -> LoweredPlan:
    """Lower one command stream under `cfg` to a `LoweredPlan`.

    `param_trace` is the plan's precomputed cache-residency trace
    (`param_beat_trace`); without one every CU op pays the flat
    `param_load_cycles` beats, exactly like the interpreted engine.
    Raises ValueError when `cfg` enables rank timing — the fastpath
    models the default gate-free rank (`tFAW/tRRD/tRTW/tWTR == 0`).
    """
    if cfg.tFAW or cfg.tRRD or cfg.tRTW or cfg.tWTR:
        raise ValueError(
            "fastpath models the gate-free rank; rank timing "
            "(tFAW/tRRD/tRTW/tWTR) requires the interpreted engine")
    d = cfg.dram_ns
    c = cfg.cu_ns
    t_bus = 1 * d
    t_ccd = cfg.tCCD * d
    t_cl = cfg.CL * d
    t_act = (cfg.tRP + cfg.tRCD) * d
    t_ras = cfg.tRAS * d
    t_wr = cfg.tWR * d
    t_c1 = cfg.c1_latency * c
    t_c2 = cfg.c2_latency * c
    t_c2_extra = cfg.atom_words * c
    t_buw = cfg.bu_word_latency * c
    t_param = cfg.param_load_cycles * d
    c1_bu = cfg.atom_words // 2
    c2_bu = cfg.atom_words

    # done-completion constants per class; C2 overrides add2 per command
    _ADD = {
        K_ACT: (t_act, 0.0), K_COL_READ: (t_cl, t_ccd),
        K_COL_WRITE: (t_ccd, 0.0), K_C1: (t_c1, 0.0), K_C2: (t_c2, 0.0),
        K_CMUL: (t_c2, 0.0), K_WORD_LOAD: (t_cl, 0.0),
        K_WORD_STORE: (t_ccd, 0.0), K_BU_WORD: (t_buw, 0.0),
    }

    kinds: list[int] = []
    pns: list[float] = []
    codes: list[int] = []
    add1s: list[float] = []
    add2s: list[float] = []
    preds: list[tuple[int, ...]] = []
    col_preds: list[int] = []
    act_preds: list[int] = []
    marks: list[tuple[int, str]] = []

    # last-writer trackers (command indices; -1 = initial state)
    last_col = -1           # col_t writer (start-valued)
    last_act = -1           # Act: row_usable_t (done) + act cadence (start)
    last_cu = -1            # cu_t writer (done-valued)
    dr: dict[int, int] = {}     # data_ready[buf] writer
    bf: dict[int, int] = {}     # buf_free[buf] writer
    rr = [-1, -1]               # reg_ready writer per register
    counts = [0] * len(_STAT_KEY)
    bu_ops = 0
    has_bu = False
    n_hit = n_miss = 0

    it = iter(param_trace) if param_trace is not None else None
    i = 0
    for cmd in commands:
        cls = cmd.__class__
        if cls is Mark:
            marks.append((i, cmd.name))
            continue
        k = _KIND[cls]
        pn = 0.0
        code = P_NONE
        if cls in PARAM_OPS:
            if it is None:
                pn = t_param
            else:
                try:
                    beats, code = next(it)
                except StopIteration:
                    raise ValueError(
                        "param_trace shorter than the stream's CU ops"
                    ) from None
                pn = beats * d
                if code == P_HIT:
                    n_hit += 1
                else:
                    n_miss += 1
        a1, a2 = _ADD[k]
        cp = last_col if _COL[k] else -1
        ap = -1
        if k == K_ACT:
            p: tuple[int, ...] = ()
            ap = last_act
            last_act = i
        elif k == K_COL_READ:
            p = (last_act, bf.get(cmd.buf, -1))
            last_col = i
            dr[cmd.buf] = i
        elif k == K_COL_WRITE:
            p = (last_act, dr.get(cmd.buf, -1))
            last_col = i
            bf[cmd.buf] = i
        elif k == K_C1:
            p = (last_cu, dr.get(cmd.buf, -1))
            last_cu = i
            dr[cmd.buf] = bf[cmd.buf] = i
            bu_ops += c1_bu * (cmd.stages_hi - cmd.stages_lo)
            has_bu = True
        elif k == K_C2:
            bufs = tuple(cmd.bufs_u) + tuple(cmd.bufs_v)
            p = (last_cu,) + tuple(dr.get(b, -1) for b in bufs)
            a2 = t_c2_extra * (len(cmd.bufs_u) - 1)
            last_cu = i
            for b in bufs:
                dr[b] = bf[b] = i
            bu_ops += c2_bu * len(cmd.bufs_u)
            has_bu = True
        elif k == K_CMUL:
            p = (last_cu, dr.get(cmd.buf_u, -1), dr.get(cmd.buf_v, -1))
            last_cu = i
            dr[cmd.buf_u] = bf[cmd.buf_u] = i
            bf[cmd.buf_v] = i
        elif k == K_WORD_LOAD:
            p = (last_act, rr[cmd.reg])
            last_col = i
            rr[cmd.reg] = i
        elif k == K_WORD_STORE:
            p = (last_act, rr[cmd.reg])
            last_col = i
        else:  # K_BU_WORD
            p = (last_cu, rr[0], rr[1])
            last_cu = i
            rr[0] = rr[1] = i
            bu_ops += 1
            has_bu = True
        kinds.append(k)
        pns.append(pn)
        codes.append(code)
        add1s.append(a1)
        add2s.append(a2)
        preds.append(p)
        col_preds.append(cp)
        act_preds.append(ap)
        counts[k] += 1
        i += 1
    if it is not None and next(it, None) is not None:
        raise ValueError("param_trace longer than the stream's CU ops")

    n = i
    width = max((len(p) for p in preds), default=1) or 1
    kind = np.asarray(kinds, dtype=np.int8)
    done_preds = np.full((n, width), n, dtype=np.int32)
    for r, p in enumerate(preds):
        for j, v in enumerate(p):
            done_preds[r, j] = v if v >= 0 else n
    col_pred = np.asarray(col_preds, dtype=np.int32)
    col_pred[col_pred < 0] = n          # S sentinel row holds -tCCD
    act_pred = np.asarray(act_preds, dtype=np.int32)
    act_pred[act_pred < 0] = n + 1      # S sentinel row holds -tRAS

    pn_arr = np.asarray(pns, dtype=np.float64)
    kt = kind if n else kind.reshape(0)
    take = lambda tbl: np.asarray(tbl, dtype=bool)[kt] if n else np.zeros(0, bool)
    return LoweredPlan(
        cfg=cfg,
        n_cmds=n,
        kind=kind,
        dram=take(_DRAM),
        pn=pn_arr,
        code=np.asarray(codes, dtype=np.int8),
        add1=np.asarray(add1s, dtype=np.float64),
        add2=np.asarray(add2s, dtype=np.float64),
        bus_inc=pn_arr + t_bus,
        done_preds=done_preds,
        col_pred=col_pred,
        act_pred=act_pred,
        act_mask=(kind == K_ACT) if n else np.zeros(0, bool),
        wr_mask=take(_WR),
        qui_mask=take(_QUI),
        class_counts=tuple(
            (key, cnt) for key, cnt in zip(_STAT_KEY, counts) if cnt),
        bu_ops=bu_ops,
        has_bu=has_bu,
        n_param_hit=n_hit,
        n_param_miss=n_miss,
        marks=tuple(marks),
        t_bus=t_bus,
        t_ccd=t_ccd,
        t_ras=t_ras,
        t_wr=t_wr,
        trefi=cfg.tREFI_ns,
        trfc=cfg.tRFC_ns,
    )


def lower_plan(cfg: PimConfig, plan) -> LoweredPlan:
    """Lower a `CompiledPlan` (NttOp/PolymulOp, or a homogeneous BatchOp
    of one) to dense arrays, reusing the plan's cached `param_trace`.

    A BatchOp plan lowers its replicated member stream once — the gang
    width comes in at evaluation time (`evaluate_gang(lowered, banks)`).
    """
    if plan.cfg != cfg:
        raise ValueError("lower_plan: cfg does not match plan.cfg")
    inner = plan.inner if plan.inner is not None else plan
    if inner.sharded_plan is not None or not inner.commands:
        raise ValueError("lower_plan: plan has no homogeneous command "
                         "stream (sharded plans run on the interpreted "
                         "engine)")
    return lower_commands(cfg, inner.commands, inner.param_trace)
