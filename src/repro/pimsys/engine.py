"""Hierarchical resource engine: THE command-issue path of the device.

Historically the repo issued commands through three parallel
re-implementations — `core.pimsim.BankTimer.simulate`, the arbitration
loop in `pimsys.controller.ChannelController`, and the sharded exchange
loop in `pimsys.sharded` — each owning its own bus bookkeeping.  This
module unifies them into ONE engine that composes explicit resource
layers, outermost to innermost:

    DeviceEngine            channels (independent command/address buses)
      ChannelEngine         one shared bus: arbitration (rr / ready),
                            per-CU-op (w0, r_w) parameter-beat charging,
                            device-side parameter-cache accounting
        RankState           tFAW / tRRD activation windows and same-rank
                            read<->write data-bus turnaround
          BankEngine        per-bank hazards only (column path, CU,
                            buffers, refresh) — `core.pimsim.BankEngine`
            CU              compute latencies inside the bank model

`BankTimer`, `ChannelController`/`Device`, and the sharded exchange are
thin drivers of this path, so a one-bank device is bit-identical to the
paper's single-bank simulator *by construction* — there is no second
timing model to drift.

Rank layer (`RankState`)
    DRAM rank-level constraints the seed model idealized away: at most
    four activations per rank inside any `tFAW` window, `tRRD` between
    consecutive same-rank ACTs, and `tRTW`/`tWTR` data-bus turnaround
    when consecutive column accesses in a rank switch direction.  All
    four default to 0 in `PimConfig` (= the seed's idealized model, the
    differential anchor); setting them nonzero enforces the windows.
    Banks partition into ranks by `DeviceTopology.banks_per_rank`; a
    standalone `ChannelEngine` without a topology models one rank.

Device-side twiddle-parameter cache (`PimConfig.param_cache_entries`)
    Every C1/C2/CMul streams its (w0, r_w) parameter program over the
    shared bus (`param_load_cycles` beats, §IV-A) — the traffic that
    sets the multibank bus knee.  The paper's §V answer to repeated
    parameter traffic is small per-application buffers; we model an
    LRU cache of `param_cache_entries` recently-used parameter programs
    at each bank's CU: a miss pays the full `param_load_cycles` beats,
    a hit pays a single re-select beat.  `param_beat_trace` precomputes
    a stream's hit/miss residency offline (the plan layer caches it, so
    `run()` stays zero-regeneration); the engine just replays per-op
    beat counts and tracks `param_hit`/`param_miss` per bank.  Entries
    = 0 (default) disables the cache and charges the seed model's flat
    `param_load_cycles` per CU op.  `CMul` carries pointwise-operand
    parameters with no reusable generator program and always pays the
    full load; the `BUWord` word path never charged parameter beats in
    the seed model and still does not.

The hot loop is deliberately low-level Python: `__slots__` everywhere,
per-command-class dispatch tables instead of isinstance chains, bound
locals in `advance`/`drain` — see `benchmarks/engine_speed.py`.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict, deque
from typing import Sequence

from repro.core.mapping import (
    Act,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    WordLoad,
    WordStore,
    cu_twiddle_indices,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import PARAM_OPS, BankEngine
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import DeviceTopology

POLICIES = ("rr", "ready")

_INF = math.inf
_EMPTY: tuple = ()

# queue-entry param codes (slot 4 of a queue tuple)
_P_NONE, _P_MISS, _P_HIT = 0, 1, 2

# rank-gate kinds, resolved once per command class
_RK_NONE, _RK_ACT, _RK_READ, _RK_WRITE = 0, 1, 2, 3
_RANK_KIND = {
    ColRead: _RK_READ,
    WordLoad: _RK_READ,
    ColWrite: _RK_WRITE,
    WordStore: _RK_WRITE,
}


# --------------------------------------------------------------------------
# Parameter-cache residency (computed offline, replayed by the engine)
# --------------------------------------------------------------------------


def param_program_key(cfg: PimConfig, n: int, cmd: Command):
    """Cache key of a CU op's (w0, r_w) parameter program, or None.

    Two ops share a program iff they resolve the same global twiddle
    table indices (`core.mapping.cu_twiddle_indices` — the same single
    identity `session.twiddle_param_stream` makes functional) with the
    same generator schedule (op kind + butterfly direction).  CMul has
    no reusable program and BUWord's word path never charged parameter
    beats, so only C1/C2 key into the cache.
    """
    cls = cmd.__class__
    if cls is C1 or cls is C2:
        return (cls.__name__, cmd.gs, cu_twiddle_indices(cfg, n, cmd))
    return None


def param_hit_beats(cfg: PimConfig) -> int:
    """Bus beats a parameter-cache HIT pays: one re-select beat, clamped
    so a hit never costs more than a miss on degenerate configs with
    `param_load_cycles < 1`.  The single definition of the hit cost —
    the offline trace builder and the sharded exchange both use it."""
    full = cfg.param_load_cycles
    return full if full < 1 else 1


def param_beat_trace(
    cfg: PimConfig, n: int, commands: Sequence[Command],
) -> tuple[tuple[int, int], ...] | None:
    """Per-CU-op (bus_beats, code) residency trace for one command stream.

    One entry per C1/C2/CMul in issue order, under an LRU cache of
    `cfg.param_cache_entries` parameter programs: a hit pays one
    re-select beat, a miss the full `param_load_cycles`.  Returns None
    when the cache is disabled (`param_cache_entries == 0`), which the
    engine reads as "charge the flat seed-model cost" — the two spellings
    are bit-identical (`tests/test_engine_props.py` proves it).
    """
    entries = cfg.param_cache_entries
    if entries <= 0:
        return None
    full = cfg.param_load_cycles
    hit_beats = param_hit_beats(cfg)
    lru: OrderedDict = OrderedDict()
    out: list[tuple[int, int]] = []
    for cmd in commands:
        if cmd.__class__ not in PARAM_OPS:
            continue
        key = param_program_key(cfg, n, cmd)
        if key is None:  # CMul: no reusable generator program
            out.append((full, _P_MISS))
        elif key in lru:
            lru.move_to_end(key)
            out.append((hit_beats, _P_HIT))
        else:
            lru[key] = True
            if len(lru) > entries:
                lru.popitem(last=False)
            out.append((full, _P_MISS))
    return tuple(out)


def trace_param_beats(cfg: PimConfig,
                      trace: Sequence[tuple[int, int]] | None,
                      cu_ops: int) -> int:
    """Total (w0, r_w) bus beats a stream pays for `cu_ops` CU ops —
    `sum` of the residency trace, or the flat seed cost without one."""
    if trace is None:
        return cfg.param_load_cycles * cu_ops
    return sum(b for b, _ in trace)


# --------------------------------------------------------------------------
# Rank layer
# --------------------------------------------------------------------------


class RankState:
    """tFAW/tRRD activation windows + read<->write turnaround for one rank.

    Activation windows are charge-pump limits and apply rank-wide: the
    state tracks the last four ACT start times (the tFAW window is
    defined over activation *issue* times) and gates the next ACT to
    `max(last + tRRD, fourth_last + tFAW)`.  Turnaround models the
    rank-shared column strobes re-terminating on a direction switch —
    but NTT-PIM column accesses terminate at the issuing bank's own
    atom buffers, so only transitions between *different banks* of the
    rank pay `tRTW`/`tWTR`; a lone bank keeps the paper-calibrated
    single-bank timing even with rank timing enabled (asserted in
    `tests/test_engine.py`).  Every gate collapses to 0.0 when its
    `PimConfig` field is 0, so a default-config rank is exactly the
    seed's unconstrained model.

    `act_log` (enabled via `record_acts`) keeps every ACT start so tests
    can assert the tFAW invariant on a recorded trace: any `tFAW`-wide
    slice contains at most four activations.
    """

    __slots__ = ("t_faw", "t_rrd", "t_rtw", "t_wtr", "acts",
                 "col_end", "col_write", "col_bank", "act_log")

    def __init__(self, cfg: PimConfig, record_acts: bool = False):
        d = cfg.dram_ns
        self.t_faw = cfg.tFAW * d
        self.t_rrd = cfg.tRRD * d
        self.t_rtw = cfg.tRTW * d
        self.t_wtr = cfg.tWTR * d
        self.acts: deque = deque(maxlen=4)  # last 4 ACT start times
        self.col_end = 0.0
        self.col_write = False
        self.col_bank = -1
        self.act_log: list[float] | None = [] if record_acts else None

    def gate(self, kind: int, bank: int) -> float:
        """Earliest start the rank allows `bank` a command of `kind`."""
        if kind == _RK_ACT:
            acts = self.acts
            if not acts:
                return 0.0
            g = 0.0
            if self.t_rrd:
                g = acts[-1] + self.t_rrd
            if self.t_faw and len(acts) == 4:
                faw = acts[0] + self.t_faw
                if faw > g:
                    g = faw
            return g
        if self.col_bank == bank or self.col_bank < 0:
            return 0.0  # same-bank switches stay inside the atom buffers
        if kind == _RK_READ:
            return self.col_end + self.t_wtr if (self.col_write and self.t_wtr) else 0.0
        if kind == _RK_WRITE:
            return self.col_end + self.t_rtw if (not self.col_write and self.t_rtw) else 0.0
        return 0.0

    def commit(self, kind: int, bank: int, s: float, done: float) -> None:
        if kind == _RK_ACT:
            self.acts.append(s)
            if self.act_log is not None:
                self.act_log.append(s)
        else:
            if done > self.col_end:
                self.col_end = done
            self.col_write = kind == _RK_WRITE
            self.col_bank = bank


# --------------------------------------------------------------------------
# Channel layer
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Completion:
    """A job's last command finished on `channel`/`bank` at `done` ns."""

    job_id: object
    channel: int
    bank: int
    done: float


class _Job:
    __slots__ = ("remaining", "max_done")

    def __init__(self):
        self.remaining = 0
        self.max_done = 0.0


class ChannelEngine:
    """One command/address bus shared by bank ports, cycle-level.

    Each `advance` grants the bus to one bank and issues that bank's
    head command through rank gating (`RankState`) into the bank's own
    `BankEngine` — the exact hazard model of the paper's single-bank
    simulator.  With one bank the grant sequence degenerates to program
    order and the timing is bit-identical to `BankTimer`.

    Arbitration policies:
      rr      round-robin over banks whose head command is ready at the
              earliest grant time (fair, FCFS-like)
      ready   ready-first (FR-FCFS flavor): grant the bank whose head
              command would *start* soonest given its internal hazards,
              so a bank stalled on tRAS/CU latency does not block a
              ready neighbor

    Causality note: commands become visible to the arbiter at their
    `gate` time (job dispatch time), so open-loop traffic injected by
    the scheduler contends only with commands that coexist with it.

    Queue entries are `(cmd, gate, job_id, param_ns, code)`: the
    (w0, r_w) parameter-beat charge and its hit/miss code are resolved
    at `enqueue` time from a `param_beat_trace`, so the hot loop never
    re-derives cache state.
    """

    __slots__ = ("cfg", "channel_id", "policy", "bus_free", "bus_busy_ns",
                 "engines", "queues", "ranks", "_rank_of", "_jobs", "_rr",
                 "issued", "_banks_per_rank", "_rank_on", "_record_acts",
                 "_t_bus", "_t_param", "_dram_ns", "tracer")

    def __init__(self, cfg: PimConfig, channel_id: int = 0, policy: str = "rr",
                 banks_per_rank: int | None = None, record_acts: bool = False,
                 tracer=None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        self.cfg = cfg
        self.channel_id = channel_id
        self.policy = policy
        # telemetry sink (telemetry.Tracer) or None; the issue paths pay
        # exactly one `is not None` test per command when disabled
        self.tracer = tracer
        self.bus_free = 0.0
        self.bus_busy_ns = 0.0
        self.engines: list[BankEngine] = []
        self.queues: list[deque] = []
        self.ranks: list[RankState] = []
        self._rank_of: list[int] = []
        self._jobs: dict[object, _Job] = {}
        self._rr = 0  # last granted bank (round-robin pointer)
        self.issued = 0
        self._banks_per_rank = banks_per_rank
        # record_acts routes commands through the (inert, all-zero-gate)
        # rank path so the ACT log fills even without rank timing
        self._rank_on = bool(cfg.tFAW or cfg.tRRD or cfg.tRTW or cfg.tWTR
                             or record_acts)
        self._record_acts = record_acts
        d = cfg.dram_ns
        self._t_bus = 1.0 * d
        self._t_param = cfg.param_load_cycles * d
        self._dram_ns = d

    # -- construction --------------------------------------------------------
    def add_bank(self, pipelined: bool = True, rank: int | None = None) -> int:
        """Attach one bank port; `rank` defaults to the topology-derived
        partition (`banks_per_rank` banks per rank, one rank for a
        standalone channel)."""
        idx = len(self.engines)
        if rank is None:
            rank = idx // self._banks_per_rank if self._banks_per_rank else 0
        while rank >= len(self.ranks):
            self.ranks.append(RankState(self.cfg, record_acts=self._record_acts))
        self.engines.append(BankEngine(self.cfg, pipelined=pipelined))
        self.queues.append(deque())
        self._rank_of.append(rank)
        return idx

    def enqueue(self, bank: int, commands, gate: float = 0.0, job_id=None,
                param_trace: Sequence[tuple[int, int]] | None = None) -> None:
        """Queue a command stream on `bank`, visible to the arbiter at
        `gate` (dispatch time).  `Mark`s are phase annotations with no
        hardware effect and are dropped here, exactly as `BankTimer`
        ignores them.  `param_trace` (from `param_beat_trace`) supplies
        each CU op's parameter-beat charge; without one, every CU op
        pays the flat `param_load_cycles` (the cache-disabled model)."""
        q = self.queues[bank]
        job = None
        if job_id is not None:
            job = self._jobs.get(job_id)
            if job is None:
                job = self._jobs[job_id] = _Job()
        t_param, d = self._t_param, self._dram_ns
        it = iter(param_trace) if param_trace is not None else None
        n = 0
        for cmd in commands:
            cls = cmd.__class__
            if cls is Mark:
                continue
            if cls in PARAM_OPS:
                if it is None:
                    entry = (cmd, gate, job_id, t_param, _P_NONE)
                else:
                    try:
                        beats, code = next(it)
                    except StopIteration:
                        raise ValueError(
                            "param_trace shorter than the stream's CU ops"
                        ) from None
                    entry = (cmd, gate, job_id, beats * d, code)
            else:
                entry = (cmd, gate, job_id, 0.0, _P_NONE)
            q.append(entry)
            n += 1
        if it is not None and next(it, _EMPTY) is not _EMPTY:
            raise ValueError("param_trace longer than the stream's CU ops")
        if job is not None:
            job.remaining += n

    # -- non-queued bus transactions -----------------------------------------
    def occupy_bus(self, not_before: float, hold_ns: float) -> float:
        """Grant the shared bus for a non-command transaction (an
        inter-bank atom burst — see `repro.pimsys.sharded`).  Returns
        the grant time; the bus is busy for `hold_ns` from there."""
        s = max(not_before, self.bus_free)
        self.bus_free = s + hold_ns
        self.bus_busy_ns += hold_ns
        return s

    def earliest_issue(self, bank: int, cmd: Command,
                       not_before: float = 0.0,
                       param_ns: float | None = None) -> float:
        """Non-mutating: the start time `issue_direct` would produce for
        `cmd` right now (bus grant, rank gates, and the bank's internal
        hazards included).  The sharded exchange's pipelined driver
        ranks competing pair chains by this estimate so a command
        stalled on a data hazard never parks the channel bus ahead of
        work that could start sooner."""
        eng = self.engines[bank]
        if param_ns is None:
            param_ns = self._t_param if cmd.__class__ in PARAM_OPS else 0.0
        lb = not_before if not_before > self.bus_free else self.bus_free
        if self._rank_on:
            cls = cmd.__class__
            kind = _RK_ACT if cls is Act else _RANK_KIND.get(cls, _RK_NONE)
            if kind != _RK_NONE:
                g = self.ranks[self._rank_of[bank]].gate(kind, bank)
                if g > lb:
                    lb = g
        return eng.earliest_start(cmd, lb, param_ns)

    def issue_direct(self, bank: int, cmd: Command, not_before: float = 0.0,
                     param_ns: float | None = None,
                     code: int = _P_NONE) -> tuple[float, float]:
        """Issue one command on `bank` outside the queued arbitration
        path (the sharded exchange drives engines directly), with
        exactly the bus-grant, rank-gate, and parameter-beat bookkeeping
        `advance` applies.  Returns (start, done)."""
        eng = self.engines[bank]
        if param_ns is None:
            param_ns = self._t_param if cmd.__class__ in PARAM_OPS else 0.0
        lb = grant = not_before if not_before > self.bus_free else self.bus_free
        rank = None
        kind = _RK_NONE
        if self._rank_on:
            rank = self.ranks[self._rank_of[bank]]
            cls = cmd.__class__
            kind = _RK_ACT if cls is Act else _RANK_KIND.get(cls, _RK_NONE)
            if kind != _RK_NONE:
                g = rank.gate(kind, bank)
                if g > lb:
                    lb = g
        s, done = eng.issue(cmd, lb, param_ns)
        if rank is not None and kind != _RK_NONE:
            rank.commit(kind, bank, s, done)
        if code:
            eng.stats["param_hit" if code == _P_HIT else "param_miss"] += 1
        self.bus_free = s + self._t_bus
        self.bus_busy_ns += param_ns + self._t_bus
        self.issued += 1
        tr = self.tracer
        if tr is not None:
            tr.commands.append((self.channel_id, bank, cmd.__class__.__name__,
                                not_before, grant, s, done, param_ns, code))
        return s, done

    # -- arbitration ---------------------------------------------------------
    def next_grant(self) -> float:
        """Earliest time any queued command could be granted the bus."""
        g = _INF
        bus = self.bus_free
        for q in self.queues:
            if q:
                t = q[0][1]
                if t < g:
                    g = t
        if g is _INF:
            return _INF
        return g if g > bus else bus

    def _rank_gate(self, bank: int, cmd: Command) -> float:
        rank = self.ranks[self._rank_of[bank]]
        cls = cmd.__class__
        if cls is Act:
            return rank.gate(_RK_ACT, bank)
        return rank.gate(_RANK_KIND.get(cls, _RK_NONE), bank)

    def _pick(self) -> int | None:
        queues = self.queues
        n = len(queues)
        rr = self._rr
        if self.policy == "rr":
            # Fair rotation over banks grantable at the earliest grant
            # time.  Fast path: the first non-empty bank (cyclically
            # after the last grant) whose head gate <= bus_free is
            # grantable at bus_free, the minimum possible grant — O(1)
            # amortized.
            bus = self.bus_free
            best, best_gate = None, _INF
            for off in range(1, n + 1):
                q = queues[(rr + off) % n]
                if not q:
                    continue
                gate = q[0][1]
                if gate <= bus:
                    return (rr + off) % n
                if gate < best_gate:
                    best, best_gate = (rr + off) % n, gate
            return best  # None iff every queue is empty
        # ready-first: grant whichever grantable head would START soonest
        rank_on = self._rank_on
        best, best_s = None, _INF
        for off in range(1, n + 1):
            b = (rr + off) % n
            q = queues[b]
            if not q:
                continue
            head = q[0]
            g = head[1]
            if g < self.bus_free:
                g = self.bus_free
            if rank_on:
                rg = self._rank_gate(b, head[0])
                if rg > g:
                    g = rg
            s = self.engines[b].earliest_start(head[0], g, head[3])
            if s < best_s:
                best, best_s = b, s
        return best

    # -- simulation ----------------------------------------------------------
    def advance(self, horizon: float = _INF) -> Sequence[Completion] | None:
        """Grant the bus once and issue one command.

        Returns completions triggered by that issue (an empty sequence
        if none), or `None` if no queued command can be granted before
        `horizon` (the scheduler then injects the next arrival).
        """
        bank = self._pick()
        if bank is None:
            return None
        # Causality: the guard is on the CHOSEN bank's grant, not the
        # global minimum — the ready policy may pick a later-gated bank
        # than the earliest one, and issuing at/after `horizon` would
        # advance the bus past an arrival the scheduler has not injected
        # yet.  Rank gates and bank hazards may still push the START
        # past the horizon (they are dependencies, not bus grants).
        head = self.queues[bank][0]
        grant = head[1]
        if grant < self.bus_free:
            grant = self.bus_free
        if grant >= horizon:
            return None
        cmd, gate, job_id, param_ns, code = self.queues[bank].popleft()
        eng = self.engines[bank]
        lb = grant
        rank = None
        kind = _RK_NONE
        if self._rank_on:
            rank = self.ranks[self._rank_of[bank]]
            cls = cmd.__class__
            kind = _RK_ACT if cls is Act else _RANK_KIND.get(cls, _RK_NONE)
            if kind != _RK_NONE:
                g = rank.gate(kind, bank)
                if g > lb:
                    lb = g
        s, done = eng.issue(cmd, lb, param_ns)
        if kind != _RK_NONE:
            rank.commit(kind, bank, s, done)
        if code:
            eng.stats["param_hit" if code == _P_HIT else "param_miss"] += 1
        self.bus_free = s + self._t_bus
        self.bus_busy_ns += param_ns + self._t_bus
        self._rr = bank
        self.issued += 1
        tr = self.tracer
        if tr is not None:
            tr.commands.append((self.channel_id, bank, cmd.__class__.__name__,
                                gate, grant, s, done, param_ns, code))

        if job_id is None:
            return _EMPTY
        job = self._jobs[job_id]
        if done > job.max_done:
            job.max_done = done
        job.remaining -= 1
        if job.remaining:
            return _EMPTY
        del self._jobs[job_id]
        return (Completion(job_id, self.channel_id, bank, job.max_done),)

    def drain(self) -> list[Completion]:
        """Run until every queue is empty; return all completions."""
        out: list[Completion] = []
        advance = self.advance
        while True:
            evs = advance()
            if evs is None:
                return out
            if evs:
                out.extend(evs)

    # -- results -------------------------------------------------------------
    @property
    def makespan_ns(self) -> float:
        return max((e.end_t for e in self.engines), default=0.0)

    def bank_ns(self, bank: int) -> float:
        return self.engines[bank].end_t

    def act_starts(self, rank: int = 0) -> list[float]:
        """Recorded ACT start times of `rank` (requires `record_acts`)."""
        log = self.ranks[rank].act_log
        if log is None:
            raise RuntimeError("construct the engine with record_acts=True")
        return list(log)

    def record_stats(self, reg: StatsRegistry) -> None:
        for b, eng in enumerate(self.engines):
            reg.add_bank(self.channel_id, b, dict(eng.stats))
        reg.add_bus(self.channel_id, self.bus_busy_ns, self.makespan_ns)


def replay_gang(cfg: PimConfig, commands, banks: int, *,
                param_trace=None, policy: str = "rr",
                pipelined: bool = True, tracer=None) -> ChannelEngine:
    """Interpreted evaluation of one homogeneous gang: `banks` copies of
    one command stream enqueued at t=0 on one shared-bus channel and
    drained to completion.  This is the differential oracle the fastpath
    (`repro.pimsys.fastpath`) verifies against — the returned engine
    carries per-bank `stats`/`end_t`, `bus_busy_ns` and `makespan_ns`
    (plus the full per-command schedule when a `tracer` is passed)."""
    eng = ChannelEngine(cfg, policy=policy, tracer=tracer)
    for i in range(banks):
        bank = eng.add_bank(pipelined=pipelined)
        eng.enqueue(bank, commands, job_id=i, param_trace=param_trace)
    eng.drain()
    return eng


# --------------------------------------------------------------------------
# Device layer
# --------------------------------------------------------------------------


class DeviceEngine:
    """A full PIM device: one `ChannelEngine` per channel.

    Channels have independent buses, so they only interact through the
    scheduler's placement decisions (and the sharded exchange's
    cross-channel bursts); `advance` always steps the channel with the
    earliest grantable command to keep event order causal.
    """

    __slots__ = ("cfg", "topo", "channels", "tracer")

    def __init__(self, cfg: PimConfig, topo: DeviceTopology | None = None,
                 policy: str = "rr", pipelined: bool = True,
                 record_acts: bool = False, tracer=None):
        self.cfg = cfg
        self.topo = topo or DeviceTopology.from_config(cfg)
        self.tracer = tracer
        if tracer is not None:
            tracer.meta.setdefault("dram_ns", cfg.dram_ns)
        self.channels = [
            ChannelEngine(cfg, channel_id=ch, policy=policy,
                          banks_per_rank=self.topo.banks_per_rank,
                          record_acts=record_acts, tracer=tracer)
            for ch in range(self.topo.channels)
        ]
        for ctrl in self.channels:
            for _ in range(self.topo.banks_per_channel):
                ctrl.add_bank(pipelined=pipelined)

    def enqueue_flat(self, flat_bank: int, commands, gate: float = 0.0,
                     job_id=None, param_trace=None):
        addr = self.topo.address_of(flat_bank)
        self.channels[addr.channel].enqueue(
            self.topo.local_id(addr), commands, gate=gate, job_id=job_id,
            param_trace=param_trace)

    def burst(self, ch_src: int, ch_dst: int, earliest: float) -> float:
        """One inter-bank atom burst over the shared bus(es).

        Same channel: one bus holds for `xfer_beats_per_atom` beats.
        Cross-channel: both buses are held for the burst and the arrival
        additionally pays `channel_hop_cycles`.  Returns the arrival
        time at the destination buffer."""
        cfg = self.cfg
        hold = cfg.xfer_beats_per_atom * cfg.dram_ns
        cs = self.channels[ch_src]
        tr = self.tracer
        if ch_src == ch_dst:
            s = cs.occupy_bus(earliest, hold)
            if tr is not None:
                tr.bursts.append((ch_src, ch_dst, s, s + hold))
            return s + hold
        cd = self.channels[ch_dst]
        s = max(earliest, cs.bus_free, cd.bus_free)
        cs.occupy_bus(s, hold)
        cd.occupy_bus(s, hold)
        end = s + hold + cfg.channel_hop_cycles * cfg.dram_ns
        if tr is not None:
            tr.bursts.append((ch_src, ch_dst, s, end))
        return end

    def advance(self, horizon: float = _INF) -> Sequence[Completion] | None:
        best, best_g = None, _INF
        for ctrl in self.channels:
            g = ctrl.next_grant()
            if g < best_g:
                best, best_g = ctrl, g
        if best is None or best_g >= horizon:
            return None
        return best.advance(horizon)

    def drain(self) -> list[Completion]:
        out: list[Completion] = []
        for ctrl in self.channels:
            out.extend(ctrl.drain())
        return out

    @property
    def makespan_ns(self) -> float:
        return max(c.makespan_ns for c in self.channels)

    def stats(self) -> StatsRegistry:
        reg = StatsRegistry(channels=len(self.channels))
        for ctrl in self.channels:
            ctrl.record_stats(reg)
        return reg
