"""Request queue + batch scheduler for the PIM device.

Accepts `NttJob` / `PolymulJob` requests, places each on a free bank
(earliest-free bank first, which channel-interleaves via the topology's
flat-id order), and injects them either

  closed-loop  a fixed batch all present at t=0 (the paper's §VI-A
               "multiple NTT functions using multiple banks" setting), or
  open-loop    Poisson arrivals at a given rate (the serving regime the
               ROADMAP's north star asks about),

then reports per-request latency percentiles and device throughput.
A bank serves one job at a time; jobs that find no free bank wait in a
FIFO request queue.  Placement is greedy over known bank-release times:
before dispatching, the controller is advanced up to the k-th best
known release (the horizon past which further progress cannot improve
this dispatch), so a bank completing sooner than a parked reservation
is always preferred — but dispatch never peeks past that horizon at
completions that could not matter.

`ShardedNttJob` coexists in the same FIFO: it gang-reserves `banks`
banks (waiting at the head until that many are free) and runs the
four-step sharded plan of `repro.pimsys.sharded` on them; see its
docstring for the reservation approximation.  Gang specs are validated
(shard size, bank count, topology fit) before any simulation starts.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Iterable, Sequence

import numpy as np

from repro.core.mapping import Command, RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.polymul import polymul_commands
from repro.pimsys.controller import Device
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import DeviceTopology


# --------------------------------------------------------------------------
# Requests
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NttJob:
    """One size-n NTT (inverse by default, the paper's orientation)."""

    n: int
    forward: bool = False


@dataclasses.dataclass(frozen=True)
class PolymulJob:
    """One RLWE polynomial product: NTT(a), NTT(b), ⊙, INTT, scale."""

    n: int


@dataclasses.dataclass(frozen=True)
class ShardedNttJob:
    """ONE size-n NTT gang-scheduled over `banks` banks at once.

    Dispatched when `banks` banks are free (FIFO order is preserved, so
    a gang job at the head waits — classic head-of-line gang blocking —
    while single-bank jobs behind it keep their arrival order).  The
    reserved gang runs the four-step sharded plan of
    `repro.pimsys.sharded` on the banks it was placed on; during the
    reservation the gang's channels are modeled as dedicated to it (a
    sharded job's bus traffic does not interleave with concurrent
    single-bank jobs' — the reservation approximation, noted here
    because it slightly favors the gang under mixed load).
    """

    n: int
    banks: int = 2
    forward: bool = False


Job = NttJob | PolymulJob | ShardedNttJob


def job_commands(cfg: PimConfig, job: Job) -> list[Command]:
    if isinstance(job, NttJob):
        return RowCentricMapper(cfg, job.n, forward=job.forward).commands()
    if isinstance(job, PolymulJob):
        return polymul_commands(cfg, job.n)[0]
    if isinstance(job, ShardedNttJob):
        raise TypeError(
            "ShardedNttJob spans banks and has no single-bank command "
            "stream; use ShardedNttPlan(...).local_streams() instead")
    raise TypeError(job)


def job_rows(cfg: PimConfig, job: Job) -> int:
    """Rows of bank storage the job's working set occupies (per bank)."""
    if isinstance(job, ShardedNttJob):
        return max(1, (job.n // job.banks) // cfg.row_words)
    rows = max(1, job.n // cfg.row_words)
    return rows if isinstance(job, NttJob) else 2 * rows  # polymul holds a AND b


# --------------------------------------------------------------------------
# Results
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SchedulerResult:
    submitted: int
    completed: int
    makespan_ns: float
    arrivals_ns: np.ndarray
    dispatch_ns: np.ndarray
    done_ns: np.ndarray
    stats: StatsRegistry

    @property
    def latency_ns(self) -> np.ndarray:
        return self.done_ns - self.arrivals_ns

    @property
    def queue_delay_ns(self) -> np.ndarray:
        return self.dispatch_ns - self.arrivals_ns

    def latency_percentiles_us(self, qs: Sequence[float] = (50, 95, 99)) -> dict:
        if self.completed == 0:
            return {f"p{int(q)}": 0.0 for q in qs}
        lat = self.latency_ns / 1e3
        return {f"p{int(q)}": float(np.percentile(lat, q)) for q in qs}

    @property
    def throughput_jobs_per_ms(self) -> float:
        if self.makespan_ns <= 0:
            return 0.0
        return self.completed / (self.makespan_ns / 1e6)

    def summary(self) -> dict:
        out = {
            "submitted": self.submitted,
            "completed": self.completed,
            "makespan_us": self.makespan_ns / 1e3,
            "throughput_jobs_per_ms": self.throughput_jobs_per_ms,
            "mean_queue_delay_us": (
                float(self.queue_delay_ns.mean() / 1e3) if self.completed else 0.0),
        }
        out.update(self.latency_percentiles_us())
        return out


# --------------------------------------------------------------------------
# Scheduler
# --------------------------------------------------------------------------


class RequestScheduler:
    def __init__(self, cfg: PimConfig, topo: DeviceTopology | None = None,
                 policy: str = "rr", pipelined: bool = True):
        self.cfg = cfg
        self.topo = topo or DeviceTopology.from_config(cfg)
        self.policy = policy
        self.pipelined = pipelined
        # job -> (commands, param-cache residency trace or None)
        self._cmd_cache: dict[Job, tuple[list[Command], tuple | None]] = {}
        # sharded-plan timing cache: only the shard count, orientation and
        # the gang's per-shard channel placement affect the latency.
        # Values are (latency_ns, per-shard counters, per-channel bus
        # busy ns, device counters) — see _sharded_latency.
        self._sharded_cache: dict[tuple, tuple[float, list, dict, dict]] = {}

    # -- injection frontends -------------------------------------------------
    def run_closed_loop(self, jobs: Iterable[Job]) -> SchedulerResult:
        """Fixed batch: all requests present at t=0."""
        jobs = list(jobs)
        return self._run([(0.0, j) for j in jobs])

    def run_open_loop(self, jobs: Iterable[Job], rate_per_us: float,
                      seed: int = 0) -> SchedulerResult:
        """Poisson arrivals at `rate_per_us` requests/us (open loop)."""
        jobs = list(jobs)
        if rate_per_us <= 0:
            raise ValueError("rate_per_us must be positive")
        rng = np.random.default_rng(seed)
        gaps_ns = rng.exponential(1e3 / rate_per_us, size=len(jobs))
        arrivals = np.cumsum(gaps_ns)
        return self._run(list(zip(arrivals.tolist(), jobs)))

    # -- plan priming (repro.pimsys.session) ---------------------------------
    def prime(self, job: Job, commands: Sequence[Command],
              param_trace=None) -> None:
        """Pre-populate the per-job command cache from a compiled plan.

        `PimSession.submit` routes `CompiledPlan`s here so queued traffic
        replays the plan's frozen stream (and its precomputed
        parameter-cache residency trace) instead of re-running the
        mapper per distinct job spec.  The stream must be the job's
        canonical one (`job_commands` equivalent) — the scheduler trusts
        the session's compiler for that.
        """
        if isinstance(job, ShardedNttJob):
            raise TypeError("gang jobs have no single-bank stream to prime; "
                            "the sharded plan cache handles them")
        if job_rows(self.cfg, job) > self.cfg.rows_per_bank:
            raise ValueError(f"{job} does not fit in one bank")
        if param_trace is None and self.cfg.param_cache_entries:
            from repro.pimsys.engine import param_beat_trace

            param_trace = param_beat_trace(self.cfg, job.n, commands)
        self._cmd_cache[job] = (list(commands), param_trace)

    # -- core event loop -----------------------------------------------------
    def _commands(self, job: Job) -> tuple[list[Command], tuple | None]:
        hit = self._cmd_cache.get(job)
        if hit is None:
            if job_rows(self.cfg, job) > self.cfg.rows_per_bank:
                raise ValueError(f"{job} does not fit in one bank")
            cmds = job_commands(self.cfg, job)
            trace = None
            if self.cfg.param_cache_entries:
                from repro.pimsys.engine import param_beat_trace

                trace = param_beat_trace(self.cfg, job.n, cmds)
            hit = self._cmd_cache[job] = (cmds, trace)
        return hit

    def _sharded_latency(self, job: ShardedNttJob, flats: Sequence[int]):
        """Latency + stats of a gang job on the banks it was placed on.

        Simulated on an idle clone of the device (the gang reservation —
        see `ShardedNttJob`); cached by the placement's channel pattern,
        which is all the plan's timing depends on.  Counters are cached
        PER SHARD (not as a registry keyed to the first placement's
        banks) so a later gang with the same channel pattern but
        different banks attributes its work to the banks it actually
        ran on.  Returns (latency_ns, per_shard_counters, per_channel
        bus busy, device counters).
        """
        from repro.pimsys.sharded import ShardedNttPlan

        key = (job.n, job.banks, job.forward,
               tuple(self.topo.channel_of(f) for f in flats))
        hit = self._sharded_cache.get(key)
        if hit is None:
            plan = ShardedNttPlan(self.cfg, job.n, job.banks,
                                  forward=job.forward, topo=self.topo,
                                  flat_banks=flats)
            r = plan.simulate(policy=self.policy, baseline=False,
                              pipelined=self.pipelined)
            shard_counters = []
            for f in flats:
                addr = self.topo.address_of(f)
                shard_counters.append(
                    r.stats.bank_counts(addr.channel, self.topo.local_id(addr)))
            bus_busy = {ch: r.stats.bus_busy_ns(ch) for ch in r.stats.channels()}
            dev = {"xfer_atoms": r.xfer_atoms, "xfer_hops": r.xfer_hops}
            hit = self._sharded_cache[key] = (
                r.latency_ns, shard_counters, bus_busy, dev)
        return hit

    def _validate_gang(self, job: ShardedNttJob) -> None:
        """Fail fast on an unsatisfiable gang spec — the plan constructor
        holds the single copy of the rules (power-of-two banks and n,
        shard >= one atom, row fit, topology fit, buffer count)."""
        from repro.pimsys.sharded import ShardedNttPlan

        ShardedNttPlan(self.cfg, job.n, job.banks, forward=job.forward,
                       topo=self.topo)

    def _run(self, arrivals: list[tuple[float, Job]]) -> SchedulerResult:
        for job in {j for _, j in arrivals if isinstance(j, ShardedNttJob)}:
            self._validate_gang(job)
        device = Device(self.cfg, self.topo, policy=self.policy,
                        pipelined=self.pipelined)
        topo = self.topo
        pending = deque(sorted(arrivals, key=lambda p: p[0]))
        free: list[tuple[float, int]] = [(0.0, b) for b in range(topo.total_banks)]
        heapq.heapify(free)

        n = len(arrivals)
        t_arr = np.zeros(n)
        t_disp = np.zeros(n)
        t_done = np.zeros(n)
        done_count = 0
        jid = 0
        gang_makespan = 0.0
        # (flats, per-shard counters, per-channel bus busy, device counters)
        gang_stats: list[tuple] = []

        def record(ev):
            nonlocal done_count
            t_done[ev.job_id] = ev.done
            done_count += 1
            flat = topo.flat_from_local(ev.channel, ev.bank)
            heapq.heappush(free, (ev.done, flat))

        def need(job: Job) -> int:
            return job.banks if isinstance(job, ShardedNttJob) else 1

        while pending:
            t, job = pending[0]
            k = need(job)
            # surface every completion the device reaches before this arrival
            while True:
                evs = device.advance(horizon=t)
                if evs is None:
                    break
                for ev in evs:
                    record(ev)
            # Advance past any in-flight completion that beats the release
            # times currently known in `free`: gang reservations park their
            # banks in the heap with FUTURE timestamps, and a busy bank may
            # complete sooner than those — the k-th best known release is
            # exactly the horizon beyond which more device progress can't
            # improve this dispatch.  The horizon is only recomputed when a
            # completion changes `free` (advance issues ONE command per call
            # and usually completes nothing), and the common k=1 case reads
            # the heap minimum instead of scanning.
            horizon_stale = True
            while True:
                if horizon_stale:
                    if len(free) >= k:
                        horizon = free[0][0] if k == 1 else \
                            heapq.nsmallest(k, free)[-1][0]
                    else:
                        horizon = math.inf
                    horizon_stale = False
                if len(free) >= k and horizon <= t:
                    break
                evs = device.advance(horizon=horizon)
                if evs is None:
                    if len(free) < k:  # pragma: no cover - deficit implies work queued
                        raise RuntimeError("scheduler stalled with jobs in flight")
                    break
                for ev in evs:
                    record(ev)
                    horizon_stale = True
            pending.popleft()
            picked = [heapq.heappop(free) for _ in range(k)]
            gate = max(t, max(ft for ft, _ in picked))
            t_arr[jid], t_disp[jid] = t, gate
            if isinstance(job, ShardedNttJob):
                # gang reservation: the plan runs on its own sub-device
                # timeline; the banks rejoin the pool at completion
                flats = [f for _, f in picked]
                dur, shard_counters, bus_busy, dev_c = self._sharded_latency(job, flats)
                done = gate + dur
                t_done[jid] = done
                done_count += 1
                gang_makespan = max(gang_makespan, done)
                gang_stats.append((flats, shard_counters, bus_busy, dev_c))
                for f in flats:
                    heapq.heappush(free, (done, f))
            else:
                cmds, trace = self._commands(job)
                device.enqueue_flat(picked[0][1], cmds, gate=gate,
                                    job_id=jid, param_trace=trace)
            jid += 1

        for ev in device.drain():
            record(ev)

        if done_count != n:  # not an assert: must survive python -O
            raise RuntimeError(f"conservation violated: {done_count} != {n}")
        stats = device.stats()
        for flats, shard_counters, bus_busy, dev_c in gang_stats:
            for f, counters in zip(flats, shard_counters):
                addr = topo.address_of(f)
                stats.add_bank(addr.channel, topo.local_id(addr), counters)
            for ch, busy in bus_busy.items():
                stats.add_bus(ch, busy, 0.0)
            stats.add_device(dev_c)
        makespan = max(device.makespan_ns, gang_makespan)
        # gang sub-device spans are gang-relative; the utilization
        # denominator must be the whole run
        stats.extend_span(makespan)
        return SchedulerResult(
            submitted=n,
            completed=done_count,
            makespan_ns=makespan,
            arrivals_ns=t_arr,
            dispatch_ns=t_disp,
            done_ns=t_done,
            stats=stats,
        )
