"""Request queue + batch scheduler for the PIM device.

Accepts `NttJob` / `PolymulJob` requests, places each on a free bank
(earliest-free bank first, which channel-interleaves via the topology's
flat-id order), and injects them either

  closed-loop  a fixed batch all present at t=0 (the paper's §VI-A
               "multiple NTT functions using multiple banks" setting), or
  open-loop    Poisson arrivals at a given rate (the serving regime the
               ROADMAP's north star asks about),

then reports per-request latency percentiles and device throughput.
A bank serves one job at a time; jobs that find no free bank wait in a
FIFO request queue.  Placement is greedy over known bank-release times:
before dispatching, the controller is advanced up to the k-th best
known release (the horizon past which further progress cannot improve
this dispatch), so a bank completing sooner than a parked reservation
is always preferred — but dispatch never peeks past that horizon at
completions that could not matter.

`ShardedNttJob` coexists in the same FIFO: it gang-reserves `banks`
banks (waiting at the head until that many are free) and runs the
four-step sharded plan of `repro.pimsys.sharded` on them; see its
docstring for the reservation approximation.  Gang specs are validated
(shard size, bank count, topology fit) before any simulation starts.

Service dispatch (`run_service`, the `repro.pimsys.service` substrate)
--------------------------------------------------------------------
The FIFO loop above is the legacy reference.  `run_service` is the
policy-driven dispatcher underneath `DeviceService`: it takes explicit
`ServiceRequest`s (arrival, job, QoS class, optional deadline) and a
`ServicePolicy`, and adds

  * QoS classes with weighted priority aging — a request's priority is
    `weight(class) * (now - arrival)`, so a `latency`-class request
    overtakes queued `throughput` work but an aging throughput request
    eventually wins (no starvation).  With equal weights the order
    degenerates to arrival order: `ServicePolicy()` (the default) is
    bit-identical to the FIFO loop on the same arrival trace
    (`tests/test_service.py` asserts arrays and stats exactly).
  * admission control — a bound on queued-but-undispatched requests
    (`max_queue_depth`) plus a token-bucket rate limiter
    (`bucket_rate_per_us` / `bucket_burst`).  Rejected requests never
    touch the device; they are reported per class and reason in
    `SchedulerResult.rejected_by` and in `StatsRegistry.service_counts`.
  * dynamic batching — `throughput`-class single-bank requests with the
    SAME job spec that are waiting together (or arrive within
    `batch_window_us` of the issue) coalesce, up to `max_batch`, into
    one gang issue on one bank: every member's frozen command stream is
    enqueued back-to-back at one shared gate, so the pipelined bank
    engine overlaps the seams and — with the device-side parameter
    cache on — members after the first replay a WARM residency trace
    (`_batch_traces`).  Zero mapper regeneration either way; the bank
    rejoins the free pool when its last member completes.
    `latency`-class requests are never batched and never delayed.
  * deadline/SLO accounting — per-request deadlines resolve to
    attainment and per-class latency percentiles on `SchedulerResult`.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Iterable, Sequence

import numpy as np

from repro.core.mapping import Command, RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.polymul import polymul_commands
from repro.pimsys.controller import Device
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.telemetry import TelemetryHandle, Tracer, WindowedSeries, device_series
from repro.pimsys.topology import DeviceTopology


# --------------------------------------------------------------------------
# Requests
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NttJob:
    """One size-n NTT (inverse by default, the paper's orientation)."""

    n: int
    forward: bool = False


@dataclasses.dataclass(frozen=True)
class PolymulJob:
    """One RLWE polynomial product: NTT(a), NTT(b), ⊙, INTT, scale."""

    n: int


@dataclasses.dataclass(frozen=True)
class ShardedNttJob:
    """ONE size-n NTT gang-scheduled over `banks` banks at once.

    Dispatched when `banks` banks are free (FIFO order is preserved, so
    a gang job at the head waits — classic head-of-line gang blocking —
    while single-bank jobs behind it keep their arrival order).  The
    reserved gang runs the four-step sharded plan of
    `repro.pimsys.sharded` on the banks it was placed on; during the
    reservation the gang's channels are modeled as dedicated to it (a
    sharded job's bus traffic does not interleave with concurrent
    single-bank jobs' — the reservation approximation, noted here
    because it slightly favors the gang under mixed load).
    """

    n: int
    banks: int = 2
    forward: bool = False


@dataclasses.dataclass(frozen=True)
class GangJob:
    """A generic gang-scheduled job: `banks` banks reserved at once.

    The scheduler knows nothing about what runs inside the reservation —
    the owning compiled plan primes a *resolver* (`prime_gang`) that,
    given the reserved flat banks, returns the gang's latency and stats
    in the same shape `ShardedNttJob` uses.  `op` is the hashable op
    spec the plan compiled (the cache identity); `rows` the per-bank
    working-set bound validated against `rows_per_bank`.  The
    reservation approximation of `ShardedNttJob` applies: the gang's
    bus traffic runs on a dedicated sub-device timeline.  HE ciphertext
    ops (`repro.he`) dispatch through this.
    """

    op: object
    banks: int = 1
    rows: int = 1


Job = NttJob | PolymulJob | ShardedNttJob | GangJob

#: jobs that gang-reserve `job.banks` banks per dispatch
GANG_JOBS = (ShardedNttJob, GangJob)


def job_commands(cfg: PimConfig, job: Job) -> list[Command]:
    if isinstance(job, NttJob):
        return RowCentricMapper(cfg, job.n, forward=job.forward).commands()
    if isinstance(job, PolymulJob):
        return polymul_commands(cfg, job.n)[0]
    if isinstance(job, ShardedNttJob):
        raise TypeError(
            "ShardedNttJob spans banks and has no single-bank command "
            "stream; use ShardedNttPlan(...).local_streams() instead")
    if isinstance(job, GangJob):
        raise TypeError(
            f"{job} spans banks and has no single-bank command stream; "
            "gang jobs resolve through their primed resolver")
    raise TypeError(job)


def job_rows(cfg: PimConfig, job: Job) -> int:
    """Rows of bank storage the job's working set occupies (per bank)."""
    if isinstance(job, ShardedNttJob):
        return max(1, (job.n // job.banks) // cfg.row_words)
    if isinstance(job, GangJob):
        return job.rows
    rows = max(1, job.n // cfg.row_words)
    return rows if isinstance(job, NttJob) else 2 * rows  # polymul holds a AND b


def poisson_arrivals_ns(seed: int, count: int, rate_per_us: float) -> np.ndarray:
    """Arrival times (ns) of `count` Poisson arrivals at `rate_per_us`.

    THE arrival-trace formula: `run_open_loop` and the service's
    `submit_poisson` both call it, so the two paths stay bit-identical
    on the same seed by construction.
    """
    if rate_per_us <= 0:
        raise ValueError("rate_per_us must be positive")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1e3 / rate_per_us, size=count))


# --------------------------------------------------------------------------
# Service policy: QoS classes, admission control, batching
# --------------------------------------------------------------------------


QOS_CLASSES = ("latency", "throughput")

# request status codes (SchedulerResult.status)
STATUS_COMPLETED, STATUS_REJECTED = 1, 2


@dataclasses.dataclass(frozen=True)
class ServicePolicy:
    """Dispatch policy of the service layer (`run_service`).

    The default instance is deliberately neutral — equal class weights,
    no admission limits, no batching — and is bit-identical to the
    legacy FIFO loop on any arrival trace.  Every knob departs from
    that anchor:

    weight_latency / weight_throughput
        Priority-aging weights: priority = weight * (now - arrival).
        Equal weights = arrival order (FIFO).
    max_queue_depth
        Admit a request only while fewer than this many admitted
        requests are queued undispatched; excess arrivals are rejected
        (reason ``queue_full``).  None = unbounded.
    bucket_rate_per_us / bucket_burst
        Token-bucket rate limiter refilled in simulated time; an
        arrival that finds no token is shed (reason ``rate_limited``).
        None = unlimited.
    batch_window_us / max_batch
        Plan-coalescing window: throughput-class single-bank requests
        with the same job spec gang-issue together (see module
        docstring).  0.0 disables batching.
    telemetry / telemetry_window_us
        Record the run's timeline (`repro.pimsys.telemetry`): per-command
        device events, per-request lifecycle spans, admission-reject
        instants, and tumbling-window series (queue depth per class,
        rejects, bus/bank occupancy) at `telemetry_window_us` windows.
        The result then carries a `TelemetryHandle` and the stats
        registry a `timeseries` summary block.  Off by default — the
        dispatch loop and the device pay nothing.
    backend / verify_every
        ``backend="fastpath"`` times every single-bank dispatch (and
        every coalesced gang) through the compiled vectorized evaluator
        (`repro.pimsys.fastpath`) instead of stepping the interpreted
        device command-by-command: each (job, gang-size) gets ONE
        dedicated-bank profile, evaluated once and replayed as O(1)
        per-dispatch arithmetic — what makes million-request sweeps
        tractable.  The model is the dedicated-gang timeline the
        sharded path already uses (no cross-dispatch bus contention or
        carried bank state), so absolute timestamps are a model of the
        interpreted backend's, not a bit-copy; each profile itself IS
        bit-identical to the interpreted engine, and `verify_every=K`
        makes every K-th fastpath dispatch prove that by replaying its
        profile stream through the interpreted oracle (cached per
        profile; `FastpathMismatch` on any divergence).  Incompatible
        with telemetry (the fastpath records no per-command events).
    """

    weight_latency: float = 1.0
    weight_throughput: float = 1.0
    max_queue_depth: int | None = None
    bucket_rate_per_us: float | None = None
    bucket_burst: int = 1
    batch_window_us: float = 0.0
    max_batch: int = 8
    telemetry: bool = False
    telemetry_window_us: float = 50.0
    backend: str = "engine"
    verify_every: int = 0

    def __post_init__(self):
        if self.backend not in ("engine", "fastpath"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             "expected 'engine' or 'fastpath'")
        if self.verify_every < 0:
            raise ValueError("verify_every must be >= 0")
        if self.backend == "fastpath" and self.telemetry:
            raise ValueError(
                "backend='fastpath' records no per-command telemetry; "
                "disable telemetry or use backend='engine'")
        if self.weight_latency <= 0 or self.weight_throughput <= 0:
            raise ValueError("QoS weights must be positive")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1 (or None)")
        if self.bucket_rate_per_us is not None and self.bucket_rate_per_us <= 0:
            raise ValueError("bucket_rate_per_us must be positive (or None)")
        if self.bucket_burst < 1:
            raise ValueError("bucket_burst must be >= 1")
        if self.batch_window_us < 0:
            raise ValueError("batch_window_us must be >= 0")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.telemetry_window_us <= 0:
            raise ValueError("telemetry_window_us must be positive")

    def weight(self, qos: str) -> float:
        return self.weight_latency if qos == "latency" else self.weight_throughput

    @property
    def batching(self) -> bool:
        return self.batch_window_us > 0.0 and self.max_batch > 1


DEFAULT_POLICY = ServicePolicy()


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    """One request entering the service dispatcher.

    `deadline_ns` is relative to `arrival_ns` (an SLO, not an absolute
    timestamp); None means no deadline.
    """

    arrival_ns: float
    job: Job
    qos: str = "throughput"
    deadline_ns: float | None = None

    def __post_init__(self):
        if self.qos not in QOS_CLASSES:
            raise ValueError(f"qos must be one of {QOS_CLASSES}, got {self.qos!r}")
        if self.deadline_ns is not None and self.deadline_ns <= 0:
            raise ValueError("deadline_ns must be positive (or None)")
        if self.arrival_ns < 0:
            raise ValueError("arrival_ns must be >= 0")


class _TokenBucket:
    """Token-bucket rate limiter over simulated time."""

    __slots__ = ("rate_per_ns", "burst", "tokens", "t")

    def __init__(self, rate_per_us: float, burst: int):
        self.rate_per_ns = rate_per_us / 1e3
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t = 0.0

    def take(self, now: float) -> bool:
        tokens = self.tokens + (now - self.t) * self.rate_per_ns
        if tokens > self.burst:
            tokens = self.burst
        self.t = now
        if tokens >= 1.0:
            self.tokens = tokens - 1.0
            return True
        self.tokens = tokens
        return False


class _Waiting:
    """An admitted, not-yet-dispatched request."""

    __slots__ = ("arrival", "seq", "job", "qos", "deadline")

    def __init__(self, arrival, seq, job, qos, deadline):
        self.arrival = arrival
        self.seq = seq
        self.job = job
        self.qos = qos
        self.deadline = deadline


class _Batch:
    """Bank-release bookkeeping for one coalesced gang issue."""

    __slots__ = ("remaining", "flat", "max_done")

    def __init__(self, remaining: int, flat: int):
        self.remaining = remaining
        self.flat = flat
        self.max_done = 0.0


class _FastProfile:
    """Dedicated-bank timing profile of one (job, gang size) under
    `ServicePolicy(backend="fastpath")`: evaluated once by the
    vectorized fastpath, replayed per dispatch as gate + offsets."""

    __slots__ = ("member_done", "release", "counters", "bus_busy")

    def __init__(self, member_done, release, counters, bus_busy):
        self.member_done = member_done  # per-member completion offset
        self.release = release          # bank release offset (max done)
        self.counters = counters        # whole-gang bank counters
        self.bus_busy = bus_busy        # whole-gang bus occupancy (ns)


# --------------------------------------------------------------------------
# Results
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SchedulerResult:
    """Aggregate result of one scheduler run.

    Rows are in DISPATCH-DECISION order (identical to arrival order for
    the FIFO loop).  The service-dispatch fields default to None/empty
    on legacy FIFO runs: `qos` (class per row), `deadline_ns` (relative
    SLO, NaN = none), `status` (STATUS_COMPLETED / STATUS_REJECTED),
    `batched` (row rode a coalesced gang), `request_ids` (submission
    index per row, the futures' join key), `rejected_by` ((qos, reason)
    -> count), `batches`/`coalesced` (gang issues and member count),
    and `seed` (the arrival-trace RNG seed, for reproducibility).
    """

    submitted: int
    completed: int
    makespan_ns: float
    arrivals_ns: np.ndarray
    dispatch_ns: np.ndarray
    done_ns: np.ndarray
    stats: StatsRegistry
    qos: list[str] | None = None
    deadline_ns: np.ndarray | None = None
    status: np.ndarray | None = None
    batched: np.ndarray | None = None
    request_ids: np.ndarray | None = None
    rejected_by: dict = dataclasses.field(default_factory=dict)
    batches: int = 0
    coalesced: int = 0
    seed: int | list | None = None
    telemetry: TelemetryHandle | None = None

    @property
    def latency_ns(self) -> np.ndarray:
        return self.done_ns - self.arrivals_ns

    @property
    def queue_delay_ns(self) -> np.ndarray:
        return self.dispatch_ns - self.arrivals_ns

    @property
    def rejected(self) -> int:
        return sum(self.rejected_by.values())

    def _mask(self, qos: str | None = None) -> np.ndarray:
        """Completed rows, optionally restricted to one QoS class."""
        if self.status is None:
            m = np.ones(self.submitted, dtype=bool)
        else:
            m = self.status == STATUS_COMPLETED
        if qos is not None:
            if self.qos is None:
                raise ValueError("this result carries no QoS classes")
            m = m & np.array([c == qos for c in self.qos])
        return m

    def class_latency_ns(self, qos: str | None = None) -> np.ndarray:
        """Latencies of completed requests (one class, or all)."""
        return self.latency_ns[self._mask(qos)]

    def latency_percentiles_us(self, qs: Sequence[float] = (50, 95, 99),
                               qos: str | None = None) -> dict:
        lat = self.class_latency_ns(qos)
        if lat.size == 0:
            return {f"p{int(q)}": 0.0 for q in qs}
        lat = lat / 1e3
        return {f"p{int(q)}": float(np.percentile(lat, q)) for q in qs}

    def deadline_attainment(self, qos: str | None = None) -> float:
        """Fraction of completed deadline-carrying requests that met
        their deadline; 1.0 when no completed request carries one."""
        if self.deadline_ns is None:
            return 1.0
        m = self._mask(qos) & np.isfinite(self.deadline_ns)
        if not m.any():
            return 1.0
        return float((self.latency_ns[m] <= self.deadline_ns[m]).mean())

    @property
    def throughput_jobs_per_ms(self) -> float:
        if self.makespan_ns <= 0:
            return 0.0
        return self.completed / (self.makespan_ns / 1e6)

    def windowed_deadline_attainment(
            self, window_us: float, qos: str | None = None,
    ) -> list[list[float]]:
        """Deadline attainment over tumbling completion-time windows:
        `[[window_start_us, attained_fraction], ...]` over completed
        deadline-carrying requests (one class, or all).  Computed from
        the result arrays, so it needs no telemetry recording — the
        per-class SLO timeline `examples/serve_polymul.py` prints.
        """
        if self.deadline_ns is None:
            return []
        m = self._mask(qos) & np.isfinite(self.deadline_ns)
        if not m.any():
            return []
        series = WindowedSeries(window_us * 1e3, "mean")
        met = self.latency_ns[m] <= self.deadline_ns[m]
        for t, ok in zip(self.done_ns[m], met):
            series.record(float(t), 1.0 if ok else 0.0)
        return series.points_us()

    def class_throughput_jobs_per_ms(self, qos: str) -> float:
        if self.makespan_ns <= 0:
            return 0.0
        return int(self._mask(qos).sum()) / (self.makespan_ns / 1e6)

    def summary(self, window_us: float | None = None) -> dict:
        """Flat report dict.  With `window_us`, per-class blocks gain
        `deadline_attainment_windows` — the tumbling-window SLO timeline
        of `windowed_deadline_attainment` (array-derived, available with
        telemetry off)."""
        out = {
            "submitted": self.submitted,
            "completed": self.completed,
            "makespan_us": self.makespan_ns / 1e3,
            "throughput_jobs_per_ms": self.throughput_jobs_per_ms,
            "mean_queue_delay_us": (
                float(self.queue_delay_ns[self._mask()].mean() / 1e3)
                if self.completed else 0.0),
            "seed": self.seed,
        }
        out.update(self.latency_percentiles_us())
        if self.qos is not None:
            out["rejected"] = self.rejected
            out["batches"] = self.batches
            out["coalesced"] = self.coalesced
            per_class = {}
            for cls in QOS_CLASSES:
                n_cls = sum(1 for c in self.qos if c == cls)
                if not n_cls:
                    continue
                block = {
                    "submitted": n_cls,
                    "completed": int(self._mask(cls).sum()),
                    "rejected": sum(v for (c, _), v in self.rejected_by.items()
                                    if c == cls),
                    "throughput_jobs_per_ms":
                        self.class_throughput_jobs_per_ms(cls),
                    "deadline_attainment": self.deadline_attainment(cls),
                }
                if window_us is not None:
                    block["deadline_attainment_windows"] = \
                        self.windowed_deadline_attainment(window_us, cls)
                block.update(self.latency_percentiles_us(qos=cls))
                per_class[cls] = block
            out["per_class"] = per_class
        return out


# --------------------------------------------------------------------------
# Scheduler
# --------------------------------------------------------------------------


class RequestScheduler:
    def __init__(self, cfg: PimConfig, topo: DeviceTopology | None = None,
                 policy: str = "rr", pipelined: bool = True):
        self.cfg = cfg
        self.topo = topo or DeviceTopology.from_config(cfg)
        self.policy = policy
        self.pipelined = pipelined
        # job -> (commands, param-cache residency trace or None)
        self._cmd_cache: dict[Job, tuple[list[Command], tuple | None]] = {}
        # job -> WARM param-cache residency trace (steady-state repeat of
        # the same stream on the same bank CU) for coalesced gang issues
        self._warm_cache: dict[Job, tuple | None] = {}
        # sharded-plan timing cache: only the shard count, orientation and
        # the gang's per-shard channel placement affect the latency.
        # Values are (latency_ns, per-shard counters, per-channel bus
        # busy ns, device counters) — see _sharded_latency.
        self._sharded_cache: dict[tuple, tuple[float, list, dict, dict]] = {}
        # GangJob -> resolver(flats) -> (latency_ns, per-bank counters,
        # per-channel bus busy, device counters); resolved results cache
        # by channel pattern exactly like the sharded cache
        self._gang_resolvers: dict[GangJob, object] = {}
        self._gang_cache: dict[tuple, tuple[float, list, dict, dict]] = {}
        # (job, gang size) -> _FastProfile for ServicePolicy(backend=
        # "fastpath"); _fast_verified holds the profiles already proven
        # against the interpreted oracle (verify_every sampling).
        self._fast_profiles: dict[tuple[Job, int], _FastProfile] = {}
        self._fast_verified: set[tuple[Job, int]] = set()

    # -- injection frontends -------------------------------------------------
    def run_closed_loop(self, jobs: Iterable[Job]) -> SchedulerResult:
        """Fixed batch: all requests present at t=0."""
        jobs = list(jobs)
        return self._run([(0.0, j) for j in jobs])

    def run_open_loop(self, jobs: Iterable[Job], rate_per_us: float,
                      seed: int = 0) -> SchedulerResult:
        """Poisson arrivals at `rate_per_us` requests/us (open loop)."""
        jobs = list(jobs)
        arrivals = poisson_arrivals_ns(seed, len(jobs), rate_per_us)
        return self._run(list(zip(arrivals.tolist(), jobs)))

    # -- plan priming (repro.pimsys.session) ---------------------------------
    def prime(self, job: Job, commands: Sequence[Command],
              param_trace=None) -> None:
        """Pre-populate the per-job command cache from a compiled plan.

        `PimSession.submit` routes `CompiledPlan`s here so queued traffic
        replays the plan's frozen stream (and its precomputed
        parameter-cache residency trace) instead of re-running the
        mapper per distinct job spec.  The stream must be the job's
        canonical one (`job_commands` equivalent) — the scheduler trusts
        the session's compiler for that.
        """
        if isinstance(job, GANG_JOBS):
            raise TypeError("gang jobs have no single-bank stream to prime; "
                            "use prime_gang (the sharded plan cache handles "
                            "ShardedNttJob)")
        if job_rows(self.cfg, job) > self.cfg.rows_per_bank:
            raise ValueError(f"{job} does not fit in one bank")
        if param_trace is None and self.cfg.param_cache_entries:
            from repro.pimsys.engine import param_beat_trace

            param_trace = param_beat_trace(self.cfg, job.n, commands)
        self._cmd_cache[job] = (list(commands), param_trace)

    # -- core event loop -----------------------------------------------------
    def _commands(self, job: Job) -> tuple[list[Command], tuple | None]:
        hit = self._cmd_cache.get(job)
        if hit is None:
            if job_rows(self.cfg, job) > self.cfg.rows_per_bank:
                raise ValueError(f"{job} does not fit in one bank")
            cmds = job_commands(self.cfg, job)
            trace = None
            if self.cfg.param_cache_entries:
                from repro.pimsys.engine import param_beat_trace

                trace = param_beat_trace(self.cfg, job.n, cmds)
            hit = self._cmd_cache[job] = (cmds, trace)
        return hit

    def _sharded_latency(self, job: ShardedNttJob, flats: Sequence[int]):
        """Latency + stats of a gang job on the banks it was placed on.

        Simulated on an idle clone of the device (the gang reservation —
        see `ShardedNttJob`); cached by the placement's channel pattern,
        which is all the plan's timing depends on.  Counters are cached
        PER SHARD (not as a registry keyed to the first placement's
        banks) so a later gang with the same channel pattern but
        different banks attributes its work to the banks it actually
        ran on.  Returns (latency_ns, per_shard_counters, per_channel
        bus busy, device counters).
        """
        from repro.pimsys.sharded import ShardedNttPlan

        key = (job.n, job.banks, job.forward,
               tuple(self.topo.channel_of(f) for f in flats))
        hit = self._sharded_cache.get(key)
        if hit is None:
            plan = ShardedNttPlan(self.cfg, job.n, job.banks,
                                  forward=job.forward, topo=self.topo,
                                  flat_banks=flats)
            r = plan.simulate(policy=self.policy, baseline=False,
                              pipelined=self.pipelined)
            shard_counters = []
            for f in flats:
                addr = self.topo.address_of(f)
                shard_counters.append(
                    r.stats.bank_counts(addr.channel, self.topo.local_id(addr)))
            bus_busy = {ch: r.stats.bus_busy_ns(ch) for ch in r.stats.channels()}
            dev = {"xfer_atoms": r.xfer_atoms, "xfer_hops": r.xfer_hops}
            hit = self._sharded_cache[key] = (
                r.latency_ns, shard_counters, bus_busy, dev)
        return hit

    # -- generic gang jobs (repro.he and other extension ops) ----------------
    def prime_gang(self, job: GangJob, resolver) -> None:
        """Register the resolver a `GangJob` dispatches through.

        `resolver(flats)` simulates the gang on the reserved flat banks
        (on its own idle sub-device, the gang reservation model) and
        returns `(latency_ns, per_bank_counters, bus_busy_by_channel,
        device_counters)` — the exact shape `_sharded_latency` returns,
        so the dispatch loops and stats merging treat both identically.
        Results are cached by the placement's channel pattern, so the
        resolver runs once per distinct pattern no matter how many
        requests replay the plan.  Compiled plans prime this through
        `CompiledPlan.prime_scheduler`.
        """
        if not isinstance(job, GangJob):
            raise TypeError(f"prime_gang takes a GangJob, got {job!r}")
        self._gang_resolvers[job] = resolver

    def _gang_latency(self, job, flats: Sequence[int]):
        """Latency + stats of any gang job on its reserved banks."""
        if isinstance(job, ShardedNttJob):
            return self._sharded_latency(job, flats)
        key = (job, tuple(self.topo.channel_of(f) for f in flats))
        hit = self._gang_cache.get(key)
        if hit is None:
            resolver = self._gang_resolvers.get(job)
            if resolver is None:
                raise TypeError(
                    f"{job} has no primed resolver; submit gang plans "
                    "through the service (CompiledPlan.prime_scheduler) "
                    "or call prime_gang first")
            hit = self._gang_cache[key] = resolver(list(flats))
        return hit

    def _batch_traces(self, job: Job) -> tuple[tuple | None, tuple | None]:
        """(cold, warm) parameter-cache residency traces for one member
        of a coalesced gang issue.

        The first member starts from a cold per-bank CU cache (the
        plan's ordinary trace); members after it find the cache in the
        steady state the stream itself leaves behind, so they replay the
        WARM trace — the second pass of the stream issued twice.  LRU
        state after any full pass equals the state after the first, so
        one doubled-stream evaluation covers every subsequent member.
        Both traces derive from the frozen command list: zero mapper
        regeneration.  (None, None) when the device cache is disabled.
        """
        cmds, cold = self._commands(job)
        if cold is None:
            return None, None
        warm = self._warm_cache.get(job)
        if warm is None:
            from repro.pimsys.engine import param_beat_trace

            doubled = param_beat_trace(self.cfg, job.n, cmds + cmds)
            warm = self._warm_cache[job] = doubled[len(cold):]
        return cold, warm

    def _fast_stream(self, job: Job, members: int):
        """The concatenated (commands, param_trace) one coalesced gang of
        `members` same-spec requests runs on its bank: cold first pass,
        warm steady-state repeats — exactly what the engine backend
        enqueues on the batch dispatch path."""
        cmds, trace = self._commands(job)
        if members == 1:
            return cmds, trace
        cold, warm = self._batch_traces(job)
        stream = cmds * members
        full = None if cold is None else tuple(cold) + tuple(warm) * (members - 1)
        return stream, full

    def _fast_profile(self, job: Job, members: int) -> _FastProfile:
        key = (job, members)
        hit = self._fast_profiles.get(key)
        if hit is None:
            from repro.pimsys.fastpath import evaluate_gang, lower_commands

            stream, trace = self._fast_stream(job, members)
            lp = lower_commands(self.cfg, stream, trace)
            g = evaluate_gang(lp, 1, pipelined=self.pipelined)
            dones = g.dones[:, 0]
            per = lp.n_cmds // members
            member_done = tuple(float(dones[m * per:(m + 1) * per].max())
                                for m in range(members))
            hit = self._fast_profiles[key] = _FastProfile(
                member_done, float(g.bank_end_ns[0]),
                dict(g.counters[0]), g.bus_busy_ns)
        return hit

    def _verify_fast(self, job: Job, members: int) -> None:
        """Replay one fastpath profile's stream through the interpreted
        engine (`FastpathMismatch` on divergence); each distinct
        profile is proven at most once per scheduler."""
        key = (job, members)
        if key in self._fast_verified:
            return
        from repro.pimsys.fastpath import verify_stream

        stream, trace = self._fast_stream(job, members)
        verify_stream(self.cfg, stream, 1, param_trace=trace,
                      pipelined=self.pipelined)
        self._fast_verified.add(key)

    def _validate_gang(self, job) -> None:
        """Fail fast on an unsatisfiable gang spec — for sharded NTTs the
        plan constructor holds the single copy of the rules (power-of-two
        banks and n, shard >= one atom, row fit, topology fit, buffer
        count); a generic `GangJob` checks its declared bank/row needs."""
        if isinstance(job, GangJob):
            if not 1 <= job.banks <= self.topo.total_banks:
                raise ValueError(
                    f"{job} needs {job.banks} banks; topology has "
                    f"{self.topo.total_banks}")
            if job.rows > self.cfg.rows_per_bank:
                raise ValueError(f"{job} does not fit in one bank")
            return
        from repro.pimsys.sharded import ShardedNttPlan

        ShardedNttPlan(self.cfg, job.n, job.banks, forward=job.forward,
                       topo=self.topo)

    def _run(self, arrivals: list[tuple[float, Job]]) -> SchedulerResult:
        for job in {j for _, j in arrivals if isinstance(j, GANG_JOBS)}:
            self._validate_gang(job)
        tracer = Tracer() if self.cfg.telemetry else None
        device = Device(self.cfg, self.topo, policy=self.policy,
                        pipelined=self.pipelined, tracer=tracer)
        topo = self.topo
        pending = deque(sorted(arrivals, key=lambda p: p[0]))
        free: list[tuple[float, int]] = [(0.0, b) for b in range(topo.total_banks)]
        heapq.heapify(free)

        n = len(arrivals)
        t_arr = np.zeros(n)
        t_disp = np.zeros(n)
        t_done = np.zeros(n)
        done_count = 0
        jid = 0
        gang_makespan = 0.0
        # (flats, per-shard counters, per-channel bus busy, device counters)
        gang_stats: list[tuple] = []

        def record(ev):
            nonlocal done_count
            t_done[ev.job_id] = ev.done
            done_count += 1
            flat = topo.flat_from_local(ev.channel, ev.bank)
            heapq.heappush(free, (ev.done, flat))

        def need(job: Job) -> int:
            return job.banks if isinstance(job, GANG_JOBS) else 1

        while pending:
            t, job = pending[0]
            k = need(job)
            # surface every completion the device reaches before this arrival
            while True:
                evs = device.advance(horizon=t)
                if evs is None:
                    break
                for ev in evs:
                    record(ev)
            # Advance past any in-flight completion that beats the release
            # times currently known in `free`: gang reservations park their
            # banks in the heap with FUTURE timestamps, and a busy bank may
            # complete sooner than those — the k-th best known release is
            # exactly the horizon beyond which more device progress can't
            # improve this dispatch.  The horizon is only recomputed when a
            # completion changes `free` (advance issues ONE command per call
            # and usually completes nothing), and the common k=1 case reads
            # the heap minimum instead of scanning.
            horizon_stale = True
            while True:
                if horizon_stale:
                    if len(free) >= k:
                        horizon = free[0][0] if k == 1 else \
                            heapq.nsmallest(k, free)[-1][0]
                    else:
                        horizon = math.inf
                    horizon_stale = False
                if len(free) >= k and horizon <= t:
                    break
                evs = device.advance(horizon=horizon)
                if evs is None:
                    if len(free) < k:  # pragma: no cover - deficit implies work queued
                        raise RuntimeError("scheduler stalled with jobs in flight")
                    break
                for ev in evs:
                    record(ev)
                    horizon_stale = True
            pending.popleft()
            picked = [heapq.heappop(free) for _ in range(k)]
            gate = max(t, max(ft for ft, _ in picked))
            t_arr[jid], t_disp[jid] = t, gate
            if isinstance(job, GANG_JOBS):
                # gang reservation: the plan runs on its own sub-device
                # timeline; the banks rejoin the pool at completion
                flats = [f for _, f in picked]
                dur, shard_counters, bus_busy, dev_c = self._gang_latency(job, flats)
                done = gate + dur
                t_done[jid] = done
                done_count += 1
                gang_makespan = max(gang_makespan, done)
                gang_stats.append((flats, shard_counters, bus_busy, dev_c))
                for f in flats:
                    heapq.heappush(free, (done, f))
            else:
                cmds, trace = self._commands(job)
                device.enqueue_flat(picked[0][1], cmds, gate=gate,
                                    job_id=jid, param_trace=trace)
            jid += 1

        for ev in device.drain():
            record(ev)

        if done_count != n:  # not an assert: must survive python -O
            raise RuntimeError(f"conservation violated: {done_count} != {n}")
        stats = device.stats()
        for flats, shard_counters, bus_busy, dev_c in gang_stats:
            for f, counters in zip(flats, shard_counters):
                addr = topo.address_of(f)
                stats.add_bank(addr.channel, topo.local_id(addr), counters)
            for ch, busy in bus_busy.items():
                stats.add_bus(ch, busy, 0.0)
            stats.add_device(dev_c)
        makespan = max(device.makespan_ns, gang_makespan)
        # gang sub-device spans are gang-relative; the utilization
        # denominator must be the whole run
        stats.extend_span(makespan)
        tel = None
        if tracer is not None:
            for row in range(n):
                tracer.request_spans.append(
                    (row, "", "queue_wait", t_arr[row], t_disp[row]))
                tracer.request_spans.append(
                    (row, "", "execute", t_disp[row], t_done[row]))
            tel = TelemetryHandle(tracer)
        return SchedulerResult(
            submitted=n,
            completed=done_count,
            makespan_ns=makespan,
            arrivals_ns=t_arr,
            dispatch_ns=t_disp,
            done_ns=t_done,
            stats=stats,
            telemetry=tel,
        )

    # -- service dispatch: QoS aging, admission control, batching ------------
    def run_service(self, requests: Sequence[ServiceRequest],
                    policy: ServicePolicy | None = None,
                    seed: int | list | None = None) -> SchedulerResult:
        """Policy-driven dispatch of an explicit request trace.

        The substrate of `repro.pimsys.service.DeviceService` — see the
        module docstring for the policy semantics.  `seed` is recorded
        verbatim on the result (and in `summary()`) so a run is
        reproducible from its artifact; the arrival trace itself is the
        caller's (the service generates it from that seed).

        With the default `ServicePolicy()` the dispatch sequence, every
        timestamp array, and the device stats are bit-identical to the
        legacy FIFO loop (`run_closed_loop` / `run_open_loop`) on the
        same trace.
        """
        policy = DEFAULT_POLICY if policy is None else policy
        requests = list(requests)
        for req in {r.job for r in requests if isinstance(r.job, GANG_JOBS)}:
            self._validate_gang(req)
        fast = policy.backend == "fastpath"
        if fast and self.cfg.telemetry:
            raise ValueError(
                "backend='fastpath' records no per-command telemetry; "
                "disable cfg.telemetry or use backend='engine'")
        # GangJob traffic composes with fastpath: its dispatch never steps
        # the shared device (the primed resolver runs once per channel
        # pattern and replays O(1) from the gang cache), so only sharded
        # NTTs — which interleave on the interpreted device — are rejected.
        if fast and any(isinstance(r.job, ShardedNttJob) for r in requests):
            # fail loudly rather than silently timing the gang on the
            # interpreted engine while every other dispatch is fastpath
            raise ValueError(
                "backend='fastpath' does not support sharded plans: "
                "ShardedNttJob gangs need the interpreted engine's "
                "cross-bank exchange model; use backend='engine'")
        tracer = Tracer() if (policy.telemetry or self.cfg.telemetry) else None
        window_ns = policy.telemetry_window_us * 1e3
        if tracer is not None:
            qd_series = {cls: WindowedSeries(window_ns, "max")
                         for cls in QOS_CLASSES}
            rej_series = WindowedSeries(window_ns, "sum")
        # coalesced gang members share one bank's working rows (same job
        # spec), so the single-job fit check in _commands covers batches
        device = Device(self.cfg, self.topo, policy=self.policy,
                        pipelined=self.pipelined, tracer=tracer)
        topo = self.topo
        n = len(requests)
        order = sorted(range(n), key=lambda i: (requests[i].arrival_ns, i))

        t_arr = np.zeros(n)
        t_disp = np.full(n, np.nan)
        t_done = np.full(n, np.nan)
        deadline = np.full(n, np.nan)
        status = np.zeros(n, dtype=np.int8)
        batched = np.zeros(n, dtype=bool)
        request_ids = np.zeros(n, dtype=np.int64)
        qos_rows: list[str] = [""] * n
        rejected_by: dict[tuple[str, str], int] = {}
        admitted = 0
        done_count = 0
        rid = 0  # next result row (dispatch-decision order)
        gang_makespan = 0.0
        gang_stats: list[tuple] = []
        n_batches = 0
        n_coalesced = 0
        # fastpath bookkeeping: dispatch counter for verify sampling and
        # (job, gang size, flat bank) -> use count for stats replay
        n_fast = 0
        fast_uses: dict[tuple[Job, int, int], int] = {}

        # Admitted-but-undispatched requests, one deque per QoS class.
        # Arrivals ingest in time order, so each deque stays sorted by
        # (arrival, seq) and its HEAD is the class's oldest request —
        # which, at any fixed weight, is also its highest-priority one.
        # Selection therefore compares just the two heads: O(1) per
        # dispatch instead of scanning the whole queue at saturation.
        lat_q: deque = deque()
        tput_q: deque = deque()
        n_waiting = 0
        bucket = (None if policy.bucket_rate_per_us is None
                  else _TokenBucket(policy.bucket_rate_per_us, policy.bucket_burst))
        free: list[tuple[float, int]] = [(0.0, b) for b in range(topo.total_banks)]
        heapq.heapify(free)
        batch_of: dict[int, _Batch] = {}

        def record(ev):
            nonlocal done_count
            t_done[ev.job_id] = ev.done
            done_count += 1
            b = batch_of.pop(ev.job_id, None)
            if b is None:
                flat = topo.flat_from_local(ev.channel, ev.bank)
                heapq.heappush(free, (ev.done, flat))
                return
            b.remaining -= 1
            if ev.done > b.max_done:
                b.max_done = ev.done
            if b.remaining == 0:
                heapq.heappush(free, (b.max_done, b.flat))

        def surface(t: float) -> None:
            """Surface every completion the device reaches before t."""
            while True:
                evs = device.advance(horizon=t)
                if evs is None:
                    return
                for ev in evs:
                    record(ev)

        def ingest(seq: int, queue: bool = True) -> _Waiting | None:
            """Admission-check one arrival; queue it or reject it.

            `queue=False` admits a batch joiner that dispatches
            immediately instead of waiting: the rate limiter still
            applies (it meters arrivals), the queue-depth bound does
            not (the joiner never occupies the queue).
            """
            nonlocal rid, admitted, n_waiting
            req = requests[seq]
            t = req.arrival_ns
            if (queue and policy.max_queue_depth is not None
                    and n_waiting >= policy.max_queue_depth):
                reason = "queue_full"
            elif bucket is not None and not bucket.take(t):
                reason = "rate_limited"
            else:
                admitted += 1
                w = _Waiting(t, seq, req.job, req.qos, req.deadline_ns)
                if queue:
                    q = lat_q if req.qos == "latency" else tput_q
                    q.append(w)
                    n_waiting += 1
                    if tracer is not None:
                        qd_series[req.qos].record(t, float(len(q)))
                return w
            row = rid
            rid += 1
            t_arr[row] = t
            qos_rows[row] = req.qos
            request_ids[row] = seq
            status[row] = STATUS_REJECTED
            key = (req.qos, reason)
            rejected_by[key] = rejected_by.get(key, 0) + 1
            if tracer is not None:
                tracer.request_events.append(
                    (seq, req.qos, f"rejected:{reason}", t))
                rej_series.record(t, 1.0)
            return None

        def place(w: _Waiting, row: int, gate: float) -> None:
            t_arr[row] = w.arrival
            t_disp[row] = gate
            qos_rows[row] = w.qos
            request_ids[row] = w.seq
            status[row] = STATUS_COMPLETED  # resolved by conservation check
            if w.deadline is not None:
                deadline[row] = w.deadline

        def need(job: Job) -> int:
            return job.banks if isinstance(job, GANG_JOBS) else 1

        i = 0  # arrival cursor over `order`
        while i < n or n_waiting:
            if not n_waiting:
                seq = order[i]
                t = requests[seq].arrival_ns
                surface(t)
                ingest(seq)
                i += 1
                continue

            # At full load every bank can be in flight (the heap empty);
            # surface completions until one release is known, so the
            # ingest cutoff below tracks the next dispatch opportunity.
            while not free:
                evs = device.advance()
                if evs is None:  # pragma: no cover - no free bank implies work in flight
                    raise RuntimeError(
                        "service dispatch stalled: no free bank, no work in flight")
                for ev in evs:
                    record(ev)
            # Ingest every arrival that lands by the earliest KNOWN
            # dispatch opportunity, so selection sees it.  `cutoff` is a
            # lower bound on the next dispatch gate: the best known bank
            # release (banks absent from the heap only complete later)
            # or the oldest queued arrival, whichever is later.
            cutoff = min(q[0].arrival for q in (lat_q, tput_q) if q)
            if free[0][0] > cutoff:
                cutoff = free[0][0]
            while i < n and requests[order[i]].arrival_ns <= cutoff:
                ingest(order[i])
                i += 1

            # weighted priority aging, evaluated at the decision time
            # over the two class heads (each head is its class's oldest
            # and therefore highest-priority request): ties (equal
            # weights -> pure age) break to arrival order, then
            # submission order — the FIFO anchor.
            t_sel = cutoff
            winner_q = None
            best = (-math.inf, 0.0, 0)
            for q, wt in ((lat_q, policy.weight_latency),
                          (tput_q, policy.weight_throughput)):
                if not q:
                    continue
                h = q[0]
                key = (wt * (t_sel - h.arrival), -h.arrival, -h.seq)
                if key > best:
                    best, winner_q = key, q
            winner = winner_q[0]
            k = need(winner.job)

            # the FIFO loop's horizon dance, anchored at the winner's
            # arrival: surface completions that beat the k-th best known
            # release without peeking past what could matter
            t = winner.arrival
            surface(t)
            horizon_stale = True
            while True:
                if horizon_stale:
                    if len(free) >= k:
                        horizon = free[0][0] if k == 1 else \
                            heapq.nsmallest(k, free)[-1][0]
                    else:
                        horizon = math.inf
                    horizon_stale = False
                if len(free) >= k and horizon <= t:
                    break
                evs = device.advance(horizon=horizon)
                if evs is None:
                    if len(free) < k:  # pragma: no cover - deficit implies work queued
                        raise RuntimeError("service dispatch stalled with jobs in flight")
                    break
                for ev in evs:
                    record(ev)
                    horizon_stale = True
            winner_q.popleft()
            n_waiting -= 1
            picked = [heapq.heappop(free) for _ in range(k)]
            gate = max(t, max(ft for ft, _ in picked))
            if tracer is not None:
                qd_series[winner.qos].record(gate, float(len(winner_q)))

            if isinstance(winner.job, GANG_JOBS):
                flats = [f for _, f in picked]
                dur, shard_counters, bus_busy, dev_c = self._gang_latency(
                    winner.job, flats)
                row = rid
                rid += 1
                place(winner, row, gate)
                done = gate + dur
                t_done[row] = done
                done_count += 1
                gang_makespan = max(gang_makespan, done)
                gang_stats.append((flats, shard_counters, bus_busy, dev_c))
                for f in flats:
                    heapq.heappush(free, (done, f))
                continue

            members = [winner]
            if (policy.batching and winner.qos == "throughput"):
                # Coalesce same-spec throughput work already waiting (no
                # added delay), oldest first — but stay work-conserving:
                # a batch takes at most an even share of the queue (one
                # bank's worth), so fattening one bank's gang never
                # starves the others and the drain-down tail never
                # serializes onto one bank.
                room = min(policy.max_batch - 1,
                           n_waiting // topo.total_banks)
                if room > 0:
                    keep: deque = deque()
                    wj = winner.job
                    for w in tput_q:
                        # w.arrival <= gate: the ingest cutoff can run
                        # ahead of the dispatch gate (gang reservations
                        # park banks at future release times), and a
                        # member must never issue before it arrives
                        if (len(members) <= room and w.job == wj
                                and w.arrival <= gate):
                            members.append(w)
                        else:
                            keep.append(w)
                    n_waiting -= len(members) - 1
                    tput_q.clear()
                    tput_q.extend(keep)
                # Hold the issue open inside the window for same-spec
                # arrivals still in flight (they delay the whole gang).
                # The window only consumes CONSECUTIVE matching
                # arrivals: the first non-matching one closes it and is
                # processed at its own dispatch turn, so its admission
                # check sees the queue state of its own time, not the
                # gang's (no spurious queue_full rejections).
                window_end = gate + policy.batch_window_us * 1e3
                while i < n and len(members) < policy.max_batch:
                    req = requests[order[i]]
                    if (req.arrival_ns > window_end
                            or req.qos != winner.qos
                            or req.job != winner.job):
                        break
                    w = ingest(order[i], queue=False)
                    i += 1
                    if w is not None:  # None: shed by the rate limiter
                        members.append(w)
                        if w.arrival > gate:
                            gate = w.arrival

            flat = picked[0][1]
            if fast:
                # O(1) replay of the gang's dedicated-bank profile: the
                # device never sees the commands, only the bank heap and
                # the timestamp arrays advance.
                m = len(members)
                prof = self._fast_profile(winner.job, m)
                n_fast += 1
                if policy.verify_every and n_fast % policy.verify_every == 0:
                    self._verify_fast(winner.job, m)
                if m > 1:
                    n_batches += 1
                    n_coalesced += m
                for k_m, w in enumerate(members):
                    row = rid
                    rid += 1
                    place(w, row, gate)
                    if m > 1:
                        batched[row] = True
                    t_done[row] = gate + prof.member_done[k_m]
                    done_count += 1
                release = gate + prof.release
                gang_makespan = max(gang_makespan, release)
                heapq.heappush(free, (release, flat))
                fkey = (winner.job, m, flat)
                fast_uses[fkey] = fast_uses.get(fkey, 0) + 1
            elif len(members) == 1:
                cmds, trace = self._commands(winner.job)
                row = rid
                rid += 1
                place(winner, row, gate)
                device.enqueue_flat(flat, cmds, gate=gate, job_id=row,
                                    param_trace=trace)
            else:
                cmds, _ = self._commands(winner.job)
                cold, warm = self._batch_traces(winner.job)
                batch = _Batch(len(members), flat)
                n_batches += 1
                n_coalesced += len(members)
                for m, w in enumerate(members):
                    row = rid
                    rid += 1
                    place(w, row, gate)
                    batched[row] = True
                    device.enqueue_flat(flat, cmds, gate=gate, job_id=row,
                                        param_trace=cold if m == 0 else warm)
                    batch_of[row] = batch

        for ev in device.drain():
            record(ev)

        if rid != n:  # not an assert: must survive python -O
            raise RuntimeError(f"row accounting violated: {rid} != {n}")
        if done_count != admitted:
            raise RuntimeError(
                f"conservation violated: {done_count} completed != "
                f"{admitted} admitted")
        stats = device.stats()
        for flats, shard_counters, bus_busy, dev_c in gang_stats:
            for f, counters in zip(flats, shard_counters):
                addr = topo.address_of(f)
                stats.add_bank(addr.channel, topo.local_id(addr), counters)
            for ch, busy in bus_busy.items():
                stats.add_bus(ch, busy, 0.0)
            stats.add_device(dev_c)
        # fastpath dispatches never touched the device: fold each
        # profile's counters back in, scaled by its per-bank use count
        fast_bus: dict[int, float] = {}
        for (job, m, f), cnt in fast_uses.items():
            prof = self._fast_profiles[(job, m)]
            addr = topo.address_of(f)
            stats.add_bank(addr.channel, topo.local_id(addr),
                           {k: v * cnt for k, v in prof.counters.items()})
            fast_bus[addr.channel] = (fast_bus.get(addr.channel, 0.0)
                                      + prof.bus_busy * cnt)
        for ch, busy in fast_bus.items():
            stats.add_bus(ch, busy, 0.0)
        makespan = max(device.makespan_ns, gang_makespan)
        stats.extend_span(makespan)
        for cls in QOS_CLASSES:
            n_cls = sum(1 for r in requests if r.qos == cls)
            if n_cls:
                stats.add_service(cls, "submitted", n_cls)
        for (cls, reason), count in rejected_by.items():
            stats.add_service(cls, f"rejected_{reason}", count)
        tel = None
        if tracer is not None:
            # Per-request lifecycle spans, from the result arrays: the
            # wait span (arrival -> dispatch; "coalesce_wait" when the
            # row rode a coalesced gang, whose gate may rise to joiner
            # arrivals) plus "execute" (dispatch -> completion) tile the
            # whole end-to-end latency — 100% attribution by
            # construction, which is what report_telemetry.py's >= 95%
            # gate checks survives export/import.
            for row in range(n):
                if status[row] != STATUS_COMPLETED:
                    continue
                rid_tag = int(request_ids[row])
                cls = qos_rows[row]
                wait = "coalesce_wait" if batched[row] else "queue_wait"
                tracer.request_spans.append(
                    (rid_tag, cls, wait, float(t_arr[row]), float(t_disp[row])))
                tracer.request_spans.append(
                    (rid_tag, cls, "execute", float(t_disp[row]),
                     float(t_done[row])))
            for cls, s in qd_series.items():
                if len(s):
                    stats.attach_series(f"queue_depth/{cls}", s)
            if len(rej_series):
                stats.attach_series("admission_rejects", rej_series)
            for name, s in device_series(tracer, window_ns).items():
                stats.attach_series(name, s)
            tel = TelemetryHandle(tracer)
        return SchedulerResult(
            submitted=n,
            completed=done_count,
            makespan_ns=makespan,
            arrivals_ns=t_arr,
            dispatch_ns=t_disp,
            done_ns=t_done,
            stats=stats,
            qos=qos_rows,
            deadline_ns=deadline,
            status=status,
            batched=batched,
            request_ids=request_ids,
            rejected_by=rejected_by,
            batches=n_batches,
            coalesced=n_coalesced,
            seed=seed,
            telemetry=tel,
        )
