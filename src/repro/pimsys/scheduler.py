"""Request queue + batch scheduler for the PIM device.

Accepts `NttJob` / `PolymulJob` requests, places each on a free bank
(earliest-free bank first, which channel-interleaves via the topology's
flat-id order), and injects them either

  closed-loop  a fixed batch all present at t=0 (the paper's §VI-A
               "multiple NTT functions using multiple banks" setting), or
  open-loop    Poisson arrivals at a given rate (the serving regime the
               ROADMAP's north star asks about),

then reports per-request latency percentiles and device throughput.
A bank serves one job at a time; jobs that find no free bank wait in a
FIFO request queue.  Placement is greedy over *known-free* banks — the
controller is advanced only up to each arrival's timestamp, so dispatch
decisions never peek at future completions.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Iterable, Sequence

import numpy as np

from repro.core.mapping import Command, RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.polymul import polymul_commands
from repro.pimsys.controller import Device
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import DeviceTopology


# --------------------------------------------------------------------------
# Requests
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NttJob:
    """One size-n NTT (inverse by default, the paper's orientation)."""

    n: int
    forward: bool = False


@dataclasses.dataclass(frozen=True)
class PolymulJob:
    """One RLWE polynomial product: NTT(a), NTT(b), ⊙, INTT, scale."""

    n: int


Job = NttJob | PolymulJob


def job_commands(cfg: PimConfig, job: Job) -> list[Command]:
    if isinstance(job, NttJob):
        return RowCentricMapper(cfg, job.n, forward=job.forward).commands()
    if isinstance(job, PolymulJob):
        return polymul_commands(cfg, job.n)[0]
    raise TypeError(job)


def job_rows(cfg: PimConfig, job: Job) -> int:
    """Rows of bank storage the job's working set occupies."""
    rows = max(1, job.n // cfg.row_words)
    return rows if isinstance(job, NttJob) else 2 * rows  # polymul holds a AND b


# --------------------------------------------------------------------------
# Results
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SchedulerResult:
    submitted: int
    completed: int
    makespan_ns: float
    arrivals_ns: np.ndarray
    dispatch_ns: np.ndarray
    done_ns: np.ndarray
    stats: StatsRegistry

    @property
    def latency_ns(self) -> np.ndarray:
        return self.done_ns - self.arrivals_ns

    @property
    def queue_delay_ns(self) -> np.ndarray:
        return self.dispatch_ns - self.arrivals_ns

    def latency_percentiles_us(self, qs: Sequence[float] = (50, 95, 99)) -> dict:
        if self.completed == 0:
            return {f"p{int(q)}": 0.0 for q in qs}
        lat = self.latency_ns / 1e3
        return {f"p{int(q)}": float(np.percentile(lat, q)) for q in qs}

    @property
    def throughput_jobs_per_ms(self) -> float:
        if self.makespan_ns <= 0:
            return 0.0
        return self.completed / (self.makespan_ns / 1e6)

    def summary(self) -> dict:
        out = {
            "submitted": self.submitted,
            "completed": self.completed,
            "makespan_us": self.makespan_ns / 1e3,
            "throughput_jobs_per_ms": self.throughput_jobs_per_ms,
            "mean_queue_delay_us": (
                float(self.queue_delay_ns.mean() / 1e3) if self.completed else 0.0),
        }
        out.update(self.latency_percentiles_us())
        return out


# --------------------------------------------------------------------------
# Scheduler
# --------------------------------------------------------------------------


class RequestScheduler:
    def __init__(self, cfg: PimConfig, topo: DeviceTopology | None = None,
                 policy: str = "rr", pipelined: bool = True):
        self.cfg = cfg
        self.topo = topo or DeviceTopology.from_config(cfg)
        self.policy = policy
        self.pipelined = pipelined
        self._cmd_cache: dict[Job, list[Command]] = {}

    # -- injection frontends -------------------------------------------------
    def run_closed_loop(self, jobs: Iterable[Job]) -> SchedulerResult:
        """Fixed batch: all requests present at t=0."""
        jobs = list(jobs)
        return self._run([(0.0, j) for j in jobs])

    def run_open_loop(self, jobs: Iterable[Job], rate_per_us: float,
                      seed: int = 0) -> SchedulerResult:
        """Poisson arrivals at `rate_per_us` requests/us (open loop)."""
        jobs = list(jobs)
        if rate_per_us <= 0:
            raise ValueError("rate_per_us must be positive")
        rng = np.random.default_rng(seed)
        gaps_ns = rng.exponential(1e3 / rate_per_us, size=len(jobs))
        arrivals = np.cumsum(gaps_ns)
        return self._run(list(zip(arrivals.tolist(), jobs)))

    # -- core event loop -----------------------------------------------------
    def _commands(self, job: Job) -> list[Command]:
        cmds = self._cmd_cache.get(job)
        if cmds is None:
            if job_rows(self.cfg, job) > self.cfg.rows_per_bank:
                raise ValueError(f"{job} does not fit in one bank")
            cmds = self._cmd_cache[job] = job_commands(self.cfg, job)
        return cmds

    def _run(self, arrivals: list[tuple[float, Job]]) -> SchedulerResult:
        device = Device(self.cfg, self.topo, policy=self.policy,
                        pipelined=self.pipelined)
        topo = self.topo
        pending = deque(sorted(arrivals, key=lambda p: p[0]))
        free: list[tuple[float, int]] = [(0.0, b) for b in range(topo.total_banks)]
        heapq.heapify(free)

        n = len(arrivals)
        t_arr = np.zeros(n)
        t_disp = np.zeros(n)
        t_done = np.zeros(n)
        done_count = 0
        jid = 0

        def record(ev):
            nonlocal done_count
            t_done[ev.job_id] = ev.done
            done_count += 1
            flat = topo.flat_from_local(ev.channel, ev.bank)
            heapq.heappush(free, (ev.done, flat))

        while pending:
            t, job = pending[0]
            # surface every completion the device reaches before this arrival
            while True:
                evs = device.advance(horizon=t)
                if evs is None:
                    break
                for ev in evs:
                    record(ev)
            if free:
                pending.popleft()
                ft, flat = heapq.heappop(free)
                gate = max(t, ft)
                t_arr[jid], t_disp[jid] = t, gate
                device.enqueue_flat(flat, self._commands(job), gate=gate, job_id=jid)
                jid += 1
            else:
                # all banks busy: advance until one completes
                evs = device.advance()
                if evs is None:  # pragma: no cover - free empty implies work queued
                    raise RuntimeError("scheduler stalled with jobs in flight")
                for ev in evs:
                    record(ev)

        for ev in device.drain():
            record(ev)

        if done_count != n:  # not an assert: must survive python -O
            raise RuntimeError(f"conservation violated: {done_count} != {n}")
        return SchedulerResult(
            submitted=n,
            completed=done_count,
            makespan_ns=device.makespan_ns,
            arrivals_ns=t_arr,
            dispatch_ns=t_disp,
            done_ns=t_done,
            stats=device.stats(),
        )
