"""Async device-service API: futures over the policy-driven dispatcher.

`PimSession` answered "how do I run ONE op efficiently" (compile once,
replay the frozen plan).  This module answers the ROADMAP's serving
question — heavy open-loop NTT/polymul traffic from many clients — by
putting an asynchronous service façade over the device:

    svc = DeviceService(session, policy=ServicePolicy(weight_latency=8.0,
                                                      batch_window_us=10.0))
    plan = svc.session.compile(NttOp(256))
    futs = svc.submit_poisson(plan, count=64, rate_per_us=1.0,
                              qos="throughput", seed=1)
    urgent = svc.submit(plan, qos="latency", deadline_us=50.0, at_us=12.5)
    for fut in svc.as_completed([*futs, urgent]):   # simulated-time order
        rec = fut.result()       # ServedRequest: latency, deadline, status
    svc.result().summary()       # epoch-level SchedulerResult rollup

Execution model (simulated time, resolved lazily)
-------------------------------------------------
Submissions accumulate into the current *epoch*; nothing simulates until
a future's `result()` (or an explicit `flush()`) forces the epoch, which
runs the whole accumulated arrival trace through
`RequestScheduler.run_service` on a fresh device timeline and resolves
every pending future at once.  That keeps the API asynchronous — callers
hold futures, compose them with `gather`/`as_completed` — while the
simulator stays deterministic: the same submissions and seeds replay to
byte-identical results (`SchedulerResult.seed` records the arrival-trace
seed for exactly that purpose).

The dispatcher underneath (see `repro.pimsys.scheduler`) provides QoS
classes with weighted priority aging, bounded-queue + token-bucket
admission control (rejected requests resolve with status ``rejected``
rather than raising), window-based coalescing of same-`(cfg, op)`
arrivals into gang issues that replay the frozen `CompiledPlan` with
zero mapper regeneration, and per-request deadline/SLO accounting.
`ServicePolicy(backend="fastpath", verify_every=K)` swaps the
interpreted device for the compiled vectorized timing backend
(`repro.pimsys.fastpath`) — O(1) profile replay per dispatch, the
knob that makes million-request sweeps (`benchmarks/serving.py
--full`) tractable, with every K-th dispatch differentially checked
against the interpreted oracle.

`PimSession.submit()` is now a one-`DeprecationWarning` shim over this
service with the default (FIFO-equivalent) policy — bit-identical to the
pre-service scheduler path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.pim_config import PimConfig
from repro.pimsys.scheduler import (
    DEFAULT_POLICY,
    GANG_JOBS,
    QOS_CLASSES,
    STATUS_REJECTED,
    SchedulerResult,
    ServicePolicy,
    ServiceRequest,
    job_rows,
    poisson_arrivals_ns,
)
from repro.pimsys.topology import DeviceTopology


# --------------------------------------------------------------------------
# Per-request results
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServedRequest:
    """One request's resolved outcome, in simulated microseconds.

    `status` is ``"completed"`` or ``"rejected"`` (admission control);
    rejected requests carry NaN dispatch/done/latency and never touched
    the device.  `met_deadline` is None when no deadline was given.
    `batched` marks members of a coalesced gang issue.  `epoch` is the
    flush that resolved the request — each epoch simulates on a fresh
    device timeline starting at t=0, so timestamps compare only within
    one epoch.
    """

    index: int
    epoch: int
    job: object
    qos: str
    status: str
    arrival_us: float
    dispatch_us: float
    done_us: float
    latency_us: float
    deadline_us: float | None
    met_deadline: bool | None
    batched: bool

    @property
    def ok(self) -> bool:
        return self.status == "completed"


class PimFuture:
    """Handle to one submitted request, resolved in simulated time.

    `result()` forces the owning epoch (simulating every request
    submitted so far) the first time it is called; afterwards it is a
    plain lookup.  A rejected request resolves normally with
    `status == "rejected"` — admission control is an expected outcome
    of the policy, not an error.
    """

    __slots__ = ("_service", "_index", "_record")

    def __init__(self, service: "DeviceService", index: int):
        self._service = service
        self._index = index
        self._record: ServedRequest | None = None

    def done(self) -> bool:
        return self._record is not None

    def result(self) -> ServedRequest:
        if self._record is None:
            self._service.flush()
        if self._record is None:  # pragma: no cover - flush resolves it
            raise RuntimeError("future did not resolve on flush")
        return self._record

    @property
    def latency_us(self) -> float:
        return self.result().latency_us

    def __repr__(self) -> str:
        state = self._record.status if self._record else "pending"
        return f"PimFuture(index={self._index}, {state})"


@dataclasses.dataclass(frozen=True)
class _Submission:
    index: int
    job: object
    qos: str
    deadline_ns: float | None
    arrival_ns: float
    future: PimFuture
    plan: object  # CompiledPlan | None (sharded plans prime differently)


# --------------------------------------------------------------------------
# The service
# --------------------------------------------------------------------------


class DeviceService:
    """Asynchronous serving façade over one PIM device.

    Wraps a `PimSession` (or builds one from `cfg`): plans compile once
    through the session's memoized cache, the session's persistent
    `RequestScheduler` keeps its command/gang caches warm, and every
    epoch simulates on a fresh device timeline, so results depend only
    on the submissions and seeds — never on service history.

    `policy` is the dispatch `ServicePolicy` (QoS weights, admission
    control, batching window); `seed` is the default arrival-trace seed
    recorded on every epoch's `SchedulerResult`.
    """

    def __init__(self, session=None, *, cfg: PimConfig | None = None,
                 topo: DeviceTopology | None = None,
                 policy: ServicePolicy | None = None,
                 bus_policy: str = "rr", pipelined: bool = True,
                 seed: int = 0):
        if session is None:
            from repro.pimsys.session import PimSession

            session = PimSession(cfg, topo=topo, policy=bus_policy,
                                 pipelined=pipelined)
        elif cfg is not None or topo is not None:
            raise ValueError("pass either a session or cfg/topo, not both")
        self.session = session
        self.policy = policy if policy is not None else DEFAULT_POLICY
        self.seed = seed
        self._pending: list[_Submission] = []
        self._epoch_seeds: list[int] = []
        self._results: list[SchedulerResult] = []
        self._count = 0
        self._epoch = 0  # monotonic: counts every flush, retained or not

    # -- submission ----------------------------------------------------------
    def submit(self, plan, *, qos: str = "throughput",
               deadline_us: float | None = None,
               at_us: float = 0.0) -> PimFuture:
        """Submit one request; returns an unresolved `PimFuture`.

        `plan` is a `CompiledPlan` or an op spec (compiled through the
        session cache).  `at_us` is the request's simulated arrival in
        the current epoch (default 0.0 = a closed-loop submission);
        `deadline_us` an SLO relative to arrival, `qos` one of
        ``latency`` / ``throughput``.
        """
        return self._enqueue(plan, qos, deadline_us, at_us * 1e3)

    def submit_poisson(self, plan, count: int, rate_per_us: float, *,
                       qos: str = "throughput",
                       deadline_us: float | None = None,
                       seed: int | None = None,
                       start_us: float = 0.0) -> list[PimFuture]:
        """Submit `count` open-loop Poisson arrivals at `rate_per_us`.

        The arrival trace derives from `seed` (default: the service
        seed) and is recorded on the epoch's `SchedulerResult` — rerun
        with the same seeds and the results are byte-identical.
        """
        if count < 1:
            raise ValueError("count must be >= 1")
        if rate_per_us <= 0:
            raise ValueError("rate_per_us must be positive")
        seed = self.seed if seed is None else seed
        if seed not in self._epoch_seeds:
            self._epoch_seeds.append(seed)
        # the ONE arrival-trace formula (shared with run_open_loop —
        # the FIFO-parity guarantee depends on it)
        arrivals = start_us * 1e3 + poisson_arrivals_ns(seed, count,
                                                        rate_per_us)
        return [self._enqueue(plan, qos, deadline_us, float(t))
                for t in arrivals.tolist()]

    def submit_mixed_poisson(self, plan, count: int, rate_per_us: float, *,
                             latency_frac: float = 0.25,
                             deadline_us: float | None = None,
                             seed_throughput: int = 0,
                             seed_latency: int = 1) -> list[PimFuture]:
        """Submit a mixed-class open-loop trace: `latency_frac` of
        `count` as `latency`-class arrivals (with `deadline_us`), the
        rest `throughput`-class, the offered `rate_per_us` split
        proportionally, each class on its own seed.  The one definition
        of the mix convention the benchmarks and examples share.
        """
        if not 0.0 <= latency_frac <= 1.0:
            raise ValueError("latency_frac must be in [0, 1]")
        n_lat = int(round(count * latency_frac))
        n_tput = count - n_lat
        futs: list[PimFuture] = []
        if n_tput:
            futs += self.submit_poisson(
                plan, n_tput, rate_per_us * (1 - latency_frac),
                qos="throughput", seed=seed_throughput)
        if n_lat:
            futs += self.submit_poisson(
                plan, n_lat, rate_per_us * latency_frac, qos="latency",
                deadline_us=deadline_us, seed=seed_latency)
        return futs

    def _enqueue(self, plan, qos, deadline_us, arrival_ns) -> PimFuture:
        from repro.pimsys.session import BatchOp, CompiledPlan

        if not isinstance(plan, CompiledPlan):
            plan = self.session.compile(plan)
        if plan.cfg != self.session.cfg:
            raise ValueError("plan was compiled for a different PimConfig")
        if isinstance(plan.op, BatchOp):
            raise TypeError("submit BatchOp plans one request at a time; "
                            "the service owns the batching")
        if qos not in QOS_CLASSES:
            raise ValueError(f"qos must be one of {QOS_CLASSES}, got {qos!r}")
        if arrival_ns < 0:
            raise ValueError("arrival (at_us/start_us) must be >= 0")
        if deadline_us is not None and deadline_us <= 0:
            raise ValueError("deadline_us must be positive (or None)")
        job = plan.job()
        # validate NOW, not at flush: a bad submission must fail alone,
        # not poison the whole epoch's pending futures (sharded plans
        # already validate at compile time; other gang jobs validate
        # their declared bank/row needs against this device)
        if isinstance(job, GANG_JOBS):
            self.session.scheduler()._validate_gang(job)
        elif job_rows(plan.cfg, job) > plan.cfg.rows_per_bank:
            raise ValueError(f"{job} does not fit in one bank")
        fut = PimFuture(self, self._count)
        deadline_ns = None if deadline_us is None else deadline_us * 1e3
        self._pending.append(_Submission(
            self._count, job, qos, deadline_ns, arrival_ns, fut, plan))
        self._count += 1
        return fut

    # -- epoch execution -----------------------------------------------------
    def flush(self, retain: bool = True) -> SchedulerResult:
        """Simulate the current epoch and resolve its futures.

        Returns the epoch's `SchedulerResult`, kept in `results` unless
        `retain=False` (long-lived callers that consume the result
        immediately — e.g. the `PimSession.submit` shim — opt out so
        the history does not grow unboundedly).  Raises if nothing is
        pending.  With `ServicePolicy.telemetry` on, the result carries
        a `telemetry` handle with the epoch's full timeline (request
        lifecycle spans tagged by submission index = the futures' join
        key) and its stats a `timeseries` summary block.
        """
        if not self._pending:
            raise RuntimeError("nothing submitted since the last flush")
        pending, self._pending = self._pending, []
        seeds, self._epoch_seeds = self._epoch_seeds, []
        try:
            sched = self.session.scheduler()
            primed = set()
            for sub in pending:
                if sub.job not in primed:
                    primed.add(sub.job)
                    sub.plan.prime_scheduler(sched)
            reqs = [ServiceRequest(sub.arrival_ns, sub.job, qos=sub.qos,
                                   deadline_ns=sub.deadline_ns)
                    for sub in pending]
            if not seeds:
                seed: int | list | None = self.seed
            elif len(seeds) == 1:
                seed = seeds[0]
            else:
                seed = list(seeds)
            res = sched.run_service(reqs, policy=self.policy, seed=seed)
        except BaseException:
            # a failed epoch must not orphan its futures: restore the
            # submissions so the caller can retry or inspect them
            self._pending = pending + self._pending
            self._epoch_seeds = seeds + self._epoch_seeds
            raise
        epoch = self._epoch
        self._epoch += 1
        if retain:
            self._results.append(res)
        self._resolve(pending, res, epoch)
        return res

    def _resolve(self, pending: Sequence[_Submission],
                 res: SchedulerResult, epoch: int) -> None:
        row_of = {int(s): row for row, s in enumerate(res.request_ids)}
        base = pending[0].index
        for sub in pending:
            row = row_of[sub.index - base]
            rejected = res.status[row] == STATUS_REJECTED
            arrival = float(res.arrivals_ns[row])
            done = float(res.done_ns[row])
            deadline = sub.deadline_ns
            met = None
            if deadline is not None and not rejected:
                met = bool(done - arrival <= deadline)
            sub.future._record = ServedRequest(
                index=sub.index,
                epoch=epoch,
                job=sub.job,
                qos=sub.qos,
                status="rejected" if rejected else "completed",
                arrival_us=arrival / 1e3,
                dispatch_us=float(res.dispatch_ns[row]) / 1e3,
                done_us=done / 1e3,
                latency_us=(done - arrival) / 1e3,
                deadline_us=None if deadline is None else deadline / 1e3,
                met_deadline=met,
                batched=bool(res.batched[row]),
            )

    # -- composition ---------------------------------------------------------
    def gather(self, futures: Iterable[PimFuture]) -> list[ServedRequest]:
        """Resolve `futures` (flushing if needed), in submission order."""
        return [f.result() for f in futures]

    def as_completed(self, futures: Iterable[PimFuture]):
        """Yield `futures` in simulated completion order.

        Epochs simulate on independent timelines (each flush restarts
        the device clock at t=0), so futures order by epoch first, then
        within an epoch by simulated done time (ties by submission
        order); an epoch's rejected requests follow its completed ones,
        in arrival order — they never complete, but a caller iterating
        the epoch must still observe them.
        """
        futures = list(futures)
        for f in futures:
            f.result()
        def key(f: PimFuture):
            r = f._record
            if r.status == "completed":
                return (r.epoch, 0, r.done_us, r.index)
            return (r.epoch, 1, r.arrival_us, r.index)
        return iter(sorted(futures, key=key))

    # -- results -------------------------------------------------------------
    @property
    def results(self) -> list[SchedulerResult]:
        """Every flushed epoch's `SchedulerResult`, oldest first."""
        return list(self._results)

    def result(self, epoch: int = -1) -> SchedulerResult:
        """One epoch's `SchedulerResult` (default: the latest), flushing
        the current epoch first if it has pending submissions."""
        if self._pending:
            self.flush()
        if not self._results:
            raise RuntimeError("no epoch has run yet")
        return self._results[epoch]

    def pending(self) -> int:
        return len(self._pending)
