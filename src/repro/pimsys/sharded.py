"""Shard ONE large NTT across many banks and channels (`ShardedNttPlan`).

The paper pipelines butterfly stages inside one bank; the ROADMAP's next
system-level step is the opposite axis: split a single size-N NTT over
B = banks x channels banks so the inter-bank butterfly stages cross the
channel boundary.  We use the four-step (Cooley-Tukey column/row)
decomposition specialized to the row-centric command stream:

  view the coefficient vector as a (B x M) matrix, M = N/B, row b living
  contiguously in bank b.  The stage set {1, ..., N/2} splits exactly at
  stride M:

  * strides t < M   -- the "row NTTs": a full size-M sub-NTT local to
    each bank.  Emitted as an unmodified `RowCentricMapper` stream with
    `twiddle_base = b*M`, which shifts the (w0, r_w) parameters so the
    local pass resolves the *global* table (the four-step twiddle
    correction is absorbed into the shifted bases; no extra passes).
  * strides t >= M  -- the "column NTTs": log2(B) cross-bank stages.
    Bank b pairs with bank b + t/M and -- because a whole bank spans
    less than half a butterfly block at these strides -- the pair shares
    ONE twiddle: the exchange moves twiddle-scaled columns wholesale.
    Each atom crosses the per-channel shared bus as a paired
    ColRead (source bank) / ColWrite-burst (target bank) transaction
    (`ChannelController.occupy_bus`); pairs that straddle channels hold
    both buses and pay `channel_hop_cycles` extra latency.

Execution order: inverse/GS (the paper orientation) runs the local pass
first, then the exchange stages; forward/CT mirrors (exchange first,
local pass second).  A forward+inverse pipeline (NTT -> INTT round trip)
is therefore the classic four-step sandwich local/exchange/.../local.

At banks=1 the plan degenerates to the single `RowCentricMapper` stream
-- command-list identical, and (through the one-bank controller) timed
bit-identically to `BankTimer`; `tests/test_sharded.py` asserts both.

Timing is a thin driver of the hierarchical resource engine
(`repro.pimsys.engine`) end to end: phase A(/B) local streams run
through `DeviceEngine` (per-channel bus arbitration -> rank windows ->
`BankEngine` hazards), and the exchange phase issues genuine
Act/ColRead/C2/ColWrite commands into the SAME engines via
`issue_direct` -- butterfly compute happens on the u-bank's CU, hazards,
refresh, and rank tFAW/turnaround windows included -- with the
inter-bank burst modeled as shared-bus occupancy (`DeviceEngine.burst`).
The device-side twiddle-parameter cache reaches both phases: local
streams replay their plan-level residency traces
(`local_param_traces`), which then seed the exchange phase's per-bank
LRU walk (`exchange_param_charges`) — one cache per bank, threaded
across the phase boundary, so exchange C2s hit after the first atom of
each pair (one shared twiddle per pair).  Functional execution
(`run_functional`, surfaced as `core.polymul.pim_ntt_sharded`) drives
one `FunctionalBank` per bank and is asserted bit-equal to `core.ntt`.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core import ntt as ntt_ref
from repro.core.mapping import (
    Act,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    FunctionalBank,
    RowCentricMapper,
    twiddle_index,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import BankEngine, TimingResult, _time_ntt
from repro.pimsys.controller import ChannelController, Device
from repro.pimsys.engine import (
    PARAM_OPS,
    param_beat_trace,
    param_hit_beats,
    param_program_key,
    trace_param_beats,
)
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import DeviceTopology

_INF_F = math.inf


# --------------------------------------------------------------------------
# Plan structure
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExchangePair:
    """One cross-bank butterfly: u-bank pairs with v-bank at `stride`.

    `tw_index` is the (single) global twiddle-table index the whole pair
    shares -- at stride t >= M a bank spans less than half a 2t-block,
    so the MC programs one (w0, r_w) per pair and stage.
    """

    u: int       # sub-NTT index of the u operand (holds words [u*M, u*M+M))
    v: int       # sub-NTT index of the v operand
    stride: int  # butterfly stride in global words (a multiple of M)
    tw_index: int


@dataclasses.dataclass(frozen=True)
class ExchangeStage:
    stride: int
    pairs: tuple[ExchangePair, ...]


@dataclasses.dataclass(frozen=True)
class ExchangeStageSpan:
    """Timing breakdown of one executed exchange stage.

    `occupancy` is bus-busy over (used channels x span); `overlap` is
    the fraction of summed per-pair work hidden by cross-pair
    pipelining (0.0 = pairs ran strictly one after another, ->1.0 =
    fully concurrent).  Both come from the live engine run, so the
    knee is attributable from a committed benchmark artifact alone.
    """

    stride: int
    begin_ns: float
    end_ns: float
    busy_ns: float       # summed channel-bus busy accrued during the stage
    pairs: int
    channels: int        # distinct channels the stage's pairs touch
    occupancy: float
    overlap: float

    @property
    def span_ns(self) -> float:
        return self.end_ns - self.begin_ns


@dataclasses.dataclass
class ShardedTimingResult:
    """Cycle-level timing of one sharded NTT (see `ShardedNttPlan.simulate`)."""

    n: int
    banks: int
    latency_ns: float
    local_ns: float          # local-pass phase span (bus-arbitrated)
    exchange_ns: float       # exchange activity window: earliest pair
    #                          barrier -> last write (0.0 at banks=1).
    #                          Overlaps the local tail under skewed
    #                          placements, so local_ns + exchange_ns can
    #                          exceed latency_ns.
    single_ns: float         # one-bank BankTimer baseline for the same N
    analytic_local_ns: float  # per-channel bus lower bound on the local pass
    exchange_bus_occupancy: float  # busy/span over channels during exchange
    xfer_atoms: int
    xfer_hops: int           # atoms that crossed a channel boundary
    stats: StatsRegistry
    stage_breakdown: tuple[ExchangeStageSpan, ...] = ()

    @property
    def speedup(self) -> float:
        return self.single_ns / self.latency_ns if self.latency_ns else 0.0

    @property
    def efficiency(self) -> float:
        return self.speedup / self.banks


def conflict_aware_flat_banks(topo: DeviceTopology,
                              pool: Sequence[int]) -> tuple[int, ...]:
    """Bank-conflict-aware shard placement over `pool`.

    Exchange partners at stride M<<i differ in exactly bit i of the
    sub-NTT index, so placing sub-index b on a bank of channel
    XOR-fold(b) (index bit i folded onto channel bit i mod log2(C),
    every fold column nonzero) guarantees partners sit on DISTINCT
    channels at EVERY stage: each single-bit flip changes the target
    channel.  The default channel-interleaved identity only achieves
    this for the low log2(C) stages — the high-stride stages fight over
    one bus, which is the measured multi-bank efficiency knee.

    Returns a permutation of `pool` (sub-index -> flat bank id).  Falls
    back to pool order when the device has one channel or a
    non-power-of-two shape (the fold is undefined), and to the fullest
    remaining channel bucket when the pool is channel-skewed (e.g. a
    scheduler gang reserved on whatever banks were free).
    """
    pool = list(pool)
    nbanks = len(pool)
    chans = topo.channels
    if (chans <= 1 or chans & (chans - 1)
            or nbanks & (nbanks - 1) or nbanks <= 1):
        return tuple(pool)
    cb = chans.bit_length() - 1
    buckets: dict[int, list[int]] = {}
    for f in pool:
        buckets.setdefault(topo.address_of(f).channel, []).append(f)
    out = []
    for b in range(nbanks):
        want, bits, i = 0, b, 0
        while bits:
            if bits & 1:
                want ^= 1 << (i % cb)
            bits >>= 1
            i += 1
        bucket = buckets.get(want)
        if not bucket:
            want = min(buckets, key=lambda c: (-len(buckets[c]), c))
            bucket = buckets[want]
        out.append(bucket.pop(0))
        if not bucket:
            del buckets[want]
    return tuple(out)


class ShardedNttPlan:
    """Four-step command plan for one size-n NTT over `banks` banks.

    Sub-NTT index b (bank b's N/B-point slice) maps to topology flat bank
    id b -- channel-interleaved by `DeviceTopology`, so consecutive
    shards land on different channels and exchange partners at small
    strides sit across the channel boundary (the inter-channel hops the
    benchmark sweeps measure).
    """

    def __init__(self, cfg: PimConfig, n: int, banks: int, forward: bool = False,
                 topo: DeviceTopology | None = None,
                 flat_banks: Sequence[int] | None = None,
                 placement: str = "identity"):
        if n & (n - 1) or n <= 0:
            raise ValueError("n must be a power of two")
        if banks & (banks - 1) or banks <= 0:
            raise ValueError("banks must be a power of two")
        if n % banks:
            raise ValueError(f"banks={banks} does not divide n={n}")
        self.cfg = cfg
        self.n = n
        self.banks = banks
        self.forward = forward
        self.m = n // banks  # words per bank (the local sub-NTT size)
        if self.m < cfg.atom_words:
            raise ValueError(
                f"n/banks = {self.m} is below one atom ({cfg.atom_words} words)")
        rows_needed = max(1, self.m // cfg.row_words)
        if rows_needed > cfg.rows_per_bank:
            raise ValueError(
                f"a {self.m}-word shard needs {rows_needed} rows; a bank "
                f"has {cfg.rows_per_bank}")
        if banks > 1 and cfg.num_buffers < 2:
            raise ValueError("the exchange phase needs num_buffers >= 2")
        if topo is None:
            topo = DeviceTopology.from_config(cfg)
            if topo.total_banks < banks:
                # grow the default device to fit the plan (keeps the
                # functional API usable with the paper's 1-bank config);
                # an explicitly passed topology is never resized
                per_ch = -(-banks // (topo.channels * topo.ranks))  # ceil
                topo = DeviceTopology(channels=topo.channels, ranks=topo.ranks,
                                      banks_per_rank=per_ch)
        elif topo.total_banks < banks:
            raise ValueError(
                f"topology {topo.describe()} has fewer than {banks} banks")
        self.topo = topo
        if placement not in ("identity", "conflict"):
            raise ValueError(
                f"placement must be 'identity' or 'conflict', got {placement!r}")
        self.placement = placement
        # Sub-NTT index -> physical flat bank id.  The default identity
        # placement channel-interleaves shards; the scheduler passes the
        # gang it actually reserved.  `placement="conflict"` permutes
        # the pool so exchange partners always straddle channels
        # (`conflict_aware_flat_banks`).
        pool = tuple(flat_banks) if flat_banks is not None else tuple(range(banks))
        if placement == "conflict":
            pool = conflict_aware_flat_banks(self.topo, pool)
        self.flat_banks = pool
        if len(self.flat_banks) != banks or len(set(self.flat_banks)) != banks:
            raise ValueError(f"flat_banks must be {banks} distinct bank ids")
        for f in self.flat_banks:
            self.topo.address_of(f)  # range check
        self._local_streams: list[list[Command]] | None = None
        self._exchange_stages: list[ExchangeStage] | None = None
        self._local_traces: list | None = None
        self._exchange_charges: list | None = None

    # -- command-level structure --------------------------------------------
    def local_streams(self) -> list[list[Command]]:
        """Per-bank size-M Mapper streams with shifted twiddle bases.

        At banks=1 this is exactly `RowCentricMapper(cfg, n).commands()`
        -- command-list equality, the differential anchor of the plan.
        Cached: simulate() and the analytic bound both walk the streams.
        """
        if self._local_streams is None:
            self._local_streams = [
                RowCentricMapper(self.cfg, self.m, forward=self.forward,
                                 twiddle_base=b * self.m).commands()
                for b in range(self.banks)
            ]
        return self._local_streams

    def local_param_traces(self) -> list:
        """Per-bank `engine.param_beat_trace` residency traces (the
        device-side twiddle-parameter cache model), resolved against the
        GLOBAL transform size through the shifted twiddle bases.  Cached
        like `local_streams`: every simulate() replays one precomputed
        trace ([None]*banks when the cache is disabled)."""
        if self._local_traces is None:
            self._local_traces = [
                param_beat_trace(self.cfg, self.n, s)
                for s in self.local_streams()
            ]
        return self._local_traces

    def exchange_stages(self) -> list[ExchangeStage]:
        """Cross-bank stages, in execution order for this orientation.

        Cached (like `local_streams`): the stage set and its shared
        twiddle indices are pure functions of (n, banks, orientation), so
        repeated `simulate`/`run_functional` calls replay one schedule.
        """
        if self._exchange_stages is not None:
            return self._exchange_stages
        strides = [self.m << i for i in range(int(math.log2(self.banks)))]
        if self.forward:
            strides = strides[::-1]  # CT: large strides first
        stages = []
        for t in strides:
            tb = t // self.m  # stride in banks
            pairs = tuple(
                ExchangePair(u=b, v=b + tb, stride=t,
                             tw_index=twiddle_index(self.n, t, b * self.m))
                for b in range(self.banks)
                if (b // tb) % 2 == 0
            )
            stages.append(ExchangeStage(stride=t, pairs=pairs))
        self._exchange_stages = stages
        return stages

    def exchange_param_charges(self) -> list[tuple]:
        """Per-(stage, pair) parameter-cache charges for the exchange C2s.

        The device-side (w0, r_w) cache is ONE per bank: residency the
        local pass leaves behind is what the exchange phase walks into.
        This threads the plan-level LRU across the phase boundary — the
        same per-bank LRU `local_param_traces` resolves seeds the
        exchange lookups (GS runs local first; CT runs the exchange on
        cold caches, which the empty seed models exactly).

        Every atom of a pair shares ONE program (the pair's single
        twiddle) and program keys are unique per (stage, pair), so with
        any cache (entries >= 1) the outcome is a full load on the
        pair's first butterfly and a one-beat re-select after —
        `tests/test_sharded.py` pins this closed form against the LRU
        walk, which is why threading residency does not perturb any
        committed benchmark number: the key spaces of the two phases
        are disjoint (local strides < M, exchange strides >= M resolve
        different twiddle indices).

        Returns, per stage, a tuple of per-pair
        `(first_ns, first_code, rest_ns, rest_code)` charges; all-None
        charges when the cache is disabled.
        """
        if self._exchange_charges is not None:
            return self._exchange_charges
        cfg = self.cfg
        entries = cfg.param_cache_entries
        stages = self.exchange_stages()
        if not entries:
            cold = (None, 0, None, 0)
            self._exchange_charges = [tuple(cold for _ in st.pairs)
                                      for st in stages]
            return self._exchange_charges
        full_ns = cfg.param_load_cycles * cfg.dram_ns
        hit_ns = param_hit_beats(cfg) * cfg.dram_ns
        lru: list[OrderedDict] = [OrderedDict() for _ in range(self.banks)]
        if not self.forward:  # GS: the local pass has run when we arrive
            for b, cmds in enumerate(self.local_streams()):
                cache = lru[b]
                for c in cmds:
                    if c.__class__ not in PARAM_OPS:
                        continue
                    key = param_program_key(cfg, self.n, c)
                    if key in cache:
                        cache.move_to_end(key)
                    else:
                        cache[key] = True
                        if len(cache) > entries:
                            cache.popitem(last=False)
        charges = []
        for stage in stages:
            row = []
            for p in stage.pairs:
                probe = C2((0,), (1,), (p.u * self.m,), p.stride,
                           gs=not self.forward)
                key = param_program_key(cfg, self.n, probe)
                cache = lru[p.u]
                if key in cache:
                    cache.move_to_end(key)
                    first = (hit_ns, 2)
                else:
                    cache[key] = True
                    if len(cache) > entries:
                        cache.popitem(last=False)
                    first = (full_ns, 1)
                row.append((first[0], first[1], hit_ns, 2))
            charges.append(tuple(row))
        self._exchange_charges = charges
        return charges

    def trace_streams(self) -> dict[tuple[int, int], list[Command]]:
        """Local-pass streams keyed by (channel, bank-in-channel).

        This is the `pimsys.trace`-dumpable command-level artifact of the
        plan: the exchange phase is a bus/topology schedule (not bank
        program text) and is regenerated deterministically from
        `exchange_stages()` at replay time.
        """
        out: dict[tuple[int, int], list[Command]] = {}
        for b, cmds in enumerate(self.local_streams()):
            addr = self.topo.address_of(self.flat_banks[b])
            out[(addr.channel, self.topo.local_id(addr))] = cmds
        return out

    # -- functional execution -----------------------------------------------
    def run_functional(self, a: np.ndarray, ctx: ntt_ref.NttContext) -> np.ndarray:
        """Bit-exact execution on one `FunctionalBank` per bank.

        The exchange stages apply the shared-twiddle vector butterfly to
        whole bank images -- functionally identical to streaming the
        atoms through the u-bank's CU, which is what `simulate` times.
        """
        if a.shape[0] != self.n:
            raise ValueError(f"input length {a.shape[0]} != n={self.n}")
        if ctx.n != self.n:
            raise ValueError(f"context is for n={ctx.n}, plan is n={self.n}")
        q = ctx.q
        table = ctx.psi_brv if self.forward else ctx.psi_inv_brv
        # Size the memory image to the shard, not the device (a 32-bank
        # plan would otherwise allocate 32 full bank images).
        rows = max(1, self.m // self.cfg.row_words)
        small = self.cfg.with_(rows_per_bank=rows)
        fbanks = []
        for b in range(self.banks):
            fb = FunctionalBank(small, ctx, forward=self.forward)
            fb.load_poly(np.asarray(a[b * self.m:(b + 1) * self.m], np.uint32))
            fbanks.append(fb)

        def local_pass():
            for fb, cmds in zip(fbanks, self.local_streams()):
                fb.run(cmds)

        def exchange():
            for stage in self.exchange_stages():
                for p in stage.pairs:
                    u = fbanks[p.u].read_poly(self.m).astype(np.int64)
                    v = fbanks[p.v].read_poly(self.m).astype(np.int64)
                    w = int(table[p.tw_index])
                    if self.forward:  # CT: (u + w*v, u - w*v)
                        wv = v * w % q
                        nu, nv = (u + wv) % q, (u - wv) % q
                    else:  # GS: (u + v, (u - v)*w)
                        nu, nv = (u + v) % q, (u - v) * w % q
                    fbanks[p.u].load_poly(nu.astype(np.uint32))
                    fbanks[p.v].load_poly(nv.astype(np.uint32))

        if self.forward:
            exchange()
            local_pass()
        else:
            local_pass()
            exchange()
        return np.concatenate([fb.read_poly(self.m) for fb in fbanks])

    # -- timing ---------------------------------------------------------------
    def analytic_local_bound(self) -> float:
        """Per-channel shared-bus lower bound on the local pass.

        The channel bus serializes its banks' command+parameter traffic;
        the pass cannot finish before the busiest channel drains, nor
        before a lone sub-NTT would on a private bus.  Parameter beats
        come from each stream's cache-residency trace when the
        device-side parameter cache is enabled (the engine charges
        exactly those beats, so the bound stays a bound)."""
        cfg = self.cfg
        per_channel: dict[int, float] = {}
        traces = self.local_param_traces()
        for b, cmds in enumerate(self.local_streams()):
            n_cmds = sum(1 for c in cmds if not isinstance(c, Mark))
            cu = sum(1 for c in cmds if isinstance(c, (C1, C2, CMul)))
            bus_ns = (n_cmds + trace_param_beats(cfg, traces[b], cu)) * cfg.dram_ns
            ch = self.topo.address_of(self.flat_banks[b]).channel
            per_channel[ch] = per_channel.get(ch, 0.0) + bus_ns
        return max(per_channel.values(), default=0.0)

    def _port(self, dev: Device, sub: int) -> tuple[ChannelController, int]:
        addr = self.topo.address_of(self.flat_banks[sub])
        return dev.channels[addr.channel], self.topo.local_id(addr)

    def _engine(self, dev: Device, sub: int) -> tuple[ChannelController, BankEngine]:
        ctrl, local = self._port(dev, sub)
        return ctrl, ctrl.engines[local]

    def _issue(self, dev: Device, sub: int, cmd: Command, not_before: float = 0.0,
               param_ns: float | None = None, code: int = 0):
        """Issue one exchange-phase command through the bank's real engine,
        holding its channel's shared bus (and rank windows) exactly as
        the arbiter would."""
        ctrl, local = self._port(dev, sub)
        return ctrl.issue_direct(local, cmd, not_before, param_ns=param_ns,
                                 code=code)

    def _open(self, dev: Device, sub: int, row: int, not_before: float = 0.0) -> float:
        _, eng = self._engine(dev, sub)
        if eng.open_row != row:
            _, done = self._issue(dev, sub, Act(row), not_before)
            return done
        return not_before

    def _transfer(self, dev: Device, src: int, dst: int, earliest: float) -> float:
        """Move one atom src-bank -> dst-bank buffer over the shared bus
        (`DeviceEngine.burst`: same-channel = one bus hold, cross-channel
        = both buses held + hop latency).  Returns the arrival time at
        the destination buffer."""
        ch_s = self.topo.address_of(self.flat_banks[src]).channel
        ch_d = self.topo.address_of(self.flat_banks[dst]).channel
        if ch_s != ch_d:
            self._xfer_hops += 1
        return dev.burst(ch_s, ch_d, earliest)

    def _pair_chain(self, dev: Device, p: ExchangePair, t0: float,
                    charge: tuple, ready: list[float],
                    ends: list[float], idx: int):
        """The full atom-chain of one exchange pair, as a generator.

        Per atom: ColRead on v, burst v->u, ColRead of u's own atom, C2
        on u's CU (one shared twiddle per pair => one (w0, r_w) stream),
        ColWrite of u', burst u->v of v', ColWrite on v.

        Each `yield` publishes the earliest time the NEXT bus-occupying
        step could actually start (`ChannelEngine.earliest_issue`: bank
        hazards + rank gates, or the burst's data-ready edge).  The
        pipelined driver pops the globally soonest step across all live
        chains, so a command stalled on a data hazard never parks its
        channel bus ahead of a neighbor pair's ready work; the serial
        driver simply exhausts one chain at a time, reproducing the
        strictly ordered pre-pipelining schedule command for command.
        Interleaving is safe because a bank belongs to exactly one pair
        per stage: per-bank command order is unchanged, only the bus
        grant order moves, and the engines enforce every hazard either
        way.

        On exhaustion the chain publishes its completion into
        `ready[p.u]/ready[p.v]` and `ends[idx]` (pairs within a stage
        are bank-disjoint, so mid-stage updates cannot be observed by
        a concurrent chain).
        """
        cfg = self.cfg
        Na, R = cfg.atom_words, cfg.row_words
        slots = max(1, cfg.num_buffers // 2)
        ctrl_u, local_u = self._port(dev, p.u)
        ctrl_v, local_v = self._port(dev, p.v)
        eng_u = ctrl_u.engines[local_u]
        eng_v = ctrl_v.engines[local_v]
        pn0, code0, pn1, code1 = charge
        done_u = done_v = t0
        for a in range(self.m // Na):
            w0 = a * Na
            row, atom = w0 // R, (w0 % R) // Na
            slot = a % slots
            bu_loc, bu_recv = 2 * slot, 2 * slot + 1
            bv_send, bv_recv = 2 * slot, 2 * slot + 1
            # v reads its atom and bursts it to u's spare buffer
            rd_v = ColRead(row, atom, bv_send)
            if eng_v.open_row != row:
                yield ctrl_v.earliest_issue(local_v, Act(row), t0)
            else:
                yield ctrl_v.earliest_issue(local_v, rd_v, t0)
            t = self._open(dev, p.v, row, t0)
            _, v_read = self._issue(dev, p.v, rd_v, t)
            yield max(v_read, eng_u.buf_free[bu_recv])
            arrive_u = self._transfer(
                dev, p.v, p.u, max(v_read, eng_u.buf_free[bu_recv]))
            eng_u.data_ready[bu_recv] = arrive_u
            # the burst consumes bv_send: WAR for the next read
            eng_v.buf_free[bv_send] = max(eng_v.buf_free[bv_send], arrive_u)
            self._xfer_atoms += 1
            # u reads its own atom and runs the butterfly on its CU
            rd_u = ColRead(row, atom, bu_loc)
            if eng_u.open_row != row:
                yield ctrl_u.earliest_issue(local_u, Act(row), t0)
            else:
                yield ctrl_u.earliest_issue(local_u, rd_u, t0)
            t = self._open(dev, p.u, row, t0)
            self._issue(dev, p.u, rd_u, t)
            base = p.u * self.m + w0
            c2 = C2((bu_loc,), (bu_recv,), (base,), p.stride,
                    gs=not self.forward)
            pn, code = (pn0, code0) if a == 0 else (pn1, code1)
            yield ctrl_u.earliest_issue(local_u, c2, param_ns=pn)
            _, c2_done = self._issue(dev, p.u, c2, param_ns=pn, code=code)
            wr_u = ColWrite(row, atom, bu_loc)
            yield c2_done
            _, u_wr = self._issue(dev, p.u, wr_u)
            done_u = max(done_u, u_wr)
            # v' bursts back and is written on v
            yield max(c2_done, eng_v.buf_free[bv_recv])
            arrive_v = self._transfer(
                dev, p.u, p.v, max(c2_done, eng_v.buf_free[bv_recv]))
            eng_u.buf_free[bu_recv] = max(eng_u.buf_free[bu_recv], arrive_v)
            eng_v.data_ready[bv_recv] = arrive_v
            self._xfer_atoms += 1
            yield arrive_v
            _, v_wr = self._issue(dev, p.v, ColWrite(row, atom, bv_recv))
            done_v = max(done_v, v_wr)
        ready[p.u], ready[p.v] = done_u, done_v
        ends[idx] = done_u if done_u > done_v else done_v

    def _run_exchange(self, dev: Device, ready: list[float],
                      pipelined: bool = True
                      ) -> tuple[float | None, tuple[ExchangeStageSpan, ...]]:
        """Issue every exchange stage into the live engines.

        `ready[b]` carries each sub-NTT's data-complete time in and out.

        With `pipelined` (and the double-buffering the plan already
        requires, `num_buffers >= 2`), the pairs of a stage run as
        interleaved chains through a single earliest-step event loop:
        pair k+1's reads issue while pair k's writes drain, which is
        the paper's Nb-buffer pipelining applied one level up, to the
        channel-bus schedule.  `pipelined=False` exhausts one pair at a
        time — bit-identical to the historical strictly serial
        exchange.  Stages stay barriers either way (stage s+1's pairs
        consume both partners' stage-s outputs).

        Parameter-cache charges come from `exchange_param_charges()`,
        which threads each bank's LRU residency across the local ->
        exchange phase boundary.

        Returns `(x_start, stage_breakdown)`: the exchange activity
        START — the earliest first-stage pair barrier, which every
        exchange grant is at or after (pairs on lightly loaded channels
        begin exchanging before the slowest bank's local pass ends, so
        this can precede max(ready)-at-entry; the occupancy window must
        open here, not at the global phase boundary) — and one
        `ExchangeStageSpan` per executed stage.
        """
        x_start: float | None = None
        tr = dev.tracer
        charges = self.exchange_param_charges()
        stages = self.exchange_stages()
        nstages = len(stages)
        # per-stage accounting shared by both drivers
        t0s: list[list[float]] = [[0.0] * len(st.pairs) for st in stages]
        ends: list[list[float]] = [[0.0] * len(st.pairs) for st in stages]
        busy: list[float] = [0.0] * nstages

        if pipelined and self.cfg.num_buffers >= 2:
            # One global event loop over every (stage, pair) chain.  A
            # pair is eligible once BOTH its banks finished their
            # previous stage's chain (a bank is in exactly one pair per
            # stage, so per-bank command order is preserved); eligible
            # chains interleave by earliest next step, so pair k+1's
            # reads issue while pair k's writes drain AND a bank that
            # finishes stage s early starts its stage-s+1 work under
            # the stage-s stragglers.
            pair_of: list[dict[int, int]] = []
            for st in stages:
                m = {}
                for i, p in enumerate(st.pairs):
                    m[p.u] = i
                    m[p.v] = i
                pair_of.append(m)
            bank_stage = [-1] * self.banks  # last exhausted stage per bank
            heap: list = []

            def start(si: int, i: int) -> None:
                p = stages[si].pairs[i]
                t0 = max(ready[p.u], ready[p.v])
                t0s[si][i] = t0
                g = self._pair_chain(dev, p, t0, charges[si][i], ready,
                                     ends[si], i)
                try:
                    heapq.heappush(heap, (next(g), si, i, g))
                except StopIteration:
                    pass

            for i in range(len(stages[0].pairs)) if nstages else ():
                start(0, i)
            while heap:
                _, si, i, g = heapq.heappop(heap)
                b0 = sum(c.bus_busy_ns for c in dev.channels)
                try:
                    heapq.heappush(heap, (next(g), si, i, g))
                    busy[si] += sum(c.bus_busy_ns
                                    for c in dev.channels) - b0
                except StopIteration:
                    busy[si] += sum(c.bus_busy_ns
                                    for c in dev.channels) - b0
                    p = stages[si].pairs[i]
                    bank_stage[p.u] = bank_stage[p.v] = si
                    if si + 1 < nstages:
                        for b in (p.u, p.v):
                            j = pair_of[si + 1][b]
                            q = stages[si + 1].pairs[j]
                            if (bank_stage[q.u] == si
                                    and bank_stage[q.v] == si):
                                start(si + 1, j)
        else:
            for si, (stage, st_charges) in enumerate(zip(stages, charges)):
                b0 = sum(c.bus_busy_ns for c in dev.channels)
                for i, p in enumerate(stage.pairs):
                    t0s[si][i] = max(ready[p.u], ready[p.v])
                    for _ in self._pair_chain(dev, p, t0s[si][i],
                                              st_charges[i], ready,
                                              ends[si], i):
                        pass
                busy[si] = sum(c.bus_busy_ns for c in dev.channels) - b0

        spans: list[ExchangeStageSpan] = []
        for si, stage in enumerate(stages):
            if not stage.pairs:
                continue
            begin, end = min(t0s[si]), max(ends[si])
            if x_start is None or begin < x_start:
                x_start = begin
            used = {self.topo.address_of(self.flat_banks[p.u]).channel
                    for p in stage.pairs}
            used |= {self.topo.address_of(self.flat_banks[p.v]).channel
                     for p in stage.pairs}
            span = end - begin
            work = sum(e - t for e, t in zip(ends[si], t0s[si]))
            occ = busy[si] / (len(used) * span) if span > 0 else 0.0
            overlap = 1.0 - span / work if work > 0 else 0.0
            occ = min(1.0, occ)
            overlap = min(1.0, max(0.0, overlap))
            spans.append(ExchangeStageSpan(
                stride=stage.stride, begin_ns=begin, end_ns=end,
                busy_ns=busy[si], pairs=len(stage.pairs),
                channels=len(used), occupancy=occ, overlap=overlap))
            if tr is not None and end > 0.0:
                tr.phases.append(
                    ("exchange",
                     f"stride={stage.stride};occ={occ:.2f};"
                     f"overlap={overlap:.2f}",
                     begin, end))
        return x_start, tuple(spans)

    def simulate(self, policy: str = "rr", single: TimingResult | None = None,
                 baseline: bool = True, pipelined: bool = True,
                 tracer=None) -> ShardedTimingResult:
        """Time the full sharded NTT on the device-level memory system.

        Pass `single` (the one-bank `simulate_ntt` result) when sweeping
        bank counts, or `baseline=False` to skip the one-bank reference
        sim entirely (speedup then reads 0; the scheduler does this).
        `pipelined=False` forces strictly serial engines (the Fig 6a
        ablation), in the local passes AND the exchange butterflies.
        `tracer` (a `telemetry.Tracer`) records the full timeline:
        per-command events through the engines, per-bank local-pass
        spans, per-stage exchange spans, and every inter-bank burst.
        """
        dev = Device(self.cfg, self.topo, policy=policy, pipelined=pipelined,
                     tracer=tracer)
        self._xfer_atoms = 0
        self._xfer_hops = 0
        ready = [0.0] * self.banks
        if single is None and baseline:
            single = _time_ntt(self.n, self.cfg, forward=self.forward,
                               pipelined=pipelined)
        single_ns = single.ns if single is not None else 0.0

        def run_local(gates: list[float]) -> None:
            traces = self.local_param_traces()
            for b, cmds in enumerate(self.local_streams()):
                dev.enqueue_flat(self.flat_banks[b], cmds, gate=gates[b],
                                 job_id=("local", b), param_trace=traces[b])
            for ev in dev.drain():
                b = ev.job_id[1]
                ready[b] = ev.done
                if tracer is not None:
                    tracer.phases.append(
                        (f"bank{self.flat_banks[b]}", "local",
                         gates[b], ev.done))

        if self.forward:
            busy0 = [c.bus_busy_ns for c in dev.channels]
            x_start, breakdown = self._run_exchange(dev, ready, pipelined)
            x_end = max(ready)
            exchange_ns = (x_end - x_start) if x_start is not None else 0.0
            x_busy = sum(c.bus_busy_ns - b0 for c, b0 in zip(dev.channels, busy0))
            run_local(list(ready))
            local_ns = max(ready) - x_end
        else:
            run_local([0.0] * self.banks)
            local_ns = max(ready)
            busy0 = [c.bus_busy_ns for c in dev.channels]
            x_start, breakdown = self._run_exchange(dev, ready, pipelined)
            # the window opens at the earliest pair barrier: pairs on a
            # fast channel start exchanging before the slowest local
            # pass ends, and their bursts belong in the denominator
            exchange_ns = (max(ready) - x_start) if x_start is not None else 0.0
            x_busy = sum(c.bus_busy_ns - b0 for c, b0 in zip(dev.channels, busy0))

        latency = max(ready)
        bound = self.analytic_local_bound()
        if latency < bound - 1e-6:  # not an assert: must survive python -O
            raise RuntimeError(
                f"sharded plan beat the analytic local bus bound: {latency} < {bound}")
        used_channels = len({self.topo.address_of(f).channel
                             for f in self.flat_banks})
        occ = (x_busy / (used_channels * exchange_ns)) if exchange_ns > 0 else 0.0
        reg = StatsRegistry(channels=self.topo.channels)
        for ctrl in dev.channels:
            ctrl.record_stats(reg)
        reg.add_device({"xfer_atoms": self._xfer_atoms,
                        "xfer_hops": self._xfer_hops})
        return ShardedTimingResult(
            n=self.n,
            banks=self.banks,
            latency_ns=latency,
            local_ns=local_ns,
            exchange_ns=exchange_ns,
            single_ns=single_ns,
            analytic_local_ns=bound,
            exchange_bus_occupancy=min(1.0, occ),
            xfer_atoms=self._xfer_atoms,
            xfer_hops=self._xfer_hops,
            stats=reg,
            stage_breakdown=breakdown,
        )
