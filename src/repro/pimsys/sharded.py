"""Shard ONE large NTT across many banks and channels (`ShardedNttPlan`).

The paper pipelines butterfly stages inside one bank; the ROADMAP's next
system-level step is the opposite axis: split a single size-N NTT over
B = banks x channels banks so the inter-bank butterfly stages cross the
channel boundary.  We use the four-step (Cooley-Tukey column/row)
decomposition specialized to the row-centric command stream:

  view the coefficient vector as a (B x M) matrix, M = N/B, row b living
  contiguously in bank b.  The stage set {1, ..., N/2} splits exactly at
  stride M:

  * strides t < M   -- the "row NTTs": a full size-M sub-NTT local to
    each bank.  Emitted as an unmodified `RowCentricMapper` stream with
    `twiddle_base = b*M`, which shifts the (w0, r_w) parameters so the
    local pass resolves the *global* table (the four-step twiddle
    correction is absorbed into the shifted bases; no extra passes).
  * strides t >= M  -- the "column NTTs": log2(B) cross-bank stages.
    Bank b pairs with bank b + t/M and -- because a whole bank spans
    less than half a butterfly block at these strides -- the pair shares
    ONE twiddle: the exchange moves twiddle-scaled columns wholesale.
    Each atom crosses the per-channel shared bus as a paired
    ColRead (source bank) / ColWrite-burst (target bank) transaction
    (`ChannelController.occupy_bus`); pairs that straddle channels hold
    both buses and pay `channel_hop_cycles` extra latency.

Execution order: inverse/GS (the paper orientation) runs the local pass
first, then the exchange stages; forward/CT mirrors (exchange first,
local pass second).  A forward+inverse pipeline (NTT -> INTT round trip)
is therefore the classic four-step sandwich local/exchange/.../local.

At banks=1 the plan degenerates to the single `RowCentricMapper` stream
-- command-list identical, and (through the one-bank controller) timed
bit-identically to `BankTimer`; `tests/test_sharded.py` asserts both.

Timing is a thin driver of the hierarchical resource engine
(`repro.pimsys.engine`) end to end: phase A(/B) local streams run
through `DeviceEngine` (per-channel bus arbitration -> rank windows ->
`BankEngine` hazards), and the exchange phase issues genuine
Act/ColRead/C2/ColWrite commands into the SAME engines via
`issue_direct` -- butterfly compute happens on the u-bank's CU, hazards,
refresh, and rank tFAW/turnaround windows included -- with the
inter-bank burst modeled as shared-bus occupancy (`DeviceEngine.burst`).
The device-side twiddle-parameter cache reaches both phases: local
streams replay their plan-level residency traces
(`local_param_traces`), and exchange C2s hit after the first atom of
each pair (one shared twiddle per pair; each phase's cache starts cold,
a conservative simplification).  Functional execution
(`run_functional`, surfaced as `core.polymul.pim_ntt_sharded`) drives
one `FunctionalBank` per bank and is asserted bit-equal to `core.ntt`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import ntt as ntt_ref
from repro.core.mapping import (
    Act,
    C1,
    C2,
    CMul,
    ColRead,
    ColWrite,
    Command,
    Mark,
    FunctionalBank,
    RowCentricMapper,
    twiddle_index,
)
from repro.core.pim_config import PimConfig
from repro.core.pimsim import BankEngine, TimingResult, _time_ntt
from repro.pimsys.controller import ChannelController, Device
from repro.pimsys.engine import (
    param_beat_trace,
    param_hit_beats,
    trace_param_beats,
)
from repro.pimsys.stats import StatsRegistry
from repro.pimsys.topology import DeviceTopology

_INF_F = math.inf


# --------------------------------------------------------------------------
# Plan structure
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExchangePair:
    """One cross-bank butterfly: u-bank pairs with v-bank at `stride`.

    `tw_index` is the (single) global twiddle-table index the whole pair
    shares -- at stride t >= M a bank spans less than half a 2t-block,
    so the MC programs one (w0, r_w) per pair and stage.
    """

    u: int       # sub-NTT index of the u operand (holds words [u*M, u*M+M))
    v: int       # sub-NTT index of the v operand
    stride: int  # butterfly stride in global words (a multiple of M)
    tw_index: int


@dataclasses.dataclass(frozen=True)
class ExchangeStage:
    stride: int
    pairs: tuple[ExchangePair, ...]


@dataclasses.dataclass
class ShardedTimingResult:
    """Cycle-level timing of one sharded NTT (see `ShardedNttPlan.simulate`)."""

    n: int
    banks: int
    latency_ns: float
    local_ns: float          # local-pass phase span (bus-arbitrated)
    exchange_ns: float       # exchange activity window: earliest pair
    #                          barrier -> last write (0.0 at banks=1).
    #                          Overlaps the local tail under skewed
    #                          placements, so local_ns + exchange_ns can
    #                          exceed latency_ns.
    single_ns: float         # one-bank BankTimer baseline for the same N
    analytic_local_ns: float  # per-channel bus lower bound on the local pass
    exchange_bus_occupancy: float  # busy/span over channels during exchange
    xfer_atoms: int
    xfer_hops: int           # atoms that crossed a channel boundary
    stats: StatsRegistry

    @property
    def speedup(self) -> float:
        return self.single_ns / self.latency_ns if self.latency_ns else 0.0

    @property
    def efficiency(self) -> float:
        return self.speedup / self.banks


class ShardedNttPlan:
    """Four-step command plan for one size-n NTT over `banks` banks.

    Sub-NTT index b (bank b's N/B-point slice) maps to topology flat bank
    id b -- channel-interleaved by `DeviceTopology`, so consecutive
    shards land on different channels and exchange partners at small
    strides sit across the channel boundary (the inter-channel hops the
    benchmark sweeps measure).
    """

    def __init__(self, cfg: PimConfig, n: int, banks: int, forward: bool = False,
                 topo: DeviceTopology | None = None,
                 flat_banks: Sequence[int] | None = None):
        if n & (n - 1) or n <= 0:
            raise ValueError("n must be a power of two")
        if banks & (banks - 1) or banks <= 0:
            raise ValueError("banks must be a power of two")
        if n % banks:
            raise ValueError(f"banks={banks} does not divide n={n}")
        self.cfg = cfg
        self.n = n
        self.banks = banks
        self.forward = forward
        self.m = n // banks  # words per bank (the local sub-NTT size)
        if self.m < cfg.atom_words:
            raise ValueError(
                f"n/banks = {self.m} is below one atom ({cfg.atom_words} words)")
        rows_needed = max(1, self.m // cfg.row_words)
        if rows_needed > cfg.rows_per_bank:
            raise ValueError(
                f"a {self.m}-word shard needs {rows_needed} rows; a bank "
                f"has {cfg.rows_per_bank}")
        if banks > 1 and cfg.num_buffers < 2:
            raise ValueError("the exchange phase needs num_buffers >= 2")
        if topo is None:
            topo = DeviceTopology.from_config(cfg)
            if topo.total_banks < banks:
                # grow the default device to fit the plan (keeps the
                # functional API usable with the paper's 1-bank config);
                # an explicitly passed topology is never resized
                per_ch = -(-banks // (topo.channels * topo.ranks))  # ceil
                topo = DeviceTopology(channels=topo.channels, ranks=topo.ranks,
                                      banks_per_rank=per_ch)
        elif topo.total_banks < banks:
            raise ValueError(
                f"topology {topo.describe()} has fewer than {banks} banks")
        self.topo = topo
        # Sub-NTT index -> physical flat bank id.  The default identity
        # placement channel-interleaves shards; the scheduler passes the
        # gang it actually reserved.
        self.flat_banks = tuple(flat_banks) if flat_banks is not None else tuple(range(banks))
        if len(self.flat_banks) != banks or len(set(self.flat_banks)) != banks:
            raise ValueError(f"flat_banks must be {banks} distinct bank ids")
        for f in self.flat_banks:
            self.topo.address_of(f)  # range check
        self._local_streams: list[list[Command]] | None = None
        self._exchange_stages: list[ExchangeStage] | None = None
        self._local_traces: list | None = None

    # -- command-level structure --------------------------------------------
    def local_streams(self) -> list[list[Command]]:
        """Per-bank size-M Mapper streams with shifted twiddle bases.

        At banks=1 this is exactly `RowCentricMapper(cfg, n).commands()`
        -- command-list equality, the differential anchor of the plan.
        Cached: simulate() and the analytic bound both walk the streams.
        """
        if self._local_streams is None:
            self._local_streams = [
                RowCentricMapper(self.cfg, self.m, forward=self.forward,
                                 twiddle_base=b * self.m).commands()
                for b in range(self.banks)
            ]
        return self._local_streams

    def local_param_traces(self) -> list:
        """Per-bank `engine.param_beat_trace` residency traces (the
        device-side twiddle-parameter cache model), resolved against the
        GLOBAL transform size through the shifted twiddle bases.  Cached
        like `local_streams`: every simulate() replays one precomputed
        trace ([None]*banks when the cache is disabled)."""
        if self._local_traces is None:
            self._local_traces = [
                param_beat_trace(self.cfg, self.n, s)
                for s in self.local_streams()
            ]
        return self._local_traces

    def exchange_stages(self) -> list[ExchangeStage]:
        """Cross-bank stages, in execution order for this orientation.

        Cached (like `local_streams`): the stage set and its shared
        twiddle indices are pure functions of (n, banks, orientation), so
        repeated `simulate`/`run_functional` calls replay one schedule.
        """
        if self._exchange_stages is not None:
            return self._exchange_stages
        strides = [self.m << i for i in range(int(math.log2(self.banks)))]
        if self.forward:
            strides = strides[::-1]  # CT: large strides first
        stages = []
        for t in strides:
            tb = t // self.m  # stride in banks
            pairs = tuple(
                ExchangePair(u=b, v=b + tb, stride=t,
                             tw_index=twiddle_index(self.n, t, b * self.m))
                for b in range(self.banks)
                if (b // tb) % 2 == 0
            )
            stages.append(ExchangeStage(stride=t, pairs=pairs))
        self._exchange_stages = stages
        return stages

    def trace_streams(self) -> dict[tuple[int, int], list[Command]]:
        """Local-pass streams keyed by (channel, bank-in-channel).

        This is the `pimsys.trace`-dumpable command-level artifact of the
        plan: the exchange phase is a bus/topology schedule (not bank
        program text) and is regenerated deterministically from
        `exchange_stages()` at replay time.
        """
        out: dict[tuple[int, int], list[Command]] = {}
        for b, cmds in enumerate(self.local_streams()):
            addr = self.topo.address_of(self.flat_banks[b])
            out[(addr.channel, self.topo.local_id(addr))] = cmds
        return out

    # -- functional execution -----------------------------------------------
    def run_functional(self, a: np.ndarray, ctx: ntt_ref.NttContext) -> np.ndarray:
        """Bit-exact execution on one `FunctionalBank` per bank.

        The exchange stages apply the shared-twiddle vector butterfly to
        whole bank images -- functionally identical to streaming the
        atoms through the u-bank's CU, which is what `simulate` times.
        """
        if a.shape[0] != self.n:
            raise ValueError(f"input length {a.shape[0]} != n={self.n}")
        if ctx.n != self.n:
            raise ValueError(f"context is for n={ctx.n}, plan is n={self.n}")
        q = ctx.q
        table = ctx.psi_brv if self.forward else ctx.psi_inv_brv
        # Size the memory image to the shard, not the device (a 32-bank
        # plan would otherwise allocate 32 full bank images).
        rows = max(1, self.m // self.cfg.row_words)
        small = self.cfg.with_(rows_per_bank=rows)
        fbanks = []
        for b in range(self.banks):
            fb = FunctionalBank(small, ctx, forward=self.forward)
            fb.load_poly(np.asarray(a[b * self.m:(b + 1) * self.m], np.uint32))
            fbanks.append(fb)

        def local_pass():
            for fb, cmds in zip(fbanks, self.local_streams()):
                fb.run(cmds)

        def exchange():
            for stage in self.exchange_stages():
                for p in stage.pairs:
                    u = fbanks[p.u].read_poly(self.m).astype(np.int64)
                    v = fbanks[p.v].read_poly(self.m).astype(np.int64)
                    w = int(table[p.tw_index])
                    if self.forward:  # CT: (u + w*v, u - w*v)
                        wv = v * w % q
                        nu, nv = (u + wv) % q, (u - wv) % q
                    else:  # GS: (u + v, (u - v)*w)
                        nu, nv = (u + v) % q, (u - v) * w % q
                    fbanks[p.u].load_poly(nu.astype(np.uint32))
                    fbanks[p.v].load_poly(nv.astype(np.uint32))

        if self.forward:
            exchange()
            local_pass()
        else:
            local_pass()
            exchange()
        return np.concatenate([fb.read_poly(self.m) for fb in fbanks])

    # -- timing ---------------------------------------------------------------
    def analytic_local_bound(self) -> float:
        """Per-channel shared-bus lower bound on the local pass.

        The channel bus serializes its banks' command+parameter traffic;
        the pass cannot finish before the busiest channel drains, nor
        before a lone sub-NTT would on a private bus.  Parameter beats
        come from each stream's cache-residency trace when the
        device-side parameter cache is enabled (the engine charges
        exactly those beats, so the bound stays a bound)."""
        cfg = self.cfg
        per_channel: dict[int, float] = {}
        traces = self.local_param_traces()
        for b, cmds in enumerate(self.local_streams()):
            n_cmds = sum(1 for c in cmds if not isinstance(c, Mark))
            cu = sum(1 for c in cmds if isinstance(c, (C1, C2, CMul)))
            bus_ns = (n_cmds + trace_param_beats(cfg, traces[b], cu)) * cfg.dram_ns
            ch = self.topo.address_of(self.flat_banks[b]).channel
            per_channel[ch] = per_channel.get(ch, 0.0) + bus_ns
        return max(per_channel.values(), default=0.0)

    def _port(self, dev: Device, sub: int) -> tuple[ChannelController, int]:
        addr = self.topo.address_of(self.flat_banks[sub])
        return dev.channels[addr.channel], self.topo.local_id(addr)

    def _engine(self, dev: Device, sub: int) -> tuple[ChannelController, BankEngine]:
        ctrl, local = self._port(dev, sub)
        return ctrl, ctrl.engines[local]

    def _issue(self, dev: Device, sub: int, cmd: Command, not_before: float = 0.0,
               param_ns: float | None = None, code: int = 0):
        """Issue one exchange-phase command through the bank's real engine,
        holding its channel's shared bus (and rank windows) exactly as
        the arbiter would."""
        ctrl, local = self._port(dev, sub)
        return ctrl.issue_direct(local, cmd, not_before, param_ns=param_ns,
                                 code=code)

    def _open(self, dev: Device, sub: int, row: int, not_before: float = 0.0) -> float:
        _, eng = self._engine(dev, sub)
        if eng.open_row != row:
            _, done = self._issue(dev, sub, Act(row), not_before)
            return done
        return not_before

    def _transfer(self, dev: Device, src: int, dst: int, earliest: float) -> float:
        """Move one atom src-bank -> dst-bank buffer over the shared bus
        (`DeviceEngine.burst`: same-channel = one bus hold, cross-channel
        = both buses held + hop latency).  Returns the arrival time at
        the destination buffer."""
        ch_s = self.topo.address_of(self.flat_banks[src]).channel
        ch_d = self.topo.address_of(self.flat_banks[dst]).channel
        if ch_s != ch_d:
            self._xfer_hops += 1
        return dev.burst(ch_s, ch_d, earliest)

    def _run_exchange(self, dev: Device, ready: list[float]) -> float | None:
        """Issue every exchange stage into the live engines.

        `ready[b]` carries each sub-NTT's data-complete time in and out.
        Per atom: ColRead on v, burst v->u, ColRead of u's own atom, C2
        on u's CU (one shared twiddle per pair => one (w0, r_w) stream),
        ColWrite of u', burst u->v of v', ColWrite on v.

        Returns the exchange activity START — the earliest first-stage
        pair barrier, which every exchange grant is at or after.  Pairs
        on lightly loaded channels begin exchanging before the slowest
        bank's local pass ends, so this can precede max(ready)-at-entry;
        the occupancy window must open here, not at the global phase
        boundary.

        Parameter cache: every atom of a pair shares ONE (w0, r_w)
        program (the pair's single twiddle), so with
        `param_cache_entries > 0` the u-bank pays a full load on the
        pair's first butterfly and one re-select beat
        (`engine.param_hit_beats`) after.  This IS the general per-bank
        LRU outcome, not an approximation: program keys are unique per
        (stage, pair) and each pair's C2s issue contiguously on its
        u-bank, so any cache with >= 1 entry misses exactly the first
        atom.  Each bank's exchange cache starts cold (the local pass's
        residency trace is computed independently at the plan layer) —
        a conservative simplification that can only overcharge.
        """
        cfg = self.cfg
        Na, R = cfg.atom_words, cfg.row_words
        slots = max(1, cfg.num_buffers // 2)
        entries = cfg.param_cache_entries
        full_ns = cfg.param_load_cycles * cfg.dram_ns
        hit_ns = param_hit_beats(cfg) * cfg.dram_ns
        x_start: float | None = None
        tr = dev.tracer
        for stage in self.exchange_stages():
            st_begin, st_end = _INF_F, 0.0
            for p in stage.pairs:
                _, eng_u = self._engine(dev, p.u)
                _, eng_v = self._engine(dev, p.v)
                t0 = max(ready[p.u], ready[p.v])
                if x_start is None or t0 < x_start:
                    x_start = t0
                done_u = done_v = t0
                for a in range(self.m // Na):
                    w0 = a * Na
                    row, atom = w0 // R, (w0 % R) // Na
                    slot = a % slots
                    bu_loc, bu_recv = 2 * slot, 2 * slot + 1
                    bv_send, bv_recv = 2 * slot, 2 * slot + 1
                    # v reads its atom and bursts it to u's spare buffer
                    t = self._open(dev, p.v, row, t0)
                    _, v_read = self._issue(dev, p.v, ColRead(row, atom, bv_send), t)
                    arrive_u = self._transfer(
                        dev, p.v, p.u, max(v_read, eng_u.buf_free[bu_recv]))
                    eng_u.data_ready[bu_recv] = arrive_u
                    # the burst consumes bv_send: WAR for the next read
                    eng_v.buf_free[bv_send] = max(eng_v.buf_free[bv_send], arrive_u)
                    self._xfer_atoms += 1
                    # u reads its own atom and runs the butterfly on its CU
                    t = self._open(dev, p.u, row, t0)
                    self._issue(dev, p.u, ColRead(row, atom, bu_loc), t)
                    base = p.u * self.m + w0
                    c2 = C2((bu_loc,), (bu_recv,), (base,), p.stride,
                            gs=not self.forward)
                    pn, code = None, 0
                    if entries:
                        pn, code = (full_ns, 1) if a == 0 else (hit_ns, 2)
                    _, c2_done = self._issue(dev, p.u, c2, param_ns=pn,
                                             code=code)
                    _, u_wr = self._issue(dev, p.u, ColWrite(row, atom, bu_loc))
                    done_u = max(done_u, u_wr)
                    # v' bursts back and is written on v
                    arrive_v = self._transfer(
                        dev, p.u, p.v, max(c2_done, eng_v.buf_free[bv_recv]))
                    eng_u.buf_free[bu_recv] = max(eng_u.buf_free[bu_recv], arrive_v)
                    eng_v.data_ready[bv_recv] = arrive_v
                    self._xfer_atoms += 1
                    _, v_wr = self._issue(dev, p.v, ColWrite(row, atom, bv_recv))
                    done_v = max(done_v, v_wr)
                ready[p.u], ready[p.v] = done_u, done_v
                if tr is not None:
                    if t0 < st_begin:
                        st_begin = t0
                    if done_u > st_end:
                        st_end = done_u
                    if done_v > st_end:
                        st_end = done_v
            if tr is not None and st_end > 0.0:
                tr.phases.append(("exchange", f"stride={stage.stride}",
                                  st_begin, st_end))
        return x_start

    def simulate(self, policy: str = "rr", single: TimingResult | None = None,
                 baseline: bool = True, pipelined: bool = True,
                 tracer=None) -> ShardedTimingResult:
        """Time the full sharded NTT on the device-level memory system.

        Pass `single` (the one-bank `simulate_ntt` result) when sweeping
        bank counts, or `baseline=False` to skip the one-bank reference
        sim entirely (speedup then reads 0; the scheduler does this).
        `pipelined=False` forces strictly serial engines (the Fig 6a
        ablation), in the local passes AND the exchange butterflies.
        `tracer` (a `telemetry.Tracer`) records the full timeline:
        per-command events through the engines, per-bank local-pass
        spans, per-stage exchange spans, and every inter-bank burst.
        """
        dev = Device(self.cfg, self.topo, policy=policy, pipelined=pipelined,
                     tracer=tracer)
        self._xfer_atoms = 0
        self._xfer_hops = 0
        ready = [0.0] * self.banks
        if single is None and baseline:
            single = _time_ntt(self.n, self.cfg, forward=self.forward,
                               pipelined=pipelined)
        single_ns = single.ns if single is not None else 0.0

        def run_local(gates: list[float]) -> None:
            traces = self.local_param_traces()
            for b, cmds in enumerate(self.local_streams()):
                dev.enqueue_flat(self.flat_banks[b], cmds, gate=gates[b],
                                 job_id=("local", b), param_trace=traces[b])
            for ev in dev.drain():
                b = ev.job_id[1]
                ready[b] = ev.done
                if tracer is not None:
                    tracer.phases.append(
                        (f"bank{self.flat_banks[b]}", "local",
                         gates[b], ev.done))

        if self.forward:
            busy0 = [c.bus_busy_ns for c in dev.channels]
            x_start = self._run_exchange(dev, ready)
            x_end = max(ready)
            exchange_ns = (x_end - x_start) if x_start is not None else 0.0
            x_busy = sum(c.bus_busy_ns - b0 for c, b0 in zip(dev.channels, busy0))
            run_local(list(ready))
            local_ns = max(ready) - x_end
        else:
            run_local([0.0] * self.banks)
            local_ns = max(ready)
            busy0 = [c.bus_busy_ns for c in dev.channels]
            x_start = self._run_exchange(dev, ready)
            # the window opens at the earliest pair barrier: pairs on a
            # fast channel start exchanging before the slowest local
            # pass ends, and their bursts belong in the denominator
            exchange_ns = (max(ready) - x_start) if x_start is not None else 0.0
            x_busy = sum(c.bus_busy_ns - b0 for c, b0 in zip(dev.channels, busy0))

        latency = max(ready)
        bound = self.analytic_local_bound()
        if latency < bound - 1e-6:  # not an assert: must survive python -O
            raise RuntimeError(
                f"sharded plan beat the analytic local bus bound: {latency} < {bound}")
        used_channels = len({self.topo.address_of(f).channel
                             for f in self.flat_banks})
        occ = (x_busy / (used_channels * exchange_ns)) if exchange_ns > 0 else 0.0
        reg = StatsRegistry(channels=self.topo.channels)
        for ctrl in dev.channels:
            ctrl.record_stats(reg)
        reg.add_device({"xfer_atoms": self._xfer_atoms,
                        "xfer_hops": self._xfer_hops})
        return ShardedTimingResult(
            n=self.n,
            banks=self.banks,
            latency_ns=latency,
            local_ns=local_ns,
            exchange_ns=exchange_ns,
            single_ns=single_ns,
            analytic_local_ns=bound,
            exchange_bus_occupancy=min(1.0, occ),
            xfer_atoms=self._xfer_atoms,
            xfer_hops=self._xfer_hops,
            stats=reg,
        )
