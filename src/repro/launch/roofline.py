"""Roofline analysis over the dry-run reports (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) cell, single-pod mesh, from the compiled
per-device SPMD module (depth-extrapolated — see dryrun._depth_variant):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]
    collective term = collective_operand_bytes_per_device / link_bw [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reported: MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(inference) and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs x chips),
which exposes remat/redundancy waste.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun")


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the config, analytically."""
    d = cfg.d_model
    total = 0
    active = 0
    pattern = cfg.pattern()
    per_pattern = cfg.reps
    for mixer, ffn in pattern:
        t = a = 0
        if mixer in ("attn", "attn_nc", "cross", "attn_cross"):
            attn = d * cfg.num_heads * cfg.hd * 2 + d * cfg.num_kv_heads * cfg.hd * 2
            t += attn * (2 if mixer == "attn_cross" else 1)
            a += attn * (2 if mixer == "attn_cross" else 1)
        if mixer == "mamba":
            g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
            di = cfg.d_inner
            m = d * (2 * di + 2 * g * n + h) + di * d + 4 * (di + 2 * g * n) + di
            t += m
            a += m
        if ffn == "mlp":
            t += 3 * d * cfg.d_ff
            a += 3 * d * cfg.d_ff
        elif ffn == "moe":
            f = cfg.moe_d_ff or cfg.d_ff
            t += 3 * d * f * cfg.num_experts + d * cfg.num_experts
            a += 3 * d * f * cfg.experts_per_token + d * cfg.num_experts
        total += t * per_pattern
        active += a * per_pattern
    if cfg.encoder_layers:
        enc = cfg.encoder_layers * (d * cfg.num_heads * cfg.hd * 4 + 3 * d * cfg.d_ff)
        total += enc
        active += enc
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (inference)."""
    _, active = count_params(cfg)
    if cfg.max_target_len:
        seq = min(shape.seq_len, cfg.max_target_len)
    else:
        seq = shape.seq_len
    if shape.kind == "train":
        tokens = shape.global_batch * seq
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * seq
        return 2.0 * active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * active * tokens


def analyze_cell(rec: dict) -> dict | None:
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config

    if rec.get("status") != "run" or "roofline_inputs" not in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    ri = rec["roofline_inputs"]
    chips = 256 if rec["mesh"] == "pod16x16" else 512
    t_comp = ri["flops_per_device"] / PEAK_FLOPS
    t_mem = ri["bytes_per_device"] / HBM_BW
    t_coll = ri["collective_bytes_per_device"] / LINK_BW
    dominant = max(
        ("compute", t_comp), ("memory", t_mem), ("collective", t_coll), key=lambda kv: kv[1]
    )[0]
    mf = model_flops(cfg, shape)
    hlo_total = ri["flops_per_device"] * chips
    bound = max(t_comp, t_mem, t_coll)
    return dict(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        compute_s=t_comp,
        memory_s=t_mem,
        collective_s=t_coll,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        # step time if perfectly overlapped = max term; roofline fraction =
        # useful compute time / bound time.
        roofline_fraction=(mf / chips / PEAK_FLOPS) / bound if bound else 0.0,
        peak_gib=rec["memory"]["peak_bytes"] / 2**30,
        collective_by_op=ri.get("collective_by_op", {}),
    )


def load_all(report_dir: str = REPORT_DIR, mesh: str = "pod16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(report_dir, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        row = analyze_cell(rec)
        if row:
            rows.append(row)
        elif rec.get("status", "").startswith("skip"):
            rows.append(dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                             skip=rec["status"]))
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful ratio | roofline frac | peak GiB |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['skip']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2%} | {r['peak_gib']:.2f} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report-dir", default=REPORT_DIR)
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = load_all(args.report_dir, args.mesh)
    print(to_markdown(rows))
    ranked = sorted([r for r in rows if "skip" not in r], key=lambda r: r["roofline_fraction"])
    if ranked:
        print("\nWorst roofline fraction:", ranked[0]["arch"], ranked[0]["shape"],
              f"{ranked[0]['roofline_fraction']:.2%}")
        coll = sorted(ranked, key=lambda r: -r["collective_s"] / max(r["compute_s"], 1e-12))
        print("Most collective-bound:", coll[0]["arch"], coll[0]["shape"])


if __name__ == "__main__":
    main()
