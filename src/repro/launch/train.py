"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production behaviours demonstrated end-to-end (and exercised by
tests/test_train_loop.py):
  * auto-resume from the latest complete checkpoint (restart-safe data
    pipeline replays the exact stream position);
  * per-step failure handling: a failed step (device error, NaN loss,
    injected fault) rolls back to the last checkpoint and retries with
    the same data — bounded by --max-retries;
  * straggler mitigation: a per-step deadline; steps exceeding it are
    logged and counted (on real multi-host deployments the launcher
    escalates to pod eviction / spare-pod swap — see DESIGN.md §5);
  * elastic re-mesh: checkpoints are logical arrays, so a restart under
    a different device count just re-shards on load (exercised by the
    test restoring a 2-device run into a 1-device mesh).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticStream
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import OptConfig


class FaultInjector:
    """Deterministically fails chosen steps (for tests / demos)."""

    def __init__(self, fail_steps=(), exc=RuntimeError):
        self.fail_steps = set(fail_steps)
        self.exc = exc
        self.fired = set()

    def check(self, step: int):
        if step in self.fail_steps and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected fault at step {step}")


def train(
    arch,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str,
    reduced: bool = True,
    ckpt_every: int = 20,
    max_retries: int = 3,
    step_deadline_s: float = 120.0,
    seed: int = 0,
    injector: FaultInjector | None = None,
    mesh=None,
    log_every: int = 10,
):
    """arch: registry name or a ModelConfig instance (custom models)."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    if reduced and isinstance(arch, str):
        cfg = cfg.reduced()
    opt_cfg = OptConfig(total_steps=steps, warmup_steps=max(1, steps // 20))
    mesh = mesh or make_host_mesh()
    stream = SyntheticStream(cfg, batch, seq, seed=seed)
    mgr = CheckpointManager(ckpt_dir)
    injector = injector or FaultInjector()

    # -- build + shard initial state ---------------------------------------
    param_shape = steps_lib.param_specs(cfg)
    opt_shape = steps_lib.opt_specs(cfg, opt_cfg)
    p_sh = shd.param_shardings(mesh, param_shape)
    o_sh = shd.opt_shardings(mesh, opt_shape)

    train_step = steps_lib.make_train_step(cfg, opt_cfg)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    start_step = 0
    latest = mgr.latest_step()
    if latest is not None:
        (params, opt_state), _ = mgr.restore(
            latest, (param_shape, opt_shape), (p_sh, o_sh)
        )
        start_step = latest
        print(f"[train] resumed from checkpoint step {latest}")
    else:
        with mesh:
            params = jax.jit(
                lambda k: T.init_params(cfg, k), out_shardings=p_sh
            )(jax.random.PRNGKey(seed))
            init_opt = steps_lib.make_opt_init(cfg, opt_cfg)
            opt_state = jax.jit(init_opt, out_shardings=o_sh)(params)
        mgr.save(0, (params, opt_state))

    # -- loop ----------------------------------------------------------------
    history = []
    stragglers = 0
    step = start_step
    retries = 0
    while step < steps:
        batch_np = stream.batch_at(step)
        t0 = time.time()
        try:
            injector.check(step)
            with mesh:
                params, opt_state, metrics = jit_step(
                    params, opt_state, batch_np, jnp.int32(step)
                )
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
        except Exception as e:  # noqa: BLE001 — rollback + retry
            retries += 1
            if retries > max_retries:
                raise RuntimeError(f"step {step}: exceeded max retries") from e
            latest = mgr.latest_step()
            print(f"[train] step {step} failed ({e}); rolling back to ckpt {latest} "
                  f"(retry {retries}/{max_retries})")
            (params, opt_state), _ = mgr.restore(
                latest, (param_shape, opt_shape), (p_sh, o_sh)
            )
            step = latest
            continue
        dt = time.time() - t0
        if dt > step_deadline_s:
            stragglers += 1
            print(f"[train] step {step} exceeded deadline ({dt:.1f}s) — straggler logged")
        retries = 0
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} ({dt:.2f}s)")
        history.append({"step": step, "loss": loss, "time_s": dt})
        step += 1
        if step % ckpt_every == 0 or step == steps:
            mgr.save(step, (params, opt_state), blocking=False)
    mgr.wait()
    summary = {
        "arch": cfg.name,
        "steps": steps,
        "final_loss": history[-1]["loss"] if history else None,
        "first_loss": history[0]["loss"] if history else None,
        "stragglers": stragglers,
    }
    print("[train] done:", json.dumps(summary))
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full", action="store_true", help="full (paper) config")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure", type=int, default=None)
    args = ap.parse_args()
    injector = FaultInjector([args.inject_failure]) if args.inject_failure else None
    train(
        args.arch,
        args.steps,
        args.batch,
        args.seq,
        args.ckpt_dir,
        reduced=not args.full,
        ckpt_every=args.ckpt_every,
        injector=injector,
    )


if __name__ == "__main__":
    main()
