"""Step functions (pure, jit-able) + their abstract input specs.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — the
contract the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.optim import OptConfig, make_optimizer

S = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig):
    _, update = make_optimizer(opt_cfg)

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        new_params, new_opt, opt_metrics = update(grads, opt_state, params, step)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_opt_init(cfg: ModelConfig, opt_cfg: OptConfig):
    init, _ = make_optimizer(opt_cfg)
    return init


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, caches, pos):
        return T.decode_step(params, cfg, token, caches, pos)

    return decode_step


# ---------------------------------------------------------------------------
# abstract specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.max_target_len:
        s = min(s, cfg.max_target_len)
    out = {"tokens": S((b, s), jnp.int32)}
    if cfg.num_image_tokens:
        out["image_embeds"] = S((b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.encoder_layers:
        out["frames"] = S((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return out


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(T.init_params, cfg), jax.random.PRNGKey(0))


def opt_specs(cfg: ModelConfig, opt_cfg: OptConfig):
    init = make_opt_init(cfg, opt_cfg)
    return jax.eval_shape(init, param_specs(cfg))


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    enc_len = cfg.encoder_seq or cfg.num_image_tokens or 0
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, batch, cache_len, enc_len)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig, opt_cfg: OptConfig | None = None) -> dict:
    """All abstract inputs for the step implied by shape.kind."""
    opt_cfg = opt_cfg or OptConfig()
    if shape.kind == "train":
        return {
            "params": param_specs(cfg),
            "opt_state": opt_specs(cfg, opt_cfg),
            "batch": batch_specs(cfg, shape),
            "step": S((), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"params": param_specs(cfg), "batch": batch_specs(cfg, shape)}
    if shape.kind == "decode":
        b = shape.global_batch
        s = min(shape.seq_len, cfg.max_target_len) if cfg.max_target_len else shape.seq_len
        return {
            "params": param_specs(cfg),
            "token": S((b,), jnp.int32),
            "caches": cache_specs(cfg, b, s),
            "pos": S((), jnp.int32),
        }
    raise ValueError(shape.kind)
