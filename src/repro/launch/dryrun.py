import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each
cell we jit the real step function with production in/out shardings,
lower against ShapeDtypeStruct inputs (no allocation), compile, and
record memory_analysis / cost_analysis / the collective schedule parsed
from the compiled per-device HLO.  Failures here (sharding mismatch, OOM
at compile, unsupported collective) are bugs in the system.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # every runnable cell
Results land in reports/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import ARCH_NAMES, cell_status, effective_shape, get_config  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import OptConfig  # noqa: E402

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun")

#: per-arch optimizer policy (DESIGN.md §5: trillion-param MoEs need
#: factored/low-precision optimizer state to fit 16 GB/chip)
OPT_POLICY = {
    "kimi-k2-1t-a32b": OptConfig(optimizer="adafactor"),
    "jamba-1.5-large-398b": OptConfig(optimizer="adamw", moment_dtype="bfloat16"),
}

_COLL_APPLY_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective traffic from the compiled (SPMD) HLO.

    The scheduled HLO elides operand types, so we read the RESULT shape
    and derive operand bytes per op semantics:
      all-gather:      operand = result / group   (result is concatenated)
      all-reduce:      operand = result
      reduce-scatter:  operand = result * group
      all-to-all:      operand = result
      collective-permute: operand = result
    wire_bytes additionally estimates ring-algorithm link traffic.
    """
    out: dict[str, int] = {}
    wire: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_APPLY_RE.search(line)
        if m is None or "-done" in line.split("=")[0]:
            continue
        result_ty, op = m.group(1), m.group(2)
        rbytes = sum(_bytes_of(d, s) for d, s in _SHAPE_RE.findall(result_ty))
        g = _group_size(line)
        if op == "all-gather":
            operand = rbytes // max(g, 1)
            w = rbytes * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            operand = rbytes
            w = 2 * rbytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            operand = rbytes * g
            w = rbytes * (g - 1)
        else:  # all-to-all, collective-permute
            operand = rbytes
            w = rbytes * (g - 1) / max(g, 1) if op == "all-to-all" else rbytes
        out[op] = out.get(op, 0) + operand
        wire[op] = wire.get(op, 0.0) + w
        count[op] = count.get(op, 0) + 1
    out["total_bytes"] = sum(v for k, v in out.items() if k != "total_bytes")
    out["wire_bytes"] = round(sum(wire.values()))
    out["counts"] = count
    return out


def build_lowerable(cfg, shape, mesh):
    """(jitted_fn, example_args) for the step this shape implies."""
    opt_cfg = OPT_POLICY.get(cfg.name, OptConfig())
    spec = steps.input_specs(cfg, shape, opt_cfg)
    if shape.kind == "train":
        fn = steps.make_train_step(cfg, opt_cfg)
        in_sh = (
            shd.param_shardings(mesh, spec["params"]),
            shd.opt_shardings(mesh, spec["opt_state"]),
            shd.batch_shardings(mesh, spec["batch"]),
            shd.replicated(mesh),
        )
        out_sh = (in_sh[0], in_sh[1], shd.replicated(mesh))
        args = (spec["params"], spec["opt_state"], spec["batch"], spec["step"])
        # donate params/opt_state exactly as the production train loop does —
        # without it the dry-run double-counts the training state (in + out).
        return (
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1)),
            args,
        )
    elif shape.kind == "prefill":
        eff = effective_shape(cfg, shape)
        fn = steps.make_prefill_step(cfg, cache_len=eff.seq_len)
        cache_sh = shd.cache_shardings(mesh, steps.cache_specs(cfg, eff.global_batch, eff.seq_len))
        in_sh = (
            shd.param_shardings(mesh, spec["params"]),
            shd.batch_shardings(mesh, spec["batch"]),
        )
        # logits output: shard batch over dp, vocab over model
        from jax.sharding import PartitionSpec as P

        dp = shd.dp_axes(mesh) or None
        b = eff.global_batch
        logits_sh = shd.named(mesh, P(dp, "model"), (b, cfg.vocab_size))
        out_sh = (logits_sh, cache_sh)
        args = (spec["params"], spec["batch"])
    else:  # decode
        fn = steps.make_decode_step(cfg)
        from jax.sharding import PartitionSpec as P

        dp = shd.dp_axes(mesh) or None
        cache_sh = shd.cache_shardings(mesh, spec["caches"])
        b = shape.global_batch
        tok_sh = shd.named(mesh, P(dp), (b,))
        in_sh = (
            shd.param_shardings(mesh, spec["params"]),
            tok_sh,
            cache_sh,
            shd.replicated(mesh),
        )
        logits_sh = shd.named(mesh, P(dp, "model"), (b, cfg.vocab_size))
        out_sh = (logits_sh, cache_sh)
        args = (spec["params"], spec["token"], spec["caches"], spec["pos"])
        # serve loop donates the caches (in-place KV update)
        return (
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(2,)),
            args,
        )
    return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh), args


def _depth_variant(cfg, n_reps: int):
    """Unrolled n-pattern-rep config for exact per-layer HLO costing.

    XLA's cost_analysis visits while-loop (scan) bodies ONCE regardless of
    trip count (verified empirically), so the scanned model's numbers
    undercount by ~reps.  Costs are affine in depth, so two shallow
    unrolled lowerings give exact totals:
        total = c(1) + (reps - 1) * (c(2) - c(1)).
    """
    plen = len(cfg.pattern())
    over = dict(num_layers=plen * n_reps, scan_layers=False, name=cfg.name)
    if cfg.encoder_layers:
        # whisper: encoder depth == decoder depth, one combined slope
        assert cfg.encoder_layers == cfg.reps
        over["encoder_layers"] = n_reps
    return dataclasses.replace(cfg, **over)


def cost_dict(cost) -> dict:
    """Normalize Compiled.cost_analysis(): older jax returns a one-element
    list of dicts (per device), newer jax the dict itself."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def extrapolated_costs(cfg, shape, mesh) -> dict:
    samples = []
    for n in (1, 2):
        cfg_n = _depth_variant(cfg, n)
        jitted, args = build_lowerable(cfg_n, shape, mesh)
        with mesh:
            compiled = jitted.lower(*args).compile()
        cost = cost_dict(compiled.cost_analysis())
        coll = parse_collectives(compiled.as_text())
        samples.append(
            dict(
                flops=cost.get("flops", 0.0),
                bytes=cost.get("bytes accessed", 0.0),
                coll=coll["total_bytes"],
                wire=coll["wire_bytes"],
                by_op={k: v for k, v in coll.items() if k not in ("total_bytes", "wire_bytes", "counts")},
            )
        )
    c1, c2 = samples
    reps = cfg.reps

    def affine(a, b):
        return a + (reps - 1) * (b - a)

    by_op = {
        k: affine(c1["by_op"].get(k, 0), c2["by_op"].get(k, 0))
        for k in set(c1["by_op"]) | set(c2["by_op"])
    }
    return dict(
        flops_per_device=affine(c1["flops"], c2["flops"]),
        bytes_per_device=affine(c1["bytes"], c2["bytes"]),
        collective_bytes_per_device=affine(c1["coll"], c2["coll"]),
        wire_bytes_per_device=affine(c1["wire"], c2["wire"]),
        collective_by_op=by_op,
        method="unrolled-depth-extrapolation r1,r2",
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, report_dir: str = REPORT_DIR):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    os.makedirs(report_dir, exist_ok=True)
    out_path = os.path.join(report_dir, cell_id + ".json")
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": status}
    if status != "run":
        print(f"[dryrun] {cell_id}: {status}")
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        return record

    eff = effective_shape(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        jitted, args = build_lowerable(cfg, eff, mesh)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        record.update(
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops_per_device=cost.get("flops", 0.0),
            bytes_accessed_per_device=cost.get("bytes accessed", 0.0),
            collectives=coll,
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
                output_bytes=getattr(mem, "output_size_in_bytes", 0),
                temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
                peak_bytes=getattr(mem, "peak_memory_in_bytes", 0)
                or getattr(mem, "temp_size_in_bytes", 0),
                alias_bytes=getattr(mem, "alias_size_in_bytes", 0),
            ),
        )
        if not multi_pod:  # roofline table is single-pod; exact depth costs
            record["roofline_inputs"] = extrapolated_costs(cfg, eff, mesh)
        print(
            f"[dryrun] {cell_id}: OK  flops/dev={record['flops_per_device']:.3e} "
            f"coll={coll['total_bytes']:.3e}B  peak={record['memory']['peak_bytes']/2**30:.2f}GiB "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)"
        )
        # the two required proofs:
        print("  memory_analysis:", record["memory"])
        print("  cost_analysis: flops=%.4e bytes=%.4e" % (
            record["flops_per_device"], record["bytes_accessed_per_device"]))
        if "roofline_inputs" in record:
            ri = record["roofline_inputs"]
            print(
                "  extrapolated: flops=%.4e bytes=%.4e coll=%.4e"
                % (ri["flops_per_device"], ri["bytes_per_device"], ri["collective_bytes_per_device"])
            )
    except Exception as e:  # noqa: BLE001
        record["status"] = f"FAIL: {type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cell_id}: FAIL {type(e).__name__}: {str(e)[:400]}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args()
    if args.all:
        ok = True
        for arch in ARCH_NAMES:
            for shape_name in SHAPES:
                rec = run_cell(arch, shape_name, args.multi_pod, args.report_dir)
                ok &= not str(rec["status"]).startswith("FAIL")
        raise SystemExit(0 if ok else 1)
    assert args.arch and args.shape, "--arch/--shape or --all"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.report_dir)
    raise SystemExit(0 if not str(rec["status"]).startswith("FAIL") else 1)


if __name__ == "__main__":
    main()
