"""Regenerate the §Dry-run and §Roofline tables inside EXPERIMENTS.md from
reports/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report_experiments
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch import roofline

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
REPORT_DIR = os.path.join(ROOT, "reports", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def dryrun_table() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(REPORT_DIR, "*.json"))):
        r = json.load(open(path))
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        status = r["status"]
        if status == "run":
            status = "OK"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {status[:60]} | "
            f"{mem.get('peak_bytes', 0) / 2**30:.2f} | "
            f"{r.get('flops_per_device', 0):.2e} | "
            f"{coll.get('total_bytes', 0):.2e} | "
            f"{','.join(sorted((coll.get('counts') or {}).keys())) or '—'} |"
        )
    hdr = ("| arch | shape | mesh | status | peak GiB/dev | flops/dev (scanned) | "
           "coll B/dev (scanned) | collective kinds |\n|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def inject(md: str, marker: str, content: str) -> str:
    tag = f"<!-- {marker} -->"
    assert tag in md, marker
    return md.replace(tag, tag + "\n\n" + content)


def main():
    md = open(EXP).read()
    # remove previously injected content (regenerate idempotently) by
    # resetting to the section markers if present
    rows = roofline.load_all(REPORT_DIR, "pod16x16")
    roof = roofline.to_markdown(rows)
    md = inject(md, "DRYRUN_TABLE", dryrun_table())
    md = inject(md, "ROOFLINE_TABLE", roof)
    open(EXP, "w").write(md)
    print("EXPERIMENTS.md updated:",
          len(glob.glob(os.path.join(REPORT_DIR, "*.json"))), "cells")


if __name__ == "__main__":
    main()
