import os

if "--xla512" not in str(os.environ.get("_REPRO_PERF_MARK", "")):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration harness (§Perf): lower one cell with config overrides and
report the three roofline terms, so a hypothesis -> change -> measure
cycle is a single command.

  python -m repro.launch.perf --arch qwen3-moe-30b-a3b --shape train_4k \
      --set moe_dispatch=gather --set remat=False
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import effective_shape, get_config  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops  # noqa: E402


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def measure(arch: str, shape_name: str, overrides: dict, fullmem: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = effective_shape(cfg, SHAPES[shape_name])
    mesh = make_production_mesh()
    ri = dryrun.extrapolated_costs(cfg, shape, mesh)
    t_comp = ri["flops_per_device"] / PEAK_FLOPS
    t_mem = ri["bytes_per_device"] / HBM_BW
    t_coll = ri["collective_bytes_per_device"] / LINK_BW
    bound = max(t_comp, t_mem, t_coll)
    mf = model_flops(cfg, shape)
    out = dict(
        arch=arch,
        shape=shape_name,
        overrides=overrides,
        compute_s=t_comp,
        memory_s=t_mem,
        collective_s=t_coll,
        dominant=max([("compute", t_comp), ("memory", t_mem), ("collective", t_coll)],
                     key=lambda kv: kv[1])[0],
        useful_ratio=mf / (ri["flops_per_device"] * 256),
        roofline_fraction=(mf / 256 / PEAK_FLOPS) / bound if bound else 0.0,
        collective_by_op=ri["collective_by_op"],
    )
    if fullmem:
        jitted, args = dryrun.build_lowerable(cfg, shape, mesh)
        with mesh:
            compiled = jitted.lower(*args).compile()
        mem = compiled.memory_analysis()
        out["peak_gib"] = getattr(mem, "peak_memory_in_bytes", 0) / 2**30
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[], dest="overrides")
    ap.add_argument("--fullmem", action="store_true")
    args = ap.parse_args()
    overrides = dict(parse_override(kv) for kv in args.overrides)
    out = measure(args.arch, args.shape, overrides, fullmem=args.fullmem)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
