"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
device-count override to work and for tests to stay single-device.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types; 0.4.x has neither the kwarg
    # nor jax.sharding.AxisType, where Auto is already the default.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 per pod (256 v5e chips); 2 pods stack a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests, CPU runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return _make_mesh((data, model), ("data", "model"))
