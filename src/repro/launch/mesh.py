"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
device-count override to work and for tests to stay single-device.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 per pod (256 v5e chips); 2 pods stack a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests, CPU runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
