"""Serving driver: batched prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Production shape: a request batcher fills a fixed-size decode batch;
prefill runs per micro-batch and decode steps run lock-step across the
batch (continuous batching is a slot-swap on top of this loop).  The
same `decode_step` lowers for the decode_32k / long_500k dry-run cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch import steps as steps_lib
from repro.models import transformer as T


def serve(
    arch: str,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    reduced: bool = True,
    seed: int = 0,
    greedy: bool = True,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cache_len = prompt_len + gen
    rng = np.random.default_rng(seed)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))

    batch_inputs = {
        "tokens": jnp.asarray(
            rng.integers(1, cfg.vocab_size - 1, (batch, prompt_len)), jnp.int32
        )
    }
    if cfg.num_image_tokens:
        batch_inputs["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_image_tokens, cfg.d_model)), jnp.float32
        )
    if cfg.encoder_layers:
        batch_inputs["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )

    prefill = jax.jit(steps_lib.make_prefill_step(cfg, cache_len))
    decode = jax.jit(steps_lib.make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, batch_inputs)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [token]
    t_prefill = time.time() - t0

    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, token, caches, jnp.int32(prompt_len + i))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    generated = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    return {
        "generated": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    res = serve(args.arch, args.batch, args.prompt_len, args.gen, reduced=not args.full)
    print(f"prefill {res['prefill_s']:.2f}s  decode {res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s)")
    print("sample tokens:", res["generated"][0][:12])


if __name__ == "__main__":
    main()
