"""Sharded, atomic, async checkpointing with auto-resume.

Layout:
  <dir>/step_<n>.tmp/...   (in-flight write)
  <dir>/step_<n>/
      manifest.json        step, leaf paths/shapes/dtypes, mesh metadata
      <leaf-key>.npy       one file per pytree leaf (host-local shard on
                           multi-host; full array in single-process runs)
  <dir>/LATEST             text file with the newest complete step

Atomicity: write into step_<n>.tmp then os.rename -> a crash mid-write
never corrupts a restorable checkpoint.  Async: `save(..., blocking=False)`
snapshots leaves to host memory synchronously (cheap vs device->host copy
of a training state we already fetched) and writes in a daemon thread;
`wait()` joins before the next save to bound in-flight state.

Elastic restore: checkpoints store LOGICAL arrays (per host), so a
restore under a different mesh shape just re-shards via device_put with
the new sharding — mesh-agnostic by construction.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path).replace("/", "_").replace(" ", "")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = True, extra: dict | None = None):
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        host_leaves = [(_leaf_key(p), np.asarray(x)) for p, x in leaves]
        self.wait()
        if blocking:
            self._write(step, host_leaves, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, extra or {}), daemon=True
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, extra):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": [], "extra": extra}
        for key, arr in host_leaves:
            dtype = str(arr.dtype)
            if dtype == "bfloat16":  # numpy can't serialize ml_dtypes natively
                np.save(os.path.join(tmp, key + ".npy"), arr.view(np.uint16))
            else:
                np.save(os.path.join(tmp, key + ".npy"), arr)
            manifest["leaves"].append(
                {"key": key, "shape": list(arr.shape), "dtype": dtype}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "LATEST"), "w") as f:
            f.write(str(step))
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, example_state, shardings=None):
        """Restore into the structure of `example_state` (shapes must match);
        `shardings` (same pytree) re-shards for the CURRENT mesh (elastic)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        dtypes = {leaf["key"]: leaf["dtype"] for leaf in manifest["leaves"]}
        paths, treedef = jax.tree_util.tree_flatten_with_path(example_state)
        arrays = []
        for p, ex in paths:
            key = _leaf_key(p)
            arr = np.load(os.path.join(d, key + ".npy"))
            if dtypes.get(key) == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            assert tuple(arr.shape) == tuple(ex.shape), (key, arr.shape, ex.shape)
            arrays.append(arr.astype(ex.dtype))
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(example_state), arrays
        )
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state, manifest
