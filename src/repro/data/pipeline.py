"""Deterministic synthetic data pipeline with host sharding + prefetch.

Design goals mirrored from production loaders:
  * deterministic as a function of (seed, step, host) — restart-safe, so
    checkpoint resume replays the exact same stream with no state file;
  * host-sharded: each host materializes only its slice of the global
    batch (global_batch // num_hosts rows);
  * background prefetch thread with a bounded queue.

The "dataset" is a Zipf-ish synthetic token stream (cheap, stationary,
non-trivial unigram distribution so losses are meaningful); frontends
for VLM/audio stubs emit deterministic pseudo-embeddings.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticStream:
    def __init__(
        self,
        cfg: ModelConfig,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        assert global_batch % num_hosts == 0
        self.cfg = cfg
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts

    def _rng(self, step: int) -> np.random.Generator:
        seq = np.random.SeedSequence([self.seed, step, self.host_id, 0xDA7A])
        return np.random.Generator(np.random.Philox(seq))

    def batch_at(self, step: int) -> dict:
        """The batch for a given global step (pure function of step)."""
        rng = self._rng(step)
        v = self.cfg.vocab_size
        ranks = rng.zipf(1.3, size=(self.local_batch, self.seq_len)).astype(np.int64)
        tokens = (ranks % (v - 2)) + 1  # avoid 0 (pad) / v-1 (reserved)
        out = {"tokens": tokens.astype(np.int32)}
        if self.cfg.num_image_tokens:
            out["image_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.num_image_tokens, self.cfg.d_model), np.float32
            )
        if self.cfg.encoder_layers:
            out["frames"] = rng.standard_normal(
                (self.local_batch, self.cfg.encoder_seq, self.cfg.d_model), np.float32
            )
        return out

    def iterate(self, start_step: int = 0, prefetch: int = 2):
        """Background-prefetched iterator from `start_step` on."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put((step, self.batch_at(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
