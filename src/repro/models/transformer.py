"""Pattern-scanned decoder LM covering all assigned families.

The model is a scan over `cfg.reps` repetitions of `cfg.pattern()`; every
pattern position has its own stacked parameter pytree (leading dim =
reps), so the compiled graph contains exactly one pattern body — the
compile-time trick that makes 61-72-layer trillion-param configs
lowerable on the CPU dry-run host and fast to compile in production.

Entry points:
  init_params(cfg, key)                      parameter pytree
  forward(params, cfg, batch)                full-seq logits + aux (train)
  prefill(params, cfg, batch, cache_len)     logits at last pos + caches
  decode_step(params, cfg, token, caches, pos)  one-token serve step
  encoder_forward(params, cfg, frames)       whisper encoder (conv stub in)

Caches are pytrees aligned with the scanned params: leading dim = reps.
  attn  : {"k": (reps,B,L,KV,hd), "v": ...}
  mamba : {"conv": (reps,B,W-1,xbc), "state": (reps,B,H,P,N)}
  cross : {"k": (reps,B,S_enc,KV,hd), "v": ...}  (precomputed at prefill)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import layers as L
from repro.models import ssm

COMPUTE_DTYPE = L.COMPUTE_DTYPE


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, mixer: str, ffn: str):
    ks = jax.random.split(key, 6)
    p = {"ln1": L.rmsnorm_init(cfg.d_model)}
    if mixer in ("attn", "attn_nc", "cross"):
        p["mixer"] = L.attn_init(ks[0], cfg)
    elif mixer == "attn_cross":
        p["mixer"] = L.attn_init(ks[0], cfg)
        p["ln_cross"] = L.rmsnorm_init(cfg.d_model)
        p["cross"] = L.attn_init(ks[1], cfg)
    elif mixer == "mamba":
        p["mixer"] = ssm.mamba_init(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(mixer)
    if ffn == "mlp":
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = L.mlp_init(ks[2], cfg)
    elif ffn == "moe":
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = L.moe_init(ks[3], cfg)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    pattern = cfg.pattern()
    keys = jax.random.split(key, len(pattern) + 4)
    params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(
            jnp.float32
        ),
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "blocks": [],
    }
    for i, (mixer, ffn) in enumerate(pattern):
        stack = jax.vmap(lambda k, m=mixer, f=ffn: _block_init(k, cfg, m, f))(
            jax.random.split(keys[i], cfg.reps)
        )
        params["blocks"].append(stack)
    if not cfg.tie_embeddings:
        params["lm_head"] = L._he(keys[-2], (cfg.d_model, cfg.vocab_size), cfg.d_model)
    if cfg.encoder_layers:  # whisper-style encoder over precomputed frames
        enc_keys = jax.random.split(keys[-3], 2)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _block_init(k, cfg, "attn_nc", "mlp"))(
                jax.random.split(enc_keys[0], cfg.encoder_layers)
            ),
            "final_norm": L.rmsnorm_init(cfg.d_model),
        }
    if cfg.param_dtype != "float32":
        dt = jnp.dtype(cfg.param_dtype)
        params = jax.tree.map(lambda x: x.astype(dt), params)
    return params


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------


def _apply_block(cfg, mixer, ffn, p, x, positions, enc_out, causal=True):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    aux = jnp.float32(0)
    if mixer in ("attn", "attn_nc"):
        out = L.attention(p["mixer"], cfg, h, positions, causal=mixer == "attn")
    elif mixer == "cross":
        out = L.attention(p["mixer"], cfg, h, positions, kv=enc_out)
    elif mixer == "attn_cross":
        out = L.attention(p["mixer"], cfg, h, positions, causal=True)
        x = x + out
        h2 = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        out = L.attention(p["cross"], cfg, h2, positions, kv=enc_out)
    elif mixer == "mamba":
        out, _ = ssm.mamba_forward(p["mixer"], cfg, h)
    else:  # pragma: no cover
        raise ValueError(mixer)
    x = x + out
    if ffn != "none":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            out, aux = L.moe(p["ffn"], cfg, h)
        else:
            out = L.mlp(p["ffn"], h)
        x = x + out
    return x, aux


def _rep_slice(blocks, r):
    """Per-rep parameter slices from the stacked block pytrees."""
    return tuple(jax.tree.map(lambda x: x[r], stack) for stack in blocks)


def _scan_blocks(cfg: ModelConfig, params, x, positions, enc_out):
    pattern = cfg.pattern()

    def body(carry, p_slices):
        h, aux = carry
        h = sharding.maybe_constrain(h, "tokens_act")  # batch stays on DP
        for i, (mixer, ffn) in enumerate(pattern):
            h, a = _apply_block(cfg, mixer, ffn, p_slices[i], h, positions, enc_out)
            aux = aux + a
        return (h, aux), None

    # One checkpoint per pattern repetition: measured on the 398B jamba
    # dry-run, XLA's scheduler keeps the intra-rep backward working set
    # ~0.1 GiB already, so nested per-block remat only added ~19% flops —
    # rejected (see EXPERIMENTS.md §Perf).
    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), tuple(params["blocks"]))
        return x, aux
    carry = (x, jnp.float32(0))
    for r in range(cfg.reps):  # unrolled: exact per-layer HLO costs
        carry, _ = body_fn(carry, _rep_slice(params["blocks"], r))
    return carry


# ---------------------------------------------------------------------------
# public: training / scoring forward
# ---------------------------------------------------------------------------


def encoder_forward(params, cfg: ModelConfig, frames):
    """frames: (B, S_enc, D) precomputed conv-frontend embeddings (stub)."""
    x = frames.astype(COMPUTE_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    enc = params["encoder"]

    def body(carry, p_slice):
        h = carry
        h, _ = _apply_block(cfg, "attn_nc", "mlp", p_slice, h, positions, None)
        return h, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, enc["blocks"])
    else:
        for r in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[r], enc["blocks"]))
    return L.rmsnorm(x, enc["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch):
    """batch: tokens (B,S) [+ image_embeds | frames].  Returns (logits, aux)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(COMPUTE_DTYPE)
    x = sharding.maybe_constrain(x, "tokens_act")
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encoder_forward(params, cfg, batch["frames"])
    elif cfg.num_image_tokens:
        enc_out = batch["image_embeds"].astype(COMPUTE_DTYPE)
    x, aux = _scan_blocks(cfg, params, x, positions, enc_out)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head.astype(COMPUTE_DTYPE)
    logits = sharding.maybe_constrain(logits, "logits")
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux = forward(params, cfg, batch)
    targets = batch["tokens"][:, 1:]
    logits = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + one-token decode
# ---------------------------------------------------------------------------


def _init_cache_slice(cfg: ModelConfig, mixer, batch, cache_len, enc_len):
    kv, hd = cfg.num_kv_heads, cfg.hd
    if mixer in ("attn", "attn_nc"):
        shape = (batch, cache_len, kv, hd)
        return {"k": jnp.zeros(shape, COMPUTE_DTYPE), "v": jnp.zeros(shape, COMPUTE_DTYPE)}
    if mixer in ("cross", "attn_cross"):
        c = {
            "ck": jnp.zeros((batch, enc_len, kv, hd), COMPUTE_DTYPE),
            "cv": jnp.zeros((batch, enc_len, kv, hd), COMPUTE_DTYPE),
        }
        if mixer == "attn_cross":
            c["k"] = jnp.zeros((batch, cache_len, kv, hd), COMPUTE_DTYPE)
            c["v"] = jnp.zeros((batch, cache_len, kv, hd), COMPUTE_DTYPE)
        return c
    if mixer == "mamba":
        return {
            "conv": jnp.zeros(
                (batch, cfg.ssm_conv_width - 1, cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                COMPUTE_DTYPE,
            ),
            "state": jnp.zeros(
                (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), COMPUTE_DTYPE
            ),
        }
    raise ValueError(mixer)  # pragma: no cover


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int = 0):
    """Zeroed cache pytree, stacked (reps, ...) per pattern position."""
    caches = []
    for mixer, _ in cfg.pattern():
        slice_ = _init_cache_slice(cfg, mixer, batch, cache_len, max(enc_len, 1))
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x[None], (cfg.reps,) + x.shape), slice_))
    return caches


def _prefill_block(cfg, mixer, ffn, p, x, positions, enc_out, cache, cache_len):
    """Like _apply_block but fills the caches."""
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None
    s = x.shape[1]
    if mixer in ("attn", "attn_nc", "attn_cross"):
        q, k, v = L._project_qkv(p["mixer"], cfg, h, h)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        out = L._sdpa(q, k, v, cfg, causal=mixer != "attn_nc")
        out = out.reshape(*x.shape[:-1], -1) @ p["mixer"]["wo"].astype(COMPUTE_DTYPE)
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        if mixer == "attn_cross":
            x = x + out
            h2 = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
            _, ck, cv = L._project_qkv(p["cross"], cfg, h2, enc_out)
            new_cache["ck"], new_cache["cv"] = ck, cv
            q2, _, _ = L._project_qkv(p["cross"], cfg, h2, h2[:, :1])
            out = L._sdpa(q2, ck, cv, cfg, causal=False)
            out = out.reshape(*x.shape[:-1], -1) @ p["cross"]["wo"].astype(COMPUTE_DTYPE)
    elif mixer == "cross":
        _, ck, cv = L._project_qkv(p["mixer"], cfg, h, enc_out)
        new_cache["ck"], new_cache["cv"] = ck, cv
        q, _, _ = L._project_qkv(p["mixer"], cfg, h, h[:, :1])
        out = L._sdpa(q, ck, cv, cfg, causal=False)
        out = out.reshape(*x.shape[:-1], -1) @ p["mixer"]["wo"].astype(COMPUTE_DTYPE)
    elif mixer == "mamba":
        out, (conv_hist, state) = ssm.mamba_forward(p["mixer"], cfg, h)
        new_cache["conv"], new_cache["state"] = conv_hist, state
    else:  # pragma: no cover
        raise ValueError(mixer)
    x = x + out
    aux = jnp.float32(0)
    if ffn != "none":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        out, aux = (L.moe(p["ffn"], cfg, h) if ffn == "moe" else (L.mlp(p["ffn"], h), aux))
        x = x + out
    return x, new_cache


def prefill(params, cfg: ModelConfig, batch, cache_len: int):
    """Run the prompt, return (last-position logits, caches)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(COMPUTE_DTYPE)
    x = sharding.maybe_constrain(x, "tokens_act")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_out = None
    enc_len = 0
    if cfg.encoder_layers:
        enc_out = encoder_forward(params, cfg, batch["frames"])
        enc_len = enc_out.shape[1]
    elif cfg.num_image_tokens:
        enc_out = batch["image_embeds"].astype(COMPUTE_DTYPE)
        enc_len = enc_out.shape[1]
    caches = init_cache(cfg, b, cache_len, enc_len)
    pattern = cfg.pattern()

    def body(carry, scanned):
        h = carry
        h = sharding.maybe_constrain(h, "tokens_act")
        p_slices, c_slices = scanned
        new_cs = []
        for i, (mixer, ffn) in enumerate(pattern):
            h, nc = _prefill_block(cfg, mixer, ffn, p_slices[i], h, positions, enc_out, c_slices[i], cache_len)
            new_cs.append(nc)
        return h, tuple(new_cs)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body_fn, x, (tuple(params["blocks"]), tuple(caches)))
    else:
        reps_out = []
        for r in range(cfg.reps):
            c_r = tuple(jax.tree.map(lambda t: t[r], c) for c in caches)
            x, nc = body_fn(x, (_rep_slice(params["blocks"], r), c_r))
            reps_out.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *reps_out)
    x = L.rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head.astype(COMPUTE_DTYPE)
    return logits[:, 0], list(new_caches)


def _decode_block(cfg, mixer, ffn, p, x, enc_out, cache, pos):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if mixer in ("attn", "attn_nc", "attn_cross"):
        out, nk, nv = L.attention_decode(p["mixer"], cfg, h, cache["k"], cache["v"], pos)
        new_cache["k"], new_cache["v"] = nk, nv
        if mixer == "attn_cross":
            x = x + out
            h2 = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
            b = x.shape[0]
            q, _, _ = L._project_qkv(p["cross"], cfg, h2, h2)
            outc = L._sdpa(q, cache["ck"], cache["cv"], cfg, causal=False)
            out = outc.reshape(b, 1, -1) @ p["cross"]["wo"].astype(COMPUTE_DTYPE)
    elif mixer == "cross":
        b = x.shape[0]
        q, _, _ = L._project_qkv(p["mixer"], cfg, h, h)
        outc = L._sdpa(q, cache["ck"], cache["cv"], cfg, causal=False)
        out = outc.reshape(b, 1, -1) @ p["mixer"]["wo"].astype(COMPUTE_DTYPE)
    elif mixer == "mamba":
        out, (conv, state) = ssm.mamba_decode(p["mixer"], cfg, h, cache["conv"], cache["state"])
        new_cache["conv"], new_cache["state"] = conv, state
    else:  # pragma: no cover
        raise ValueError(mixer)
    x = x + out
    if ffn != "none":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        out = L.moe(p["ffn"], cfg, h)[0] if ffn == "moe" else L.mlp(p["ffn"], h)
        x = x + out
    return x, new_cache


def decode_step(params, cfg: ModelConfig, token, caches, pos):
    """token: (B,) int32; pos: scalar int32 (next position to fill).

    Returns (logits (B, V), new caches)."""
    x = params["embed"][token][:, None, :].astype(COMPUTE_DTYPE)
    x = sharding.maybe_constrain(x, "tokens_act")
    pattern = cfg.pattern()

    def body(carry, scanned):
        h = carry
        h = sharding.maybe_constrain(h, "tokens_act")
        p_slices, c_slices = scanned
        new_cs = []
        for i, (mixer, ffn) in enumerate(pattern):
            h, nc = _decode_block(cfg, mixer, ffn, p_slices[i], h, None, c_slices[i], pos)
            new_cs.append(nc)
        return h, tuple(new_cs)

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (tuple(params["blocks"]), tuple(caches)))
    else:
        reps_out = []
        for r in range(cfg.reps):
            c_r = tuple(jax.tree.map(lambda t: t[r], c) for c in caches)
            x, nc = body(x, (_rep_slice(params["blocks"], r), c_r))
            reps_out.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *reps_out)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = (x @ head.astype(COMPUTE_DTYPE))[:, 0]
    return logits, list(new_caches)
