"""Mamba2 / SSD (state-space duality) blocks, chunked-scan training form
and O(1)-state decode form.  Follows the minimal-SSD formulation of
Mamba2 (arXiv:2405.21060): per chunk a dense (L x L) decay-masked
attention-like product, plus an inter-chunk state recurrence.

Shapes: x (B, S, H, P) heads x head_dim, B/C (B, S, G, N) groups x state,
dt (B, S, H), A (H,) negative decay rates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, _he, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum_decay(a_cs):
    """L[i, j] = exp(a_cs[i] - a_cs[j]) for i >= j else 0.  a_cs: (..., L)."""
    li = a_cs[..., :, None]
    lj = a_cs[..., None, :]
    mask = jnp.tril(jnp.ones((a_cs.shape[-1],) * 2, bool))
    return jnp.where(mask, jnp.exp(li - lj), 0.0)


def ssd_chunked_grouped(xb, dA, Bg, Cg, chunk: int, init_state=None):
    """Group-factored chunked SSD (§Perf 'grouped' impl).

    xb: (B,S,H,P); dA: (B,S,H); Bg/Cg: (B,S,G,N) kept at GROUP rank.
    vs the baseline: (i) B/C are never repeated to per-head rank — the
    C·B^T score matrices are computed ONCE PER GROUP and shared by the
    H/G heads of the group (identical by construction), cutting both the
    dominant einsum flops and the (B,S,H,N) HBM traffic by H/G; (ii) the
    decay mask is exponentiated in bf16.
    """
    b, s, h, p = xb.shape
    g = Bg.shape[2]
    n = Bg.shape[-1]
    hh = h // g
    nc = s // chunk
    xc = xb.reshape(b, nc, chunk, g, hh, p)
    dAc = dA.reshape(b, nc, chunk, g, hh).astype(jnp.float32)
    Bc = Bg.reshape(b, nc, chunk, g, n)
    Cc = Cg.reshape(b, nc, chunk, g, n)

    a_cs = jnp.cumsum(dAc, axis=2)  # (b,c,l,g,hh)
    a_total = a_cs[:, :, -1]  # (b,c,g,hh)

    # per-group scores shared by the group's heads
    scores = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc)  # (b,c,g,l,s)
    a_sw = jnp.moveaxis(a_cs, 2, -1)  # (b,c,g,hh,l)
    L = _segsum_decay(a_sw).astype(COMPUTE_DTYPE)  # (b,c,g,hh,l,s)
    y_diag = jnp.einsum("bcgls,bcghls,bcsghp->bclghp", scores, L, xc)

    decay_to_end = jnp.exp(a_total[:, :, None] - a_cs).astype(COMPUTE_DTYPE)  # (b,c,l,g,hh)
    chunk_states = jnp.einsum("bclgn,bclgh,bclghp->bcghpn", Bc, decay_to_end, xc)

    if init_state is None:
        init_state = jnp.zeros((b, g, hh, p, n), COMPUTE_DTYPE)
    elif init_state.ndim == 4:  # (b,h,p,n) cache layout
        init_state = init_state.reshape(b, g, hh, p, n)

    def step(state, inp):
        s_c, a_tot = inp
        new = state * jnp.exp(a_tot)[..., None, None].astype(COMPUTE_DTYPE) + s_c
        return new, state

    final_state, prev_states = jax.lax.scan(
        step,
        init_state,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(a_total, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,g,hh,p,n)
    state_decay = jnp.exp(a_cs).astype(COMPUTE_DTYPE)  # (b,c,l,g,hh)
    y_off = jnp.einsum("bclgn,bcghpn,bclgh->bclghp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state.reshape(b, h, p, n)


def ssd_chunked(xb, dA, Bh, Ch, chunk: int, init_state=None):
    """Chunked SSD scan.

    xb: (B,S,H,P) dt-scaled inputs; dA: (B,S,H); Bh/Ch: (B,S,H,N)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = xb.shape
    n = Bh.shape[-1]
    nc = s // chunk
    xc = xb.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)

    a_cs = jnp.cumsum(dAc, axis=2)  # inclusive (b,c,l,h)
    a_total = a_cs[:, :, -1, :]  # (b,c,h)

    # intra-chunk ("diagonal") term
    L = _segsum_decay(jnp.moveaxis(a_cs, -1, -2))  # (b,c,h,l,l)
    Ldt = L.astype(COMPUTE_DTYPE)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)  # (b,c,h,l,s)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, Ldt, xc)

    # per-chunk end states
    decay_to_end = jnp.exp(a_total[:, :, None, :] - a_cs).astype(COMPUTE_DTYPE)  # (b,c,l,h)
    chunk_states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bc, decay_to_end, xc)

    # inter-chunk recurrence (scan over chunks)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), COMPUTE_DTYPE)

    def step(state, inp):
        s_c, a_tot = inp  # (b,h,p,n), (b,h)
        prev = state
        new = prev * jnp.exp(a_tot)[:, :, None, None].astype(COMPUTE_DTYPE) + s_c
        return new, prev  # emit the state *entering* this chunk

    a_tot_sw = jnp.moveaxis(a_total, 1, 0)  # (c,b,h)
    cs_sw = jnp.moveaxis(chunk_states, 1, 0)  # (c,b,h,p,n)
    final_state, prev_states = jax.lax.scan(step, init_state, (cs_sw, a_tot_sw))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,h,p,n)

    # off-diagonal (carried state) term
    state_decay = jnp.exp(a_cs).astype(COMPUTE_DTYPE)  # decay from chunk start
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def ssd_decode_step(state, x_t, dA_t, B_t, C_t):
    """One-token SSD update.  state (B,H,P,N); x_t (B,H,P); dA_t (B,H);
    B_t/C_t (B,H,N).  Returns (y_t (B,H,P), new_state)."""
    decay = jnp.exp(dA_t.astype(jnp.float32))[:, :, None, None].astype(COMPUTE_DTYPE)
    outer = x_t[..., :, None] * B_t[..., None, :]  # (B,H,P,N)
    new_state = state * decay + outer
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C_t)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 mixer block
# ---------------------------------------------------------------------------


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width
    xbc = di + 2 * g * n
    proj = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _he(ks[0], (d, proj), d),
        "conv_w": _he(ks[1], (w, xbc), w),
        "conv_b": jnp.zeros((xbc,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "skip_d": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": _he(ks[2], (di, d), di),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * g * n]
    dt = proj[..., 2 * di + 2 * g * n :]
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc):
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    x = xbc[..., :di]
    B = xbc[..., di : di + g * n]
    C = xbc[..., di + g * n :]
    return x, B, C


def _causal_conv(xbc, conv_w, conv_b, history=None):
    """Depthwise causal conv over time; xbc (B, S, Cdim), conv_w (W, Cdim).

    history: (B, W-1, Cdim) left context (decode/prefill continuity)."""
    w = conv_w.shape[0]
    if history is None:
        history = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([history, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i].astype(xbc.dtype) for i in range(w)
    )
    return out + conv_b.astype(xbc.dtype), xp[:, -(w - 1) :, :]


def _expand_groups(cfg: ModelConfig, bc):
    """(B, S, G*N) -> per-head (B, S, H, N) by repeating groups."""
    b, s = bc.shape[:2]
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    bc = bc.reshape(b, s, g, n)
    return jnp.repeat(bc, h // g, axis=2)


def mamba_forward(p, cfg: ModelConfig, x, init_state=None, conv_history=None):
    """Full-sequence mixer.  x: (B, S, D) bf16.  Returns (y, (conv_hist, state)).

    Sequences are padded (at the end) to a chunk multiple; padded steps
    have dt forced to 0, so they neither decay nor feed the state — the
    returned state is exactly the post-last-real-token state.
    """
    b, s, d = x.shape
    h_heads, hp = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(COMPUTE_DTYPE)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_history)
    xbc = jax.nn.silu(xbc)
    xi, B, C = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    pad = (-s) % cfg.ssm_chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> identity step
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    A = -jnp.exp(p["a_log"])  # (H,)
    dA = dt * A  # (B,Sp,H)
    xh = xi.reshape(b, sp, h_heads, hp)
    xb = xh * dt[..., None].astype(COMPUTE_DTYPE)
    if cfg.ssm_impl == "grouped":
        g, n = cfg.ssm_groups, cfg.ssm_state
        y, state = ssd_chunked_grouped(
            xb, dA, B.reshape(b, sp, g, n), C.reshape(b, sp, g, n),
            cfg.ssm_chunk, init_state,
        )
    else:
        Bh = _expand_groups(cfg, B)
        Ch = _expand_groups(cfg, C)
        y, state = ssd_chunked(xb, dA, Bh, Ch, cfg.ssm_chunk, init_state)
    y = y[:, :s]
    xh = xh[:, :s]
    y = y + xh * p["skip_d"][None, None, :, None].astype(COMPUTE_DTYPE)
    y = y.reshape(b, s, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(COMPUTE_DTYPE), (conv_hist, state)


def mamba_decode(p, cfg: ModelConfig, x, conv_history, state):
    """One-token mixer.  x (B, 1, D).  Returns (y, (conv_hist, state))."""
    b = x.shape[0]
    h_heads, hp = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(COMPUTE_DTYPE)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_history)
    xbc = jax.nn.silu(xbc)
    xi, B, C = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["a_log"])
    dA = dt * A  # (B,H)
    xh = xi.reshape(b, h_heads, hp)
    xb = xh * dt[..., None].astype(COMPUTE_DTYPE)
    Bh = _expand_groups(cfg, B)[:, 0]  # (B,H,N)
    Ch = _expand_groups(cfg, C)[:, 0]
    y, state = ssd_decode_step(state, xb, dA, Bh, Ch)
    y = y + xh * p["skip_d"][None, :, None].astype(COMPUTE_DTYPE)
    y = y.reshape(b, 1, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(COMPUTE_DTYPE), (conv_hist, state)
