"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU, MoE.

Pure JAX, param pytrees are plain dicts.  Compute runs in bf16 (params
are cast at use), reductions in fp32.  All functions are batch-agnostic
over leading dims of `x` (B, S, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding

COMPUTE_DTYPE = jnp.bfloat16


def _he(key, shape, fan_in):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d):
    return jnp.ones((d,), jnp.float32)


def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale).astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta):
    """x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (self / cross), optional qk-norm
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _he(ks[0], (d, h * hd), d),
        "wk": _he(ks[1], (d, kv * hd), d),
        "wv": _he(ks[2], (d, kv * hd), d),
        "wo": _he(ks[3], (h * hd, d), h * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _project_qkv(p, cfg: ModelConfig, xq, xkv):
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (xq @ p["wq"].astype(COMPUTE_DTYPE)).reshape(*xq.shape[:-1], h, hd)
    k = (xkv @ p["wk"].astype(COMPUTE_DTYPE)).reshape(*xkv.shape[:-1], kv, hd)
    v = (xkv @ p["wv"].astype(COMPUTE_DTYPE)).reshape(*xkv.shape[:-1], kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, cfg: ModelConfig, causal: bool, q_offset=0):
    """q: (B,Sq,H,hd) k,v: (B,Sk,KV,hd).  GQA: H = KV * rep."""
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd).astype(np.float32)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs, v)
    return out.reshape(b, sq, h, hd)


def attention(p, cfg: ModelConfig, x, positions, causal=True, kv=None, kv_positions=None):
    """Self (kv=None) or cross attention.  Returns (B, S, D)."""
    xkv = kv if kv is not None else x
    q, k, v = _project_qkv(p, cfg, x, xkv)
    if kv is None:  # self-attn: rotary on both
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    out = _sdpa(q, k, v, cfg, causal=causal and kv is None)
    return out.reshape(*x.shape[:-1], -1) @ p["wo"].astype(COMPUTE_DTYPE)


def attention_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos):
    """One-token decode: x (B, 1, D), cache (B, L, KV, hd), pos scalar.

    Returns (out, new_k, new_v) with the caches updated in place at pos.
    """
    q, k, v = _project_qkv(p, cfg, x, x)
    positions = jnp.full((x.shape[0], 1), pos)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    b, _, h, hd = q.shape
    kvh = cache_k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, hd)
    scores = jnp.einsum("bgrh,bkgh->bgrk", qg, cache_k.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    scores = scores / np.sqrt(hd).astype(np.float32)
    valid = jnp.arange(cache_k.shape[1])[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bgrk,bkgh->bgrh", probs, cache_v.astype(COMPUTE_DTYPE))
    out = out.reshape(b, 1, h * hd) @ p["wo"].astype(COMPUTE_DTYPE)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi": _he(ks[0], (d, f), d),
        "wg": _he(ks[1], (d, f), d),
        "wo": _he(ks[2], (f, d), f),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["wg"].astype(COMPUTE_DTYPE)) * (x @ p["wi"].astype(COMPUTE_DTYPE))
    return h @ p["wo"].astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# MoE: top-k routing, sort-based capacity dispatch (EP-shardable)
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _he(ks[0], (d, e), d),
        "wi": _he(ks[1], (e, d, f), d),
        "wg": _he(ks[2], (e, d, f), d),
        "wo": _he(ks[3], (e, f, d), f),
    }


def moe_local(p, cfg: ModelConfig, x, n_blocks: int | None = None):
    """Token-local MoE dispatch (§Perf 'local'): route within DP blocks.

    The global-sort dispatch gathers across the full token axis with
    replicated indices, which SPMD lowers into full-tensor all-reduces
    (measured 23 TB/device/step on qwen3-moe train_4k).  Here tokens are
    split into `n_blocks` blocks (sharded over DP); every sort/gather is
    block-local, so the only cross-device traffic is resharding the
    (blocks, E, cap, d) buffer from block-major to expert-major — a
    single all-to-all.  Capacity is per (block, expert), i.e. slightly
    stricter load-balance pressure than global capacity (standard for EP
    systems).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    nb = n_blocks or min(32, b)  # dp-granularity blocks
    while t % nb:
        nb //= 2
    tl = t // nb
    cap = int(np.ceil(tl * k / e * cfg.capacity_factor))
    xt = x.reshape(nb, tl, d)
    xt = sharding.maybe_constrain(xt, "moe_tokens_local")

    logits = jnp.einsum("btd,de->bte", xt, p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (nb, tl, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    density = jnp.mean(jax.nn.one_hot(top_e[..., 0], e), axis=(0, 1))
    aux = jnp.sum(density * jnp.mean(probs, axis=(0, 1))) * e

    flat_e = top_e.reshape(nb, tl * k)
    order = jnp.argsort(flat_e, axis=-1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    seg_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    seg_end = jnp.concatenate([seg_start[:, 1:], jnp.full((nb, 1), tl * k)], axis=1)
    pos_in_e = jnp.arange(tl * k)[None] - jnp.take_along_axis(seg_start, sorted_e, axis=-1)
    keep = pos_in_e < cap
    tok_of = order // k

    # dispatch: compose indices in int space -> ONE d-wide gather
    gidx = seg_start[:, :, None] + jnp.arange(cap)[None, None, :]  # (nb, e, cap)
    valid = gidx < seg_end[:, :, None]
    gidx = jnp.minimum(gidx, tl * k - 1).reshape(nb, e * cap)
    comp_idx = jnp.take_along_axis(tok_of, gidx, axis=1)  # slot -> source token
    buf = jnp.take_along_axis(xt.astype(COMPUTE_DTYPE), comp_idx[..., None], axis=1)
    buf = jnp.where(valid.reshape(nb, e * cap, 1), buf, 0).reshape(nb, e, cap, d)
    buf = sharding.maybe_constrain(buf, "moe_buffer_local")  # <- the all-to-all

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"].astype(COMPUTE_DTYPE)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["wi"].astype(COMPUTE_DTYPE))
    h = sharding.maybe_constrain(h, "moe_hidden_local")
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"].astype(COMPUTE_DTYPE))
    out_buf = sharding.maybe_constrain(out_buf, "moe_buffer_local")

    # combine: token-major slot ids (int gathers) -> ONE d-wide gather;
    # top_w is already token-major, so no weight permutation either.
    flat_out = out_buf.reshape(nb, e * cap, d)
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, 0)  # (nb, tl*k) sorted-major
    inv_order = jnp.argsort(order, axis=-1)
    slot_tm = jnp.take_along_axis(slot, inv_order, axis=-1)
    keep_tm = jnp.take_along_axis(keep, inv_order, axis=-1)
    gathered = jnp.take_along_axis(flat_out, slot_tm[..., None], axis=1)
    gathered = jnp.where(keep_tm[..., None], gathered, 0)
    w_tm = top_w.reshape(nb, tl * k).astype(COMPUTE_DTYPE)
    out = (gathered * w_tm[..., None]).reshape(nb, tl, k, d).sum(axis=2)
    return out.reshape(b, s, d), aux


def moe(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss.

    Sort-based dispatch with per-expert capacity C = k*T/E * cap_factor:
    assignments are sorted by expert id, each expert takes its first C
    tokens (standard dropping MoE).  The (E, C, D) buffer is the tensor
    sharded over the expert-parallel axis.

    Two dispatch lowerings (cfg.moe_dispatch):
      "scatter" — baseline: scatter into the expert buffer, scatter-add
          the combine.  SPMD lowers scatters into sharded operands as
          all-reduces over the FULL buffer (measured 15.7 TB/device/step
          on jamba train_4k — see EXPERIMENTS.md §Perf).
      "gather"  — dispatch via per-expert segment gathers and combine via
          the inverse permutation + reshape-sum: no scatter anywhere, so
          the partitioner emits all-to-all-style resharding instead of
          buffer-wide all-reduces.
    """
    if cfg.moe_dispatch == "local":
        return moe_local(p, cfg, x)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = int(np.ceil(t * k / e * cfg.capacity_factor))
    xt = x.reshape(t, d)

    logits = (xt @ p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # aux loss (Switch-style load balancing)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_prob) * e

    flat_e = top_e.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))  # first slot per expert
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < cap
    tok_of = order // k  # token index per sorted assignment

    if cfg.moe_dispatch == "gather":
        sorted_tok = xt[tok_of].astype(COMPUTE_DTYPE)  # (T*k, d)
        seg_end = jnp.concatenate([seg_start[1:], jnp.array([t * k])])
        gidx = seg_start[:, None] + jnp.arange(cap)[None, :]  # (e, cap)
        valid = gidx < seg_end[:, None]
        gidx = jnp.minimum(gidx, t * k - 1)
        buf = jnp.where(valid[..., None], sorted_tok[gidx], 0)
    else:  # scatter baseline
        dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
        buf = jnp.zeros((e * cap + 1, d), COMPUTE_DTYPE)
        buf = buf.at[dest].set(xt[tok_of].astype(COMPUTE_DTYPE), mode="drop")
        buf = buf[: e * cap].reshape(e, cap, d)
    buf = sharding.maybe_constrain(buf, "moe_buffer")  # EP: experts->model

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(COMPUTE_DTYPE)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(COMPUTE_DTYPE))
    h = sharding.maybe_constrain(h, "moe_hidden")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(COMPUTE_DTYPE))
    out_buf = sharding.maybe_constrain(out_buf, "moe_buffer")

    flat_out = out_buf.reshape(e * cap, d)
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, 0)
    gathered = jnp.where(keep[:, None], flat_out[slot], 0.0)  # (T*k, d) sorted
    w_sorted = top_w.reshape(-1)[order].astype(COMPUTE_DTYPE)
    contrib = gathered * w_sorted[:, None]
    if cfg.moe_dispatch == "gather":
        inv_order = jnp.argsort(order)  # combine = inverse perm + reshape-sum
        out = contrib[inv_order].reshape(t, k, d).sum(axis=1)
    else:
        out = jnp.zeros((t, d), COMPUTE_DTYPE).at[tok_of].add(contrib)
    return out.reshape(b, s, d), aux
