"""Jamba-1.5-Large (398B): hybrid Mamba+attention 1:7, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,          # MoE ffn on every 2nd layer (Jamba e=2)
    attn_every=8,         # 1 attention layer per 8 (1:7 with Mamba)
    attn_offset=4,
    ssm_state=16,         # Jamba Mamba d_state
    ssm_groups=8,
    ssm_expand=2,
    ssm_head_dim=64,
).validate()
