"""Model configuration schema + repeating layer patterns.

Every architecture is expressed as a repeating *pattern* of blocks
(mixer, ffn).  The model scans over pattern repetitions with stacked
parameters, so the compiled graph contains ONE pattern body regardless
of depth — essential for compiling 61-72 layer trillion-parameter
configs on the CPU dry-run host, and the standard production trick for
fast compiles.

Block mixers:  attn | attn_nc (non-causal) | cross | attn_cross | mamba
Block ffns:    mlp | moe | none
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "attn_nc", "cross", "attn_cross", "mamba"]
Ffn = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    use_bias: bool = False

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0          # expert hidden dim (0 -> d_ff)
    moe_every: int = 1         # MoE ffn every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"  # "scatter" (baseline) | "gather" (§Perf)

    # -- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 8
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_impl: str = "baseline"  # "grouped": §Perf group-factored einsums
    attn_every: int = 0        # hybrid: one attn layer per `attn_every` block
    attn_offset: int = 0       # position of the attn layer within the period

    # -- VLM / enc-dec --------------------------------------------------------
    cross_every: int = 0       # decoder: cross-attn mixer every k-th layer
    num_image_tokens: int = 0  # VLM frontend stub: precomputed patch embeds
    encoder_layers: int = 0    # enc-dec (whisper): encoder depth
    encoder_seq: int = 0       # precomputed frame embeddings (conv stub)
    max_target_len: int = 0    # enc-dec decoder length clamp

    # -- misc -----------------------------------------------------------------
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True  # False: unrolled (dry-run cost extrapolation)
    param_dtype: str = "float32"  # 1T-scale single-pod configs use bfloat16
    # sub-quadratic decode support (SSM/hybrid) — long_500k eligibility
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # -- pattern -----------------------------------------------------------
    def pattern(self) -> list[tuple[Mixer, Ffn]]:
        """The repeating block pattern; num_layers % len(pattern) == 0."""
        if self.family == "audio":
            return [("attn_cross", "mlp")]  # decoder blocks (enc built apart)
        if self.family == "ssm":
            return [("mamba", "none")]
        blocks: list[tuple[Mixer, Ffn]] = []
        if self.attn_every:  # hybrid (jamba): 1 attn per period
            period = self.attn_every
            for i in range(period):
                mixer: Mixer = "attn" if i == self.attn_offset else "mamba"
                ffn: Ffn = "moe" if (self.num_experts and i % self.moe_every == self.moe_every - 1) else "mlp"
                blocks.append((mixer, ffn))
            return blocks
        if self.cross_every:  # vlm: cross-attn mixer every k-th layer
            for i in range(self.cross_every):
                mixer = "cross" if i == self.cross_every - 1 else "attn"
                blocks.append((mixer, "mlp"))
            return blocks
        ffn = "moe" if self.num_experts else "mlp"
        return [("attn", ffn)]

    @property
    def reps(self) -> int:
        p = len(self.pattern())
        assert self.num_layers % p == 0, (self.name, self.num_layers, p)
        return self.num_layers // p

    def validate(self):
        assert self.d_model % 128 == 0 or self.family == "audio", self.name
        _ = self.reps
        if self.num_experts:
            assert self.experts_per_token > 0
        return self

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=len(self.pattern()) * 2,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=128 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_groups=min(self.ssm_groups, 2),
            ssm_chunk=16,
            num_image_tokens=8 if self.num_image_tokens else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            max_target_len=32 if self.max_target_len else 0,
            name=self.name + "-smoke",
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# input shapes (assigned): every arch runs these four cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
