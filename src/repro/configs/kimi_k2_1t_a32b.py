"""Kimi K2 (1T total / 32B active): 384-expert top-8 MoE (paper-table).
[arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,         # 7168 / 64
    d_ff=2048,            # expert hidden size
    moe_d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    # 1.03T params on a single 256-chip pod: fp32 weights alone are 16.1
    # GB/chip — bf16 weights (+ Adafactor factored state, see dryrun
    # OPT_POLICY) keep train/serve under the v5e 16 GB budget.
    param_dtype="bfloat16",
).validate()
