"""Mamba2-780m: attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=12,          # unused (attention-free)
    num_kv_heads=12,
    d_ff=0,                # no MLP: block = norm + SSD mixer
    vocab_size=50280,
    ssm_state=128,
    ssm_groups=1,
    ssm_expand=2,
    ssm_head_dim=64,
).validate()
