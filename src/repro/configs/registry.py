"""Architecture registry: --arch <id> resolution + per-cell applicability."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-780m": "mamba2_780m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-4b": "qwen3_4b",
    "command-r-35b": "command_r_35b",
    "qwen3-8b": "qwen3_8b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "whisper-small": "whisper_small",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a documented skip reason (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip: full-attention arch, 500k decode is quadratic (per spec)"
    return "run"


def effective_shape(cfg: ModelConfig, shape: ShapeConfig) -> ShapeConfig:
    """Per-arch shape clamps (whisper's 448-token decoder limit)."""
    if cfg.max_target_len and shape.seq_len > cfg.max_target_len:
        return ShapeConfig(shape.name, cfg.max_target_len, shape.global_batch, shape.kind)
    return shape


def all_cells():
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES.values():
            yield cfg, shape, cell_status(cfg, shape)
