"""Whisper-small: enc-dec; the conv frame frontend is a STUB
(input_specs provides precomputed frame embeddings).  Decode shapes are
clamped to the 448-token target limit — see DESIGN.md §4.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,          # decoder depth
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_seq=1500,       # 30 s of audio at 50 Hz after the conv stub
    max_target_len=448,
).validate()
