"""Llama-3.2-Vision 11B: cross-attn image layers every 5th; the vision
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_every=5,          # 8 cross-attention layers of 40
    num_image_tokens=1601,  # precomputed patch embeddings (stub frontend)
).validate()
