"""Command-R 35B: dense, GQA kv=8, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    use_bias=False,
).validate()
