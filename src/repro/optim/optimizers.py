"""Optimizers (no optax dependency): AdamW with dtype-configurable moments
and Adafactor (factored second moment) for trillion-param configs, plus
cosine schedule with linear warmup and global-norm clipping.

Moment dtypes matter at scale: kimi-k2 (1.03T params) over 512 chips
with fp32 m/v would need 8 B/param of optimizer state alone; bf16
moments (AdamW) or factored v (Adafactor) keep the per-device footprint
inside a v5e's 16 GB (see DESIGN.md §5 and the dry-run memory analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    optimizer: Literal["adamw", "adafactor"] = "adamw"
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer memory


def schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(1.0, cfg.warmup_steps)
    frac = (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(np.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def _adamw_update(grads, state, params, step, cfg: OptConfig):
    lr = schedule(cfg, step)
    grads, gnorm = _clip(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    t = jnp.asarray(step, jnp.float32) + 1.0
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory O(rows + cols) per matrix)
# ---------------------------------------------------------------------------


def adafactor_init(params, cfg: OptConfig):
    def init(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init, params, is_leaf=lambda x: isinstance(x, jnp.ndarray))}


def _adafactor_update(grads, state, params, step, cfg: OptConfig):
    lr = schedule(cfg, step)
    grads, gnorm = _clip(grads, cfg.grad_clip)
    b2 = cfg.b2

    def upd(g, v, p):
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = v["vr"] * b2 + jnp.mean(g2, axis=-1) * (1 - b2)
            vc = v["vc"] * b2 + jnp.mean(g2, axis=-2) * (1 - b2)
            vhat = vr[..., None] * vc[..., None, :] / (
                jnp.mean(vr, axis=-1, keepdims=True)[..., None] + 1e-30
            )
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = v["v"] * b2 + g2 * (1 - b2)
            vhat = vv
            new_v = {"v": vv}
        delta = g / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, new_v

    flat_g, tree = jax.tree.flatten(grads)
    flat_v = tree.flatten_up_to(state["v"])
    flat_p = jax.tree.leaves(params)
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_v = jax.tree.unflatten(tree, [o[1] for o in outs])
    return new_p, {"v": new_v}, {"lr": lr, "grad_norm": gnorm}


def make_optimizer(cfg: OptConfig):
    """Returns (init_fn(params) -> state, update_fn(grads, state, params, step))."""
    if cfg.optimizer == "adamw":
        return (lambda p: adamw_init(p, cfg)), (
            lambda g, s, p, t: _adamw_update(g, s, p, t, cfg)
        )
    if cfg.optimizer == "adafactor":
        return (lambda p: adafactor_init(p, cfg)), (
            lambda g, s, p, t: _adafactor_update(g, s, p, t, cfg)
        )
    raise ValueError(cfg.optimizer)
