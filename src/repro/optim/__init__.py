from repro.optim.optimizers import (  # noqa: F401
    OptConfig,
    adafactor_init,
    adamw_init,
    global_norm,
    make_optimizer,
    schedule,
)
