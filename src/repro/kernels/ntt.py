"""Row-centric NTT as Pallas TPU kernels.

The PIM -> TPU mapping (DESIGN.md §2):

  regime A (intra-atom + intra-row)  -> `_ntt_tile_kernel`: ALL stages with
      stride < T fused over a single VMEM-resident tile; one HBM read +
      one HBM write covers log(T) stages (the paper's "process a row-sized
      block with one row activation").
  regime B (inter-row)               -> `_ntt_pair_kernel`: one pass per
      remaining stage; each grid step's block CONTAINS both butterfly
      halves (u and v tiles), is updated IN PLACE
      (`input_output_aliases`) — the paper's BU-grained scheduling +
      in-place update, so no third buffer / no extra HBM allocation.
      Pallas's grid pipeline multi-buffers HBM<->VMEM DMAs against
      compute — the Nb-buffer pipelining idea; each HBM tile is touched
      exactly once (read+write) per stage — the activation-grouping idea.
  bank-level parallelism             -> the batch grid axis (FHE runs many
      independent NTTs; see ops.ntt / shard_map batching).

Twiddles are precomputed tables fed through VMEM and shared across the
batch (changed assumption #1 in DESIGN.md: the paper's on-the-fly
(w0, r_w) generation saves DRAM bandwidth; on TPU a serial recurrence
would idle the VPU, and the tables cost O(T) VMEM).

All arithmetic is uint32 with 16-bit-limb emulation of 32x32->64
products (TPUs have no 64-bit integer multiply); q < 2^31.  Kernels run
with interpret=True on CPU and compile for TPU through the same path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import modmath as mm
from repro.core.ntt import NttContext, Stage, forward_stages, inverse_stages

DEFAULT_TILE = 8192  # words: 32 KiB data/tile + 32 KiB twiddles << VMEM
DEFAULT_BATCH_BLOCK = 8


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# stage micro-kernel — one butterfly stage over the last axis of (B, L)
# ---------------------------------------------------------------------------


def _stage_block(x, tw, tw_sh, stage: Stage, q: int):
    b = x.shape[0]
    n = x.shape[-1]
    xr = x.reshape(b, stage.blocks, 2, stage.stride)
    u = xr[:, :, 0, :]
    v = xr[:, :, 1, :]
    w = tw.reshape(1, stage.blocks, 1)
    w_sh = tw_sh.reshape(1, stage.blocks, 1)
    if stage.gs:
        out0 = mm.addmod_u32(u, v, q)
        out1 = mm.shoup_mulmod_u32(mm.submod_u32(u, v, q), w, w_sh, q)
    else:
        wv = mm.shoup_mulmod_u32(v, w, w_sh, q)
        out0 = mm.addmod_u32(u, wv, q)
        out1 = mm.submod_u32(u, wv, q)
    return jnp.stack([out0, out1], axis=2).reshape(b, n)


# ---------------------------------------------------------------------------
# regime A kernel: fused stages over one VMEM tile
# ---------------------------------------------------------------------------


def _ntt_tile_kernel(x_ref, tw_ref, twsh_ref, o_ref, *, stages, q, scale):
    x = x_ref[...]
    if x.ndim == 3:  # (bb, 1, tile) block from the tiled path
        x = x[:, 0, :]
    tw_all = tw_ref[...].reshape(-1)
    twsh_all = twsh_ref[...].reshape(-1)
    for st in stages:
        tw = jax.lax.slice(tw_all, (st.tw_lo,), (st.tw_lo + st.blocks,))
        tw_sh = jax.lax.slice(twsh_all, (st.tw_lo,), (st.tw_lo + st.blocks,))
        x = _stage_block(x, tw, tw_sh, st, q)
    if scale is not None:
        n_inv, n_inv_sh = scale
        x = mm.shoup_mulmod_u32(x, np.uint32(n_inv), np.uint32(n_inv_sh), q)
    o_ref[...] = x.reshape(o_ref.shape)


def _pack_tile_stages(ctx: NttContext, n: int, tile: int, forward: bool):
    """Per-tile packed twiddle tables + stage plans with packed offsets.

    For tile j (global offset o = j*tile) the stage with stride t uses
    table[h + o/(2t) : ... + tile/(2t)] (h = n/(2t)) — a contiguous slice,
    so all of tile j's stage twiddles concatenate into row j of a
    (n_tiles, tile) array; one BlockSpec row feeds the fused kernel.
    """
    table = ctx.psi_brv if forward else ctx.psi_inv_brv
    table_sh = ctx.psi_brv_shoup if forward else ctx.psi_inv_brv_shoup
    plan_full = forward_stages(n) if forward else inverse_stages(n)
    stages = [st for st in plan_full if st.stride < tile]
    n_tiles = n // tile
    packed = np.zeros((n_tiles, tile), np.uint32)
    packed_sh = np.zeros((n_tiles, tile), np.uint32)
    local_stages = []
    cursor = 0
    for st in stages:
        h = n // (2 * st.stride)
        per_tile = tile // (2 * st.stride)
        for j in range(n_tiles):
            lo = h + (j * tile) // (2 * st.stride)
            packed[j, cursor : cursor + per_tile] = table[lo : lo + per_tile]
            packed_sh[j, cursor : cursor + per_tile] = table_sh[lo : lo + per_tile]
        local_stages.append(Stage(blocks=per_tile, stride=st.stride, tw_lo=cursor, gs=st.gs))
        cursor += per_tile
    return packed, packed_sh, local_stages


# ---------------------------------------------------------------------------
# regime B kernel: one inter-tile stage, block contains both halves
# ---------------------------------------------------------------------------


def _ntt_pair_kernel(x_ref, tw_ref, twsh_ref, o_ref, *, gs, q):
    # block shape (bb, 1, 2, 1, tile): dim 2 separates the butterfly halves
    u = x_ref[:, 0, 0, 0, :]
    v = x_ref[:, 0, 1, 0, :]
    w = tw_ref[0]
    w_sh = twsh_ref[0]
    if gs:
        nu = mm.addmod_u32(u, v, q)
        nv = mm.shoup_mulmod_u32(mm.submod_u32(u, v, q), w, w_sh, q)
    else:
        wv = mm.shoup_mulmod_u32(v, w, w_sh, q)
        nu = mm.addmod_u32(u, wv, q)
        nv = mm.submod_u32(u, wv, q)
    o_ref[:, 0, 0, 0, :] = nu
    o_ref[:, 0, 1, 0, :] = nv


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("ctx", "forward", "tile", "batch_block", "interpret")
)
def ntt_pallas(
    x,
    ctx: NttContext,
    forward: bool = True,
    tile: int | None = None,
    batch_block: int | None = None,
    interpret: bool | None = None,
):
    """Batched negacyclic NTT over the last axis of (batch, n) uint32.

    forward: natural order in -> bit-reversed out (CT butterflies).
    inverse: bit-reversed in -> natural out, scaled by 1/N (GS).
    """
    interpret = _interpret_default() if interpret is None else interpret
    n = ctx.n
    assert x.shape[-1] == n, (x.shape, n)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    batch = x.shape[0]
    tile = min(tile or DEFAULT_TILE, n)
    bb = min(batch_block or DEFAULT_BATCH_BLOCK, batch)
    pad = (-batch) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    scale = (ctx.n_inv, ctx.n_inv_shoup) if not forward else None

    if tile >= n:
        out = _fused_full(x, ctx, forward, bb, interpret, scale)
    else:
        out = _two_regime(x, ctx, forward, tile, bb, interpret, scale)
    if pad:
        out = out[: x.shape[0] - pad]
    return out[0] if squeeze else out


def _fused_full(x, ctx, forward, bb, interpret, scale):
    """n <= tile: whole transform VMEM-resident (regime A only)."""
    n = ctx.n
    table = ctx.psi_brv if forward else ctx.psi_inv_brv
    table_sh = ctx.psi_brv_shoup if forward else ctx.psi_inv_brv_shoup
    plan = forward_stages(n) if forward else inverse_stages(n)
    batch = x.shape[0]
    kernel = functools.partial(_ntt_tile_kernel, stages=plan, q=ctx.q, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(batch // bb,),
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x, jnp.asarray(table), jnp.asarray(table_sh))


def _two_regime(x, ctx, forward, tile, bb, interpret, scale):
    """n > tile: fused intra-tile pass + one in-place pass per inter stage."""
    n = ctx.n
    batch = x.shape[0]
    n_tiles = n // tile
    table = ctx.psi_brv if forward else ctx.psi_inv_brv
    table_sh = ctx.psi_brv_shoup if forward else ctx.psi_inv_brv_shoup
    plan_full = forward_stages(n) if forward else inverse_stages(n)
    inter = [st for st in plan_full if st.stride >= tile]
    packed, packed_sh, local_stages = _pack_tile_stages(ctx, n, tile, forward)

    def run_intra(x):
        kernel = functools.partial(_ntt_tile_kernel, stages=local_stages, q=ctx.q, scale=None)
        xr = x.reshape(batch, n_tiles, tile)
        out = pl.pallas_call(
            kernel,
            grid=(batch // bb, n_tiles),
            in_specs=[
                pl.BlockSpec((bb, 1, tile), lambda i, j: (i, j, 0)),
                pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
                pl.BlockSpec((1, tile), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((bb, 1, tile), lambda i, j: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct(xr.shape, jnp.uint32),
            input_output_aliases={0: 0},
            interpret=interpret,
        )(xr, jnp.asarray(packed), jnp.asarray(packed_sh))
        return out.reshape(batch, n)

    def run_inter_stage(x, st: Stage):
        st_tiles = st.stride // tile
        n_groups = n_tiles // (2 * st_tiles)
        h = n // (2 * st.stride)
        # twiddle depends only on the group index g: u-tile offset
        # = (g*2*st_tiles + s)*tile, and (offset)/(2*stride) = g.
        tw = np.asarray(table)[h : h + n_groups].astype(np.uint32)
        tw_sh = np.asarray(table_sh)[h : h + n_groups].astype(np.uint32)
        x5 = x.reshape(batch, n_groups, 2, st_tiles, tile)
        kernel = functools.partial(_ntt_pair_kernel, gs=st.gs, q=ctx.q)
        out = pl.pallas_call(
            kernel,
            grid=(batch // bb, n_groups, st_tiles),
            in_specs=[
                pl.BlockSpec((bb, 1, 2, 1, tile), lambda i, g, s: (i, g, 0, s, 0)),
                pl.BlockSpec((1,), lambda i, g, s: (g,)),
                pl.BlockSpec((1,), lambda i, g, s: (g,)),
            ],
            out_specs=pl.BlockSpec((bb, 1, 2, 1, tile), lambda i, g, s: (i, g, 0, s, 0)),
            out_shape=jax.ShapeDtypeStruct(x5.shape, jnp.uint32),
            input_output_aliases={0: 0},
            interpret=interpret,
        )(x5, jnp.asarray(tw), jnp.asarray(tw_sh))
        return out.reshape(batch, n)

    if forward:
        for st in inter:  # large strides first
            x = run_inter_stage(x, st)
        x = run_intra(x)
    else:
        x = run_intra(x)
        for st in inter:
            x = run_inter_stage(x, st)
    if scale is not None:
        n_inv, n_inv_sh = scale
        x = mm.shoup_mulmod_u32(x, np.uint32(n_inv), np.uint32(n_inv_sh), ctx.q)
    return x
