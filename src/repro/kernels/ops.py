"""Public jit'd API over the Pallas kernels.

  ntt / intt           batched negacyclic NTT (forward: natural->brv,
                       inverse: brv->natural, 1/N folded in)
  polymul_ntt          a*b in Z_q[X]/(X^N+1), eq. (1) of the paper — no
                       bit-reversal anywhere (element-wise NTT domain)
  ntt_conv             integer negacyclic convolution (sequence-mixing
                       primitive for the LM stack; exact, O(N log N))
  ntt_conv_fixedpoint  float sequences via fixed-point lift, exact
                       integer convolution, and un-lift

Batching across independent transforms == the paper's bank-level
parallelism; across devices, shard the batch axis of these ops with
pjit/shard_map (they are purely element-parallel in batch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ntt import NttContext, make_context  # re-export for users
from repro.kernels.modmul import modmul_pallas
from repro.kernels.ntt import ntt_pallas


def ntt(x, ctx: NttContext, **kw):
    """Forward negacyclic NTT over the last axis (natural in, brv out)."""
    return ntt_pallas(x, ctx, forward=True, **kw)


def intt(x, ctx: NttContext, **kw):
    """Inverse negacyclic NTT over the last axis (brv in, natural out, /N)."""
    return ntt_pallas(x, ctx, forward=False, **kw)


def polymul_ntt(a, b, ctx: NttContext, **kw):
    """a*b mod (X^N + 1): NTT -> element-wise modmul -> INTT."""
    ah = ntt(a, ctx, **kw)
    bh = ntt(b, ctx, **kw)
    prod = modmul_pallas(ah, bh, ctx, interpret=kw.get("interpret"))
    return intt(prod, ctx, **kw)


def ntt_conv(u, k, ctx: NttContext, **kw):
    """Exact negacyclic convolution of uint32 sequences in [0, q)."""
    return polymul_ntt(jnp.asarray(u, jnp.uint32), jnp.asarray(k, jnp.uint32), ctx, **kw)


@functools.partial(jax.jit, static_argnames=("ctx", "frac_bits", "interpret"))
def ntt_conv_fixedpoint(u, k, ctx: NttContext, frac_bits: int = 10, interpret: bool | None = None):
    """Negacyclic convolution of float sequences via fixed-point lift.

    Values are scaled by 2^frac_bits, rounded, lifted to [0, q) (negatives
    as q - |x|), convolved exactly over Z_q, and mapped back assuming the
    true result magnitude < q / 2^(2*frac_bits + 1).  This makes the NTT
    engine usable as an *exact* long-convolution mixer for sequence
    models (no FFT rounding error), the framework's point of contact
    between the paper's kernel and the LM stack.
    """
    q = ctx.q
    scale = np.float32(1 << frac_bits)

    def lift(x):
        xi = jnp.round(x * scale).astype(jnp.int64) if False else jnp.round(x * scale).astype(jnp.int32)
        return jnp.where(xi < 0, np.uint32(q) + xi.astype(jnp.uint32), xi.astype(jnp.uint32))

    uh = lift(u)
    kh = lift(k)
    ch = ntt_conv(uh, kh, ctx, interpret=interpret)
    # map back to signed: values > q/2 are negative
    signed = jnp.where(ch > np.uint32(q // 2), ch.astype(jnp.float32) - np.float32(q), ch.astype(jnp.float32))
    return signed / (scale * scale)
