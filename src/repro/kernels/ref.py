"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (in
interpret mode on CPU, and on real TPU via the same assert_allclose
sweeps).  They reuse the uint32 16-bit-limb arithmetic from repro.core so
that kernel-vs-ref differences isolate *tiling/scheduling* bugs, while
the limb primitives themselves are validated against python big-ints in
tests/test_core_ntt.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import modmath as mm
from repro.core import ntt as ntt_core
from repro.core.ntt import NttContext, make_context  # re-export


def ntt_forward_ref(x, ctx: NttContext):
    """Negacyclic forward NTT over the last axis (natural in, brv out)."""
    return ntt_core.ntt_forward_jnp(x, ctx)


def ntt_inverse_ref(x, ctx: NttContext):
    """Negacyclic inverse NTT over the last axis (brv in, natural out)."""
    return ntt_core.ntt_inverse_jnp(x, ctx)


def modmul_ref(a, b, ctx: NttContext):
    """Element-wise a*b mod q."""
    return mm.mulmod_u32(a, b, ctx.q, ctx.qprime, ctx.r2_mod_q)


def polymul_ref(a, b, ctx: NttContext):
    """Negacyclic polynomial product over the last axis (eq. 1)."""
    return ntt_core.polymul_negacyclic_jnp(a, b, ctx)


def ntt_conv_ref(u, kern, ctx: NttContext):
    """Negacyclic convolution of integer sequences (u, kern in [0, q))."""
    return polymul_ref(jnp.asarray(u, jnp.uint32), jnp.asarray(kern, jnp.uint32), ctx)
