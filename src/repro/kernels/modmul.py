"""Element-wise modular multiply Pallas kernel (NTT-domain ⊙ of eq. 1).

Montgomery round-trip per element (two REDC passes), uint32 in/out in
[0, q).  The analogue of streaming atom pairs through the CU's CMul path;
tiles are sized so two operand tiles + one result alias fit comfortably
in VMEM and the grid pipeline overlaps HBM DMA with compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import modmath as mm
from repro.core.ntt import NttContext

DEFAULT_BLOCK = 16384  # words = 64 KiB per operand tile


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _modmul_kernel(a_ref, b_ref, o_ref, *, q, qprime, r2):
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = mm.mulmod_u32(a, b, q, qprime, r2)


@functools.partial(jax.jit, static_argnames=("ctx", "block", "interpret"))
def modmul_pallas(a, b, ctx: NttContext, block: int | None = None, interpret: bool | None = None):
    """Element-wise a*b mod q over arbitrary (batch..., n) uint32 arrays."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = a.shape
    assert a.shape == b.shape
    flat_a = a.reshape(-1)
    flat_b = b.reshape(-1)
    n = flat_a.shape[0]
    blk = min(block or DEFAULT_BLOCK, n)
    pad = (-n) % blk
    if pad:
        flat_a = jnp.pad(flat_a, (0, pad))
        flat_b = jnp.pad(flat_b, (0, pad))
    kernel = functools.partial(
        _modmul_kernel, q=ctx.q, qprime=ctx.qprime, r2=ctx.r2_mod_q
    )
    out = pl.pallas_call(
        kernel,
        grid=(flat_a.shape[0] // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat_a.shape, jnp.uint32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(flat_a, flat_b)
    if pad:
        out = out[:n]
    return out.reshape(shape)
