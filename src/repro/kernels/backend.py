"""Unified NTT execution backends (`NttBackend`).

Three implementations of the SAME transform contract sit behind one
interface so they can be differentially tested against each other and
benchmarked through one harness (`benchmarks/tpu_ntt.py`):

  reference  numpy stage loop (`core.ntt`) — the ground truth.
  pim-sim    the paper's row-centric PIM bank: functional execution on
             `FunctionalBank` via `mapping.pim_ntt`, with the modeled
             `BankTimer` latency available for table3-style PIM-vs-TPU
             rows.
  pallas     the jax/pallas TPU kernel lane (`kernels.ntt.ntt_pallas`),
             interpret-mode on CPU; gated on jax being importable so
             the package (and this module) stay usable without it.

Contract (shared by all three): uint32 arrays over the last axis,
`forward=True` is natural in -> bit-reversed out, `forward=False` is
bit-reversed in -> natural out scaled by 1/N — exactly the
`core.ntt.ntt_forward_np` / `ntt_inverse_np` conventions.

`get_backend(name)` / `available_backends()` are the registry the
benchmark and the differential tests drive.
"""
from __future__ import annotations

import abc

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt as ntt_core
from repro.core.pim_config import PimConfig

DEFAULT_Q = mm.DEFAULT_Q


class NttBackend(abc.ABC):
    """One NTT execution lane behind the shared transform contract."""

    name: str = "?"
    summary: str = ""

    def __init__(self) -> None:
        self._ctxs: dict[tuple[int, int], ntt_core.NttContext] = {}

    # -- shared helpers ------------------------------------------------------
    def context(self, q: int, n: int) -> ntt_core.NttContext:
        """Cached `NttContext` per (q, n) — table setup is the expensive
        part of small transforms and must not pollute timing loops."""
        key = (q, n)
        ctx = self._ctxs.get(key)
        if ctx is None:
            ctx = self._ctxs[key] = ntt_core.make_context(q, n)
        return ctx

    def available(self) -> bool:
        """Whether this lane can run in the current environment."""
        return True

    def modeled_latency_ns(self, n: int, forward: bool = True) -> float | None:
        """Architecture-model latency for one size-n transform, if this
        backend has one (the PIM lane's `BankTimer` cycles); None means
        only wall-clock timing applies."""
        return None

    # -- the transform -------------------------------------------------------
    @abc.abstractmethod
    def _ntt_2d(self, x: np.ndarray, ctx: ntt_core.NttContext,
                forward: bool) -> np.ndarray:
        """Transform a (batch, n) uint32 array over the last axis."""

    def ntt(self, x: np.ndarray, q: int = DEFAULT_Q,
            forward: bool = True) -> np.ndarray:
        """Negacyclic NTT over the last axis of a (n,) or (batch, n)
        uint32 array; see the module docstring for the orientation
        contract."""
        x = np.asarray(x, np.uint32)
        if x.ndim not in (1, 2):
            raise ValueError(f"expected (n,) or (batch, n), got {x.shape}")
        n = x.shape[-1]
        if n & (n - 1) or n <= 0:
            raise ValueError("n must be a power of two")
        ctx = self.context(q, n)
        batched = x.ndim == 2
        out = self._ntt_2d(x if batched else x[None, :], ctx, forward)
        out = np.asarray(out, np.uint32)
        return out if batched else out[0]


class ReferenceBackend(NttBackend):
    name = "reference"
    summary = "numpy stage loop (core.ntt) — ground truth"

    def _ntt_2d(self, x, ctx, forward):
        fn = ntt_core.ntt_forward_np if forward else ntt_core.ntt_inverse_np
        return fn(x, ctx)


class PimSimBackend(NttBackend):
    """The paper's row-centric bank: functional `FunctionalBank`
    execution plus the `BankTimer` cycle model for latency rows."""

    name = "pim-sim"
    summary = "row-centric PIM bank (mapping.pim_ntt + BankTimer model)"

    def __init__(self, cfg: PimConfig | None = None) -> None:
        super().__init__()
        self.cfg = cfg or PimConfig()
        self._lat: dict[tuple[int, bool], float] = {}

    def _ntt_2d(self, x, ctx, forward):
        from repro.core.mapping import pim_ntt

        return np.stack([
            pim_ntt(row, ctx, self.cfg, forward=forward)[0] for row in x
        ])

    def modeled_latency_ns(self, n: int, forward: bool = True) -> float | None:
        key = (n, forward)
        ns = self._lat.get(key)
        if ns is None:
            from repro.pimsys.session import NttOp, PimSession

            sess = PimSession(self.cfg)
            ns = sess.run(sess.compile(NttOp(n, forward=forward))).timing.ns
            self._lat[key] = ns
        return ns


class PallasBackend(NttBackend):
    """The jax/pallas TPU kernel lane; interpret mode off-TPU."""

    name = "pallas"
    summary = "jax/pallas tiled kernel (kernels.ntt.ntt_pallas)"

    def __init__(self, interpret: bool | None = None) -> None:
        super().__init__()
        self.interpret = interpret

    def available(self) -> bool:
        try:
            import jax  # noqa: F401
        except Exception:
            return False
        return True

    def _ntt_2d(self, x, ctx, forward):
        from repro.kernels.ntt import ntt_pallas

        out = ntt_pallas(x, ctx, forward=forward, interpret=self.interpret)
        return np.asarray(out)


_REGISTRY = {
    ReferenceBackend.name: ReferenceBackend,
    PimSimBackend.name: PimSimBackend,
    PallasBackend.name: PallasBackend,
}

BACKEND_NAMES = tuple(_REGISTRY)


def get_backend(name: str, **kwargs) -> NttBackend:
    """Instantiate a backend by registry name ('reference', 'pim-sim',
    'pallas'); raises ValueError for unknown names with the list of
    known ones in the message."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown NTT backend {name!r}; known: {sorted(_REGISTRY)}"
        ) from None
    return cls(**kwargs)


def available_backends(**kwargs) -> list[NttBackend]:
    """Every registered backend that can run here, registry order."""
    out = []
    for name in _REGISTRY:
        b = get_backend(name)
        if b.available():
            out.append(b)
    return out
