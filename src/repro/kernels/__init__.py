try:  # the pallas op library needs jax; the backend registry does not
    from repro.kernels import ops  # noqa: F401
except ImportError:  # pragma: no cover - exercised only on jax-less hosts
    ops = None  # type: ignore[assignment]
from repro.kernels import backend  # noqa: F401
