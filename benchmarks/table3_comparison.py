"""Table III reproduction: NTT-PIM latency/energy vs previous work.

We report, per polynomial length N:
  * our simulated NTT-PIM latency at Nb = 2/4/6 (this work's model),
  * the paper's published NTT-PIM numbers side-by-side with the ratio
    ours/paper (the trend is the reproduction target; the paper's
    absolute numbers embed DRAMsim3 internals),
  * the paper's MeNTT / CryptoPIM / x86 / FPGA baselines (published
    values — implementing SRAM/ReRAM PIMs is out of scope, they are the
    *competitors*),
  * a measured software baseline on THIS machine's CPU (numpy NTT),
    clearly labeled as ours,
  * energy from the per-op model plus a least-squares fit of the three
    per-op coefficients to the paper's own energy table (sanity check
    that the paper's energies are consistent with its op counts).
"""
import time

import numpy as np

from repro.core import modmath as mm
from repro.core import ntt as ntt_ref
from repro.core.pim_config import EnergyModel, PimConfig
from repro.pimsys.session import PimSession

_SESSIONS: dict = {}


def _time_ntt(n: int, nb: int):
    """Session-cached NTT timing: one simulated baseline per (N, Nb)
    reused by the latency, energy, and fit passes below."""
    sess = _SESSIONS.get(nb)
    if sess is None:
        sess = _SESSIONS[nb] = PimSession(PimConfig(num_buffers=nb))
    return sess.baseline(n)

PAPER_LATENCY_US = {  # N: (Nb2, Nb4, Nb6, MeNTT, CryptoPIM, x86, FPGA)
    256: (3.90, 2.50, 1.94, 23.0, 68.57, 84.81, 21.56),
    512: (14.16, 8.33, 6.58, 26.0, 75.90, 168.96, 47.64),
    1024: (38.19, 21.62, 16.89, 34.3, 83.12, 349.41, 101.84),
    2048: (95.84, 53.03, 41.18, None, 363.90, 736.92, None),
    4096: (230.45, 124.95, 96.62, None, 392.69, 1503.31, None),
}

PAPER_ENERGY_NJ = {  # N: (Nb2, Nb4)
    256: (0.80, 0.49),
    512: (4.77, 2.67),
    1024: (13.86, 7.16),
    2048: (36.68, 18.98),
    4096: (93.08, 48.93),
}


def cpu_baseline_us(n: int, iters: int = 5) -> float:
    ctx = ntt_ref.make_context(mm.DEFAULT_Q, n)
    a = np.random.default_rng(0).integers(0, mm.DEFAULT_Q, n).astype(np.uint32)
    ntt_ref.ntt_forward_np(a, ctx)  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        ntt_ref.ntt_forward_np(a, ctx)
    return (time.perf_counter() - t0) / iters * 1e6


def fit_energy_model():
    """Least-squares (e_act, e_col, e_cu) against the paper's energy table."""
    rows, y = [], []
    for n, (e2, e4) in PAPER_ENERGY_NJ.items():
        for nb, e in ((2, e2), (4, e4)):
            st = _time_ntt(n, nb).stats
            rows.append([st["act"], st["col_read"] + st["col_write"], st["c1"] + st["c2"]])
            y.append(e)
    coef, res, *_ = np.linalg.lstsq(np.asarray(rows, float), np.asarray(y), rcond=None)
    pred = np.asarray(rows, float) @ coef
    rel = float(np.mean(np.abs(pred - y) / y))
    return coef, rel


def run(emit):
    for n, paper in PAPER_LATENCY_US.items():
        ours = [_time_ntt(n, nb).us for nb in (2, 4, 6)]
        for nb, us, p in zip((2, 4, 6), ours, paper[:3]):
            emit(f"table3/N={n}/NTT-PIM/Nb={nb}", us, f"paper={p};ratio={us / p:.2f}")
        for label, p in zip(("MeNTT", "CryptoPIM", "x86", "FPGA"), paper[3:]):
            if p is not None:
                emit(f"table3/N={n}/{label}", p, "paper-published")
        cpu = cpu_baseline_us(n)
        emit(f"table3/N={n}/thisCPU", cpu, f"speedup_vs_Nb6=x{cpu / ours[2]:.1f}")
    # energy
    model = EnergyModel()
    for n in PAPER_ENERGY_NJ:
        for nb in (2, 4):
            e = _time_ntt(n, nb).energy_nj(model)
            emit(f"table3/N={n}/energy/Nb={nb}", 0.0,
                 f"{e:.1f}nJ(lit-model);paper={PAPER_ENERGY_NJ[n][0 if nb == 2 else 1]}nJ")
    coef, rel = fit_energy_model()
    emit("table3/energy_fit", 0.0,
         f"e_act={coef[0]:.4f};e_col={coef[1]:.5f};e_cu={coef[2]:.5f}nJ;mean_rel_err={rel:.2%}")
