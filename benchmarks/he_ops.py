"""Beyond-paper: RNS-CKKS ciphertext ops on the PIM device (`repro.he`).

The paper's row-centric NTT bank is the inner loop of RNS homomorphic
encryption; this benchmark drives the ciphertext-level op specs
(`RlweCtMulOp`, `KeySwitchOp`, `RescaleOp`, fused `CtMulRelinOp`)
through `PimSession.compile` and sweeps towers x N x banks:

  1. tower-parallel scaling: each op at banks = 1 .. towers — the
     embarrassingly parallel RNS axis should hold efficiency >= 0.7 at
     banks = towers for the compute-bound ops (the acceptance gate);
     keyswitch shows the base-extension broadcast paying real bus
     bursts, rescale the movement-dominated floor
  2. op mix at banks = towers: per-op latency + the fused
     multiply-relinearize saving vs the unfused pair
  3. serving: Poisson ciphertext-multiply arrivals through the
     `DeviceService` gang path (plans stay frozen; the scheduler
     replays one primed resolver per channel pattern)

`--json PATH` writes every sweep point as machine-readable JSON under
the shared `schema_version` + metadata header; smoke.sh gates the
fresh quick sweep against the committed `BENCH_he.json`
(`scripts/perf_check.py`) and refreshes it — the simulator is
deterministic, so a diff in that file IS a perf change.

`--trace-out PATH` records ONE telemetry-enabled keyswitch run
(towers = banks = 8) and exports its Chrome trace-event JSON: the
`he` track carries one span per plan segment, including the
`base_extend` broadcast.

Usage:
    PYTHONPATH=src python -m benchmarks.he_ops [--quick] \
        [--json BENCH_he.json] [--trace-out he_trace.json]
    PYTHONPATH=src python -m benchmarks.run --only he_ops
"""
import argparse
import json

import repro.he as he
from repro.core.pim_config import PimConfig
from repro.pimsys import PimSession, ServicePolicy

#: quick topology: 2 channels x 4 banks = 8 reserved banks max
QUICK_CFG = dict(num_channels=2, num_banks=4, param_cache_entries=16)
FULL_CFG = dict(num_channels=4, num_banks=4, param_cache_entries=16)


def _op_point(sess, op):
    t = sess.run(sess.compile(op)).timing
    hit = f"hit_rate={t.param_hit_rate:.2f};" if t.param_hit_rate is not None else ""
    return t, (
        f"speedup=x{t.speedup:.2f};eff={t.efficiency:.2f};"
        f"single_us={t.single_ns / 1e3:.1f};{hit}"
        f"xfer_atoms={t.xfer_atoms};hops={t.xfer_hops}"
    )


def _scaling_sweep(emit, cfg_kw, sizes, levels, bank_counts):
    """Every op, banks = 1..towers: the tower->bank scaling curves."""
    sess = PimSession(PimConfig(**cfg_kw))
    total = sess.topo.total_banks
    for n in sizes:
        for big_l in levels:
            for banks in bank_counts:
                if banks > min(big_l, total):
                    continue
                for kind, op in (
                    ("ct_mul", he.RlweCtMulOp(n=n, towers=big_l, banks=banks)),
                    ("keyswitch", he.KeySwitchOp(n=n, towers=big_l, banks=banks)),
                ):
                    t, derived = _op_point(sess, op)
                    emit(f"he/{kind}/N={n}/L={big_l}/banks={banks}",
                         t.latency_ns / 1e3, derived)


def _op_mix(emit, cfg_kw, sizes, levels):
    """All four ops at banks = towers, plus the fusion saving."""
    sess = PimSession(PimConfig(**cfg_kw))
    total = sess.topo.total_banks
    for n in sizes:
        for big_l in levels:
            banks = min(big_l, total)
            ops = {
                "ct_mul": he.RlweCtMulOp(n=n, towers=big_l, banks=banks),
                "keyswitch": he.KeySwitchOp(n=n, towers=big_l, banks=banks),
                "rescale": he.RescaleOp(n=n, towers=big_l, banks=banks),
                "ct_mul_relin": he.CtMulRelinOp(n=n, towers=big_l, banks=banks),
            }
            lat = {}
            for kind, op in ops.items():
                t, derived = _op_point(sess, op)
                lat[kind] = t.latency_ns
                emit(f"he/mix/{kind}/N={n}/L={big_l}/banks={banks}",
                     t.latency_ns / 1e3, derived)
            unfused = lat["ct_mul"] + lat["keyswitch"]
            emit(f"he/mix/fusion/N={n}/L={big_l}/banks={banks}", 0.0,
                 f"fused_us={lat['ct_mul_relin'] / 1e3:.1f};"
                 f"unfused_us={unfused / 1e3:.1f};"
                 f"saving={1 - lat['ct_mul_relin'] / unfused:.2f}")


def _serving_sweep(emit, cfg_kw, n, big_l, rates, jobs):
    """Open-loop ciphertext-multiply arrivals through the gang path."""
    sess = PimSession(PimConfig(**cfg_kw))
    banks = min(big_l, sess.topo.total_banks)
    plan = sess.compile(he.RlweCtMulOp(n=n, towers=big_l, banks=banks))
    svc = sess.service(ServicePolicy())
    for rate in rates:
        svc.submit_poisson(plan, jobs, rate, seed=0)
        res = svc.result()
        p = res.latency_percentiles_us()
        emit(f"he/serve/ct_mul/N={n}/L={big_l}/rate={rate}", p["p50"],
             f"p95={p['p95']:.1f}us;p99={p['p99']:.1f}us;"
             f"tput={res.throughput_jobs_per_ms:.2f}jobs_ms")


def run(emit, quick: bool = False):
    if quick:
        _scaling_sweep(emit, QUICK_CFG, sizes=[256], levels=[2, 4, 8],
                       bank_counts=[1, 2, 4, 8])
        _op_mix(emit, QUICK_CFG, sizes=[256], levels=[2, 4, 8])
        _serving_sweep(emit, QUICK_CFG, n=256, big_l=4,
                       rates=[0.02], jobs=12)
        return
    _scaling_sweep(emit, FULL_CFG, sizes=[1024, 4096], levels=[2, 4, 8, 16],
                   bank_counts=[1, 2, 4, 8, 16])
    _op_mix(emit, FULL_CFG, sizes=[1024, 4096], levels=[2, 4, 8, 16])
    _serving_sweep(emit, FULL_CFG, n=1024, big_l=8,
                   rates=[0.005, 0.02], jobs=32)


def record_trace(path: str, quick: bool = False) -> dict:
    """ONE telemetry-enabled keyswitch (towers = banks), exported as a
    Chrome trace-event document whose `he` track spans every plan
    segment — base-extension broadcast included."""
    from repro.pimsys import validate_chrome_trace

    n, big_l = (256, 4) if quick else (1024, 8)
    cfg = PimConfig(num_channels=4, num_banks=2, param_cache_entries=16,
                    telemetry=True)
    sess = PimSession(cfg)
    r = sess.run(sess.compile(he.KeySwitchOp(n=n, towers=big_l)))
    tel = r.telemetry
    assert tel is not None, "telemetry=True run must carry a TelemetryHandle"
    errors = validate_chrome_trace(tel.chrome_trace())
    if errors:
        raise SystemExit("trace failed schema validation: " + "; ".join(errors))
    phases = sorted({p[1] for p in tel.tracer.phases})
    if "base_extend" not in phases:
        raise SystemExit("keyswitch trace is missing the base_extend span")
    tel.dump(path)
    return {
        "path": path,
        "events": len(tel.chrome_trace()["traceEvents"]),
        "phases": phases,
        "n": n,
        "towers": big_l,
    }


def main():
    from benchmarks.multibank import collecting_emit
    from benchmarks.run import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for smoke tests (~seconds)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every sweep point as JSON "
                         "(e.g. BENCH_he.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="instead of sweeping: record one telemetry-"
                         "enabled keyswitch run and export its Chrome "
                         "trace-event JSON")
    args = ap.parse_args()

    if args.trace_out:
        info = record_trace(args.trace_out, quick=args.quick)
        print(f"# wrote {info['events']} trace events "
              f"(phases: {', '.join(info['phases'])}, N={info['n']}, "
              f"L={info['towers']}) to {info['path']}")
        return

    records: list = []
    sink = collecting_emit(emit, records) if args.json else emit

    print("name,us_per_call,derived")
    run(sink, quick=args.quick)

    if args.json:
        from benchmarks.run import SCHEMA_VERSION, bench_meta

        with open(args.json, "w") as f:
            json.dump(
                {
                    "benchmark": "he_ops",
                    "schema_version": SCHEMA_VERSION,
                    "meta": bench_meta(
                        cfg=PimConfig(**(QUICK_CFG if args.quick else FULL_CFG)),
                        seeds={"serve": 0}),
                    "quick": args.quick,
                    "points": records,
                },
                f, indent=2)
            f.write("\n")
        print(f"# wrote {len(records)} sweep points to {args.json}")


if __name__ == "__main__":
    main()
