"""TPU NTT lane over the unified `NttBackend` harness.

Two kinds of rows:

  * structural roofline terms per mapping choice (no TPU attached, so
    the three terms derive from the lowered kernel + analytic HBM
    traffic — the same methodology as the model dry-run).  The paper's
    key metric — row activations, i.e. HBM tile touches — maps to
    `hbm_passes`: the fused intra-tile kernel does the first log(T)
    stages in ONE pass; each inter-tile stage adds one more.  These are
    deterministic arithmetic, so they gate like any other lane.
  * backend rows through `repro.kernels.backend`: a bit-exact
    {reference, pim-sim, pallas} differential (the same assert the
    tests run, proving the benchmarked kernels are the real ones), the
    PIM lane's modeled `BankTimer` latency (deterministic -> gated),
    and wall-clock annotations for the host lanes (noisy -> ungated).

`--json BENCH_tpu.json` commits the sweep as an artifact with the same
document shape as the other lanes (`scripts/perf_check.py` gates it).
Wall-clock here runs in interpret mode off-TPU (functional, not
indicative).
"""
import argparse
import json

import numpy as np

from repro.core import modmath as mm
from repro.core.ntt import make_context
from repro.core.pim_config import PimConfig
from repro.kernels.backend import available_backends, get_backend

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def structural_terms(n: int, batch: int, tile: int):
    """(hbm_passes, bytes_moved, modmul_count) for one batched NTT."""
    tile = min(tile, n)
    stages = int(np.log2(n))
    intra = min(int(np.log2(tile)), stages)
    inter = stages - intra
    passes = 1 + inter  # paper: one "row activation" per tile per pass
    words = batch * n
    bytes_moved = passes * 2 * words * 4  # read + write per pass
    butterflies = batch * (n // 2) * stages
    return passes, bytes_moved, butterflies


def run(emit):
    from repro.kernels.ntt import DEFAULT_TILE

    batch = 64  # bank-level parallelism analogue
    for n in [2**12, 2**14, 2**16, 2**17]:
        for tile in [1024, 8192, 65536]:
            if tile > n:
                continue
            passes, bts, bfs = structural_terms(n, batch, tile)
            # 1 butterfly = 1 Shoup modmul (~10 uint32 VPU ops via 16-bit
            # limbs) + add/sub: ~16 elementwise ops -> flops-equivalent.
            vpu_ops = bfs * 16
            t_mem = bts / HBM_BW
            t_comp = vpu_ops / PEAK_FLOPS
            ai = vpu_ops / bts
            emit(
                f"tpu_ntt/N={n}/tile={tile}",
                t_mem * 1e6,
                f"hbm_passes={passes};AI={ai:.1f}ops/B;"
                f"bound={'memory' if t_mem > t_comp else 'compute'}",
            )
    # single-buffer analogue: stage-at-a-time (no fusion) = log N passes
    n = 2**14
    naive_passes = int(np.log2(n))
    fused_passes, _, _ = structural_terms(n, batch, DEFAULT_TILE)
    emit(
        "tpu_ntt/fusion_win",
        0.0,
        f"stagewise={naive_passes}passes;row-centric={fused_passes}passes;"
        f"x{naive_passes / fused_passes:.1f}_traffic_reduction",
    )


def correctness_check(emit):
    """Tiny interpret-mode run to prove the benchmarked kernel is the real one."""
    ctx = make_context(mm.DEFAULT_Q, 4096)
    x = np.random.default_rng(0).integers(
        0, mm.DEFAULT_Q, (2, 4096)).astype(np.uint32)
    pallas = get_backend("pallas")
    if not pallas.available():
        emit("tpu_ntt/kernel_check", 0.0, "skipped=jax-unavailable")
        return
    from repro.kernels.ntt import ntt_pallas

    got = np.asarray(ntt_pallas(x, ctx, forward=True, tile=1024))
    exp = get_backend("reference").ntt(x, forward=True)
    assert np.array_equal(got, exp)
    emit("tpu_ntt/kernel_check", 0.0, "interpret-mode==oracle")


def backend_rows(emit, quick: bool = True, cfg: PimConfig | None = None):
    """Differential + latency rows through the `NttBackend` registry.

    The differential asserts BIT-EXACT equality of every available
    backend against the reference, forward and inverse, before any
    number is emitted — a failed cross-check must kill the benchmark,
    not publish wrong rows.  The pim-sim rows carry the deterministic
    `BankTimer`-modeled latency as `us_per_call` (gated); host
    wall-clock goes into ungated annotations (interpret-mode numbers
    mean nothing across machines).
    """
    import time

    cfg = cfg or PimConfig()
    sizes = [1024, 4096] if quick else [1024, 4096, 16384]
    batch = 2
    backends = available_backends()
    for b in backends:
        if b.name == "pim-sim":
            b.cfg = cfg
    names = [b.name for b in backends]
    rng = np.random.default_rng(0)
    ref = get_backend("reference")
    for n in sizes:
        x = rng.integers(0, mm.DEFAULT_Q, (batch, n)).astype(np.uint32)
        exp_f = ref.ntt(x, forward=True)
        exp_i = ref.ntt(exp_f, forward=False)
        assert np.array_equal(exp_i, x), "reference round-trip broke"
        for b in backends:
            t0 = time.perf_counter()
            got_f = b.ntt(x, forward=True)
            got_i = b.ntt(exp_f, forward=False)
            wall_us = (time.perf_counter() - t0) / (2 * batch) * 1e6
            assert np.array_equal(got_f, exp_f), (b.name, n, "forward")
            assert np.array_equal(got_i, exp_i), (b.name, n, "inverse")
            modeled = b.modeled_latency_ns(n, forward=True)
            if modeled is not None:
                emit(f"tpu_ntt/backend/{b.name}/N={n}", modeled / 1e3,
                     f"modeled=BankTimer;wall_us={wall_us:.1f}")
            else:
                emit(f"tpu_ntt/backend/{b.name}/N={n}", 0.0,
                     f"wall_us={wall_us:.1f}")
        emit(f"tpu_ntt/backend/differential/N={n}", 0.0,
             f"bit_equal={'+'.join(names)};batch={batch}x2dir")


def main(argv=None) -> int:
    from benchmarks.run import SCHEMA_VERSION, bench_meta, emit as print_emit

    ap = argparse.ArgumentParser(
        description="TPU NTT lane over the unified NttBackend harness")
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (the smoke/CI leg)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the sweep as a JSON artifact")
    args = ap.parse_args(argv)

    cfg = PimConfig()
    points = []

    def emit(name, us_per_call, derived=""):
        # wall-clock annotations print but stay out of the committed
        # artifact: a diff in BENCH_tpu.json must mean a model change,
        # never host noise
        clean = ";".join(p for p in derived.split(";")
                         if not p.startswith("wall_us="))
        points.append({"name": name, "us_per_call": us_per_call,
                       "derived": clean})
        print_emit(name, us_per_call, derived)

    print("name,us_per_call,derived")
    run(emit)
    correctness_check(emit)
    backend_rows(emit, quick=args.quick, cfg=cfg)

    if args.json:
        doc = {
            "benchmark": "tpu_ntt",
            "schema_version": SCHEMA_VERSION,
            "meta": bench_meta(cfg, seeds={"data": 0}),
            "quick": bool(args.quick),
            "points": points,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
