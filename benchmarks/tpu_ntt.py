"""TPU-adapted NTT kernel: structural roofline terms per mapping choice.

No TPU is attached, so this benchmark derives the three roofline terms
from the lowered kernel + analytic HBM traffic (the same methodology as
the model dry-run), for the paper-relevant sizes and the two mapping
regimes.  The paper's key metric — row activations, i.e. HBM tile
touches — maps to `hbm_passes`: the fused intra-tile kernel does the
first log(T) stages in ONE pass; each inter-tile stage adds one more.
Wall-clock here runs in interpret mode (functional, not indicative).
"""
import numpy as np

from repro.core import modmath as mm
from repro.core.ntt import make_context
from repro.kernels.ntt import DEFAULT_TILE

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def structural_terms(n: int, batch: int, tile: int):
    """(hbm_passes, bytes_moved, modmul_count) for one batched NTT."""
    tile = min(tile, n)
    stages = int(np.log2(n))
    intra = min(int(np.log2(tile)), stages)
    inter = stages - intra
    passes = 1 + inter  # paper: one "row activation" per tile per pass
    words = batch * n
    bytes_moved = passes * 2 * words * 4  # read + write per pass
    butterflies = batch * (n // 2) * stages
    return passes, bytes_moved, butterflies


def run(emit):
    batch = 64  # bank-level parallelism analogue
    for n in [2**12, 2**14, 2**16, 2**17]:
        for tile in [1024, 8192, 65536]:
            if tile > n:
                continue
            passes, bts, bfs = structural_terms(n, batch, tile)
            # 1 butterfly = 1 Shoup modmul (~10 uint32 VPU ops via 16-bit
            # limbs) + add/sub: ~16 elementwise ops -> flops-equivalent.
            vpu_ops = bfs * 16
            t_mem = bts / HBM_BW
            t_comp = vpu_ops / PEAK_FLOPS
            ai = vpu_ops / bts
            emit(
                f"tpu_ntt/N={n}/tile={tile}",
                t_mem * 1e6,
                f"hbm_passes={passes};AI={ai:.1f}ops/B;"
                f"bound={'memory' if t_mem > t_comp else 'compute'}",
            )
    # single-buffer analogue: stage-at-a-time (no fusion) = log N passes
    n = 2**14
    naive_passes = int(np.log2(n))
    fused_passes, _, _ = structural_terms(n, batch, DEFAULT_TILE)
    emit(
        "tpu_ntt/fusion_win",
        0.0,
        f"stagewise={naive_passes}passes;row-centric={fused_passes}passes;"
        f"x{naive_passes / fused_passes:.1f}_traffic_reduction",
    )


def correctness_check(emit):
    """Tiny interpret-mode run to prove the benchmarked kernel is the real one."""
    from repro.kernels.ntt import ntt_pallas
    from repro.kernels import ref

    ctx = make_context(mm.DEFAULT_Q, 4096)
    x = np.random.default_rng(0).integers(0, mm.DEFAULT_Q, (2, 4096)).astype(np.uint32)
    got = np.asarray(ntt_pallas(x, ctx, forward=True, tile=1024))
    exp = np.asarray(ref.ntt_forward_ref(x, ctx))
    assert np.array_equal(got, exp)
    emit("tpu_ntt/kernel_check", 0.0, "interpret-mode==oracle")
