"""Fig. 8 reproduction: sensitivity to CU clock frequency (Nb = 2).

DRAM timing is fixed in ns; only the CU clock scales.  Paper: dropping
1200 -> 300 MHz slows large-N NTT by only ~1.65x (DRAM-dominated)."""
from repro.core.pim_config import PimConfig
from repro.pimsys.session import NttOp, PimSession

FREQS = [300, 600, 900, 1200]
NS = [1024, 4096, 16384]


def run(emit):
    out = {}
    sessions = {f: PimSession(PimConfig(num_buffers=2, cu_clock_mhz=float(f)))
                for f in FREQS}
    for n in NS:
        base = None
        for f in FREQS[::-1]:
            sess = sessions[f]
            res = sess.run(sess.compile(NttOp(n))).timing
            out[(n, f)] = res
            if f == 1200:
                base = res
            emit(f"fig8/N={n}/f={f}MHz", res.us, f"slowdown=x{res.ns / base.ns:.2f}")
    return out
