"""Beyond-paper: bank-level parallelism vs shared command-bus contention.

The paper (§VII) expects near-linear speedup from multiple banks and
leaves the system-level study to future work; this benchmark quantifies
where the shared command/address bus (including the per-CU-op twiddle
parameter traffic of §IV-A) caps the scaling."""
from repro.core.pim_config import PimConfig
from repro.core.pimsim import simulate_multibank


def run(emit):
    for n in [1024, 4096, 16384]:
        for nb in (2, 6):
            knee = None
            for banks in [1, 2, 4, 8, 16, 32]:
                r = simulate_multibank(n, banks, PimConfig(num_buffers=nb))
                emit(
                    f"multibank/N={n}/Nb={nb}/banks={banks}",
                    r.latency_ns / 1e3,
                    f"speedup=x{r.speedup:.1f};eff={r.efficiency:.2f};bus={r.bus_utilization:.2f}",
                )
                if knee is None and r.efficiency < 0.95:
                    knee = banks
            emit(f"multibank/N={n}/Nb={nb}/knee", 0.0,
                 f"linear_until~{(knee or 33) // 2}banks")
