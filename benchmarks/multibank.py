"""Beyond-paper: device-level scaling of NTT-PIM under shared-bus traffic.

The paper (§VII) expects near-linear speedup from multiple banks and
leaves the system-level study to future work.  This benchmark drives the
cycle-level `repro.pimsys` memory system four ways, all through the
compile/execute session API (`repro.pimsys.session.PimSession` — one
compiled plan per sweep, replayed across points):

  1. banks-per-channel sweep: cycle-level controller latency vs the
     analytic shared-bus lower bound (where does the bus knee appear?)
  2. channel sweep at fixed total banks: private buses vs shared bus
  3. open-loop serving: Poisson polymul arrivals, latency percentiles
     + throughput vs offered rate
  4. (--sharded) ONE large NTT four-step-sharded over 2..32 banks
     across channels: speedup and exchange-phase bus occupancy vs the
     single-bank `BankTimer` baseline (`repro.pimsys.sharded`)
  5. (--param-cache) the device-side twiddle-parameter cache
     (`PimConfig.param_cache_entries`, `repro.pimsys.engine`): bank
     sweep at several cache sizes — entries=0 is the seed model whose
     (w0, r_w) bus beats set the multibank knee; the emitted hit rate
     and speedup columns show the knee moving
  6. (--sched) dispatch policies over the `DeviceService` futures path:
     the same open-loop mixed-class trace under FIFO, QoS priority
     aging, and aging + plan-coalescing (`benchmarks/serving.py` is the
     full rate x mix x window sweep; this is the policy column)

`--all` runs every sweep; `--json PATH` additionally writes every sweep
point as machine-readable JSON (runtime plus the parsed derived metrics:
speedup, efficiency, bus occupancy, hit rate, ..., under a
`schema_version` + run-metadata header) so the perf trajectory is
tracked across PRs; smoke.sh checks the fresh sweep against the
committed `BENCH_multibank.json` (>10% latency regression fails,
`scripts/perf_check.py`) and then refreshes it — the simulator is
deterministic, so a diff in that file IS a perf change.

`--trace-out PATH` is a separate mode: record ONE telemetry-enabled
16-bank N=4096 sharded run (the acceptance workload) and export its
Chrome trace-event JSON — open it in Perfetto / `chrome://tracing`, or
feed it to `scripts/report_telemetry.py`.

Usage:
    PYTHONPATH=src python -m benchmarks.multibank [--quick] [--sharded] \
        [--param-cache] [--all] [--json BENCH_multibank.json] \
        [--trace-out trace.json]
    PYTHONPATH=src python -m benchmarks.run --only multibank
"""
import argparse
import json

from repro.core.pim_config import PimConfig
from repro.pimsys import BatchOp, DeviceTopology, NttOp, PimSession, PolymulOp, ShardedNttOp


def _bank_sweep(emit, sizes, bank_counts, nbs):
    for n in sizes:
        for nb in nbs:
            sess = PimSession(PimConfig(num_buffers=nb))
            knee = None
            for banks in bank_counts:
                r = sess.run(sess.compile(BatchOp(NttOp(n), banks))).timing
                emit(
                    f"multibank/N={n}/Nb={nb}/banks={banks}",
                    r.latency_ns / 1e3,
                    f"speedup=x{r.speedup:.1f};eff={r.efficiency:.2f};"
                    f"bus={r.bus_utilization:.2f};"
                    f"analytic_lb_us={r.analytic_latency_ns / 1e3:.1f}",
                )
                if knee is None and r.efficiency < 0.95:
                    knee = banks
            emit(f"multibank/N={n}/Nb={nb}/knee", 0.0,
                 f"linear_until={(knee or max(bank_counts) + 1) // 2}banks")


def _channel_sweep(emit, n, total_banks, channel_counts, nb):
    single = PimSession(PimConfig(num_buffers=nb)).baseline(n).ns
    for ch in channel_counts:
        if total_banks % ch:
            continue
        sess = PimSession(PimConfig(num_buffers=nb, num_channels=ch,
                                    num_banks=total_banks // ch))
        svc = sess.service()
        plan = sess.compile(PolymulOp(n))
        for _ in range(total_banks):
            svc.submit(plan)
        res = svc.result()
        emit(
            f"multibank/channels/N={n}/banks={total_banks}/ch={ch}",
            res.makespan_ns / 1e3,
            f"tput={res.throughput_jobs_per_ms:.1f}jobs_ms;"
            f"p99={res.latency_percentiles_us()['p99']:.1f}us;"
            f"single_ntt_us={single / 1e3:.1f}",
        )


def _rate_sweep(emit, n, topo, rates, jobs_per_rate):
    sess = PimSession(PimConfig(num_buffers=4, num_channels=topo.channels,
                                num_banks=topo.banks_per_rank))
    svc = sess.service()  # default (FIFO-parity) policy, futures underneath
    plan = sess.compile(PolymulOp(n))
    for rate in rates:
        svc.submit_poisson(plan, jobs_per_rate, rate, seed=0)
        res = svc.result()
        p = res.latency_percentiles_us()
        emit(
            f"multibank/openloop/N={n}/{topo.channels}ch x{topo.banks_per_rank}ba/rate={rate}",
            p["p50"],
            f"p95={p['p95']:.1f}us;p99={p['p99']:.1f}us;"
            f"tput={res.throughput_jobs_per_ms:.1f}jobs_ms;"
            f"qdelay={res.queue_delay_ns.mean() / 1e3:.1f}us",
        )


def _sched_sweep(emit, n, topo, rate, jobs, nb=4):
    """Dispatch-policy sweep over the SAME open-loop mixed-class trace:
    FIFO baseline vs QoS priority aging vs aging + plan-coalescing —
    the `DeviceService` futures path end to end."""
    from repro.pimsys import ServicePolicy

    sess = PimSession(PimConfig(num_buffers=nb, num_channels=topo.channels,
                                num_banks=topo.banks_per_rank))
    plan = sess.compile(PolymulOp(n))
    policies = [
        ("fifo", None),
        ("qos", ServicePolicy(weight_latency=8.0)),
        ("batch", ServicePolicy(weight_latency=8.0, batch_window_us=10.0,
                                max_batch=4)),
    ]
    for label, pol in policies:
        svc = sess.service(pol) if pol is not None else sess.service()
        futs = svc.submit_mixed_poisson(plan, jobs, rate, latency_frac=0.25)
        svc.gather(futs)  # resolve the epoch through the futures path
        res = svc.result()
        lat = res.latency_percentiles_us(qos="latency")
        emit(
            f"multibank/sched/N={n}/{topo.channels}ch x{topo.banks_per_rank}ba"
            f"/rate={rate}/{label}",
            lat["p99"],
            f"lat_p50={lat['p50']:.1f}us;"
            f"tput={res.class_throughput_jobs_per_ms('throughput'):.1f}jobs_ms;"
            f"batches={res.batches};coalesced={res.coalesced}",
        )


def _sharded_sweep(emit, sizes, bank_counts, nbs, channels=8, banks_per_rank=2):
    """One size-N NTT split over `banks` banks (vs `banks` independent
    NTTs in `_bank_sweep`): the four-step decomposition's local passes
    run bus-arbitrated per channel, the exchange stages cross channels.

    Each sweep point is followed by per-stride annotation rows (the
    exchange-stage breakdown the pipelined engine measures live: span,
    bus occupancy over the touched channels, cross-pair overlap
    fraction), and each (N, Nb) group ends with one opt-in
    `placement=conflict` run at the top bank count so the committed
    artifact records the measured identity-vs-conflict answer."""
    for n in sizes:
        for nb in nbs:
            sess = PimSession(PimConfig(num_buffers=nb, num_channels=channels,
                                        num_banks=banks_per_rank))
            top = None
            for banks in bank_counts:
                if n // banks < sess.cfg.atom_words:
                    continue
                r = sess.run(sess.compile(ShardedNttOp(n, banks))).timing
                emit(
                    f"sharded/N={n}/Nb={nb}/banks={banks}",
                    r.latency_ns / 1e3,
                    f"speedup=x{r.speedup:.2f};eff={r.efficiency:.2f};"
                    f"local_us={r.local_ns / 1e3:.1f};"
                    f"xchg_us={r.exchange_ns / 1e3:.1f};"
                    f"xchg_bus_occ={r.exchange_bus_occupancy:.2f};"
                    f"hops={r.xfer_hops};"
                    f"single_us={r.single_ns / 1e3:.1f}",
                )
                for st in r.stage_breakdown:
                    emit(
                        f"sharded/N={n}/Nb={nb}/banks={banks}"
                        f"/stride={st.stride}",
                        0.0,
                        f"span_us={st.span_ns / 1e3:.2f};"
                        f"occ={st.occupancy:.2f};"
                        f"overlap={st.overlap:.2f};"
                        f"pairs={st.pairs};ch={st.channels}",
                    )
                if banks > 1:
                    top = (banks, r.efficiency)
            if top is None:
                continue
            banks, id_eff = top
            rc = sess.run(sess.compile(
                ShardedNttOp(n, banks, placement="conflict"))).timing
            emit(
                f"sharded/N={n}/Nb={nb}/banks={banks}/placement=conflict",
                0.0,
                f"eff={rc.efficiency:.2f};identity_eff={id_eff:.2f};"
                f"xchg_us={rc.exchange_ns / 1e3:.1f}",
            )


def run(emit, quick: bool = False):
    if quick:
        _bank_sweep(emit, sizes=[1024], bank_counts=[1, 2, 4, 8], nbs=(2,))
        _channel_sweep(emit, n=512, total_banks=4, channel_counts=[1, 2, 4], nb=2)
        _rate_sweep(emit, n=512, topo=DeviceTopology(channels=2, banks_per_rank=2),
                    rates=[0.05, 0.2], jobs_per_rate=16)
        return
    _bank_sweep(emit, sizes=[1024, 4096], bank_counts=[1, 2, 4, 8, 16, 32],
                nbs=(2, 6))
    _channel_sweep(emit, n=1024, total_banks=8, channel_counts=[1, 2, 4, 8], nb=2)
    _rate_sweep(emit, n=1024, topo=DeviceTopology(channels=2, banks_per_rank=4),
                rates=[0.02, 0.05, 0.1, 0.2], jobs_per_rate=32)


def run_sharded(emit, quick: bool = False):
    # 8ch x 2ba so 16 banks spread one pair per channel: the acceptance
    # topology where the pipelined exchange holds eff >= 0.8 at 16 banks
    if quick:
        _sharded_sweep(emit, sizes=[1024, 4096],
                       bank_counts=[1, 2, 4, 8, 16], nbs=(2,),
                       channels=8, banks_per_rank=2)
        return
    _sharded_sweep(emit, sizes=[4096, 16384, 65536],
                   bank_counts=[1, 2, 4, 8, 16, 32], nbs=(2, 4),
                   channels=8, banks_per_rank=4)


def _param_cache_sweep(emit, sizes, bank_counts, entries_list, nb=2):
    """Same workload as `_bank_sweep`, across device-side parameter-cache
    sizes.  entries=0 charges the seed model's flat `param_load_cycles`
    per CU op; a hit pays one re-select beat, so the bus knee moves
    right as the hit rate climbs."""
    for n in sizes:
        for entries in entries_list:
            sess = PimSession(PimConfig(num_buffers=nb,
                                        param_cache_entries=entries))
            for banks in bank_counts:
                r = sess.run(sess.compile(BatchOp(NttOp(n), banks))).timing
                emit(
                    f"paramcache/N={n}/entries={entries}/banks={banks}",
                    r.latency_ns / 1e3,
                    f"speedup=x{r.speedup:.2f};eff={r.efficiency:.2f};"
                    f"bus={r.bus_utilization:.2f};"
                    f"hit_rate={r.param_hit_rate:.2f};"
                    f"analytic_lb_us={r.analytic_latency_ns / 1e3:.1f}",
                )


def run_param_cache(emit, quick: bool = False):
    if quick:
        _param_cache_sweep(emit, sizes=[1024], bank_counts=[4, 16],
                           entries_list=[0, 8])
        return
    _param_cache_sweep(emit, sizes=[1024, 4096], bank_counts=[4, 8, 16, 32],
                       entries_list=[0, 4, 16, 64])


def run_sched(emit, quick: bool = False):
    if quick:
        _sched_sweep(emit, n=512,
                     topo=DeviceTopology(channels=2, banks_per_rank=2),
                     rate=0.3, jobs=24)
        return
    _sched_sweep(emit, n=1024,
                 topo=DeviceTopology(channels=2, banks_per_rank=4),
                 rate=0.2, jobs=64)


# --------------------------------------------------------------------------
# machine-readable output (--json): the cross-PR perf trajectory artifact
# --------------------------------------------------------------------------


def _parse_derived(derived: str) -> dict:
    """'speedup=x3.8;eff=0.95;hops=12' -> {speedup: 3.8, eff: 0.95, ...}."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        num = v.lstrip("x")
        for unit in ("jobs_ms", "us", "banks"):
            if num.endswith(unit):
                num = num[: -len(unit)]
                break
        try:
            out[k] = float(num)
        except ValueError:
            out[k] = v
    return out


def collecting_emit(emit, records: list):
    """Wrap an emit callback so every sweep point is also captured as a
    structured record (name, runtime, parsed derived metrics)."""

    def wrapped(name: str, us_per_call: float, derived: str = ""):
        emit(name, us_per_call, derived)
        row = {"name": name, "us_per_call": us_per_call}
        row.update(_parse_derived(derived))
        records.append(row)

    return wrapped


def record_trace(path: str, quick: bool = False) -> dict:
    """The acceptance workload: ONE N=4096 NTT four-step-sharded over 16
    banks (4 channels x 4 banks), telemetry on, exported as a Chrome
    trace-event document.  Returns {path, events, commands, banks} for
    the caller to print/check."""
    from repro.pimsys import validate_chrome_trace

    n, banks = (1024, 4) if quick else (4096, 16)
    cfg = PimConfig(num_buffers=4, num_channels=4, num_banks=4,
                    param_cache_entries=8, telemetry=True)
    sess = PimSession(cfg)
    r = sess.run(sess.compile(ShardedNttOp(n, banks)))
    tel = r.telemetry
    assert tel is not None, "telemetry=True run must carry a TelemetryHandle"
    errors = validate_chrome_trace(tel.chrome_trace())
    if errors:
        raise SystemExit("trace failed schema validation: " + "; ".join(errors))
    tel.dump(path)
    return {
        "path": path,
        "events": len(tel.chrome_trace()["traceEvents"]),
        "commands": len(tel.tracer.commands),
        "banks": banks,
        "n": n,
    }


def main():
    from benchmarks.run import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for smoke tests (~seconds)")
    ap.add_argument("--sharded", action="store_true",
                    help="run the sharded-NTT sweep instead of the "
                         "independent-jobs sweeps")
    ap.add_argument("--param-cache", action="store_true",
                    help="run the device-side twiddle-parameter-cache "
                         "sweep instead of the independent-jobs sweeps")
    ap.add_argument("--sched", action="store_true",
                    help="run the dispatch-policy sweep (FIFO vs QoS "
                         "aging vs plan-coalescing) over the "
                         "DeviceService futures path")
    ap.add_argument("--all", action="store_true",
                    help="run every sweep (base + sharded + param-cache "
                         "+ sched)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every sweep point as JSON "
                         "(e.g. BENCH_multibank.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="instead of sweeping: record one telemetry-"
                         "enabled 16-bank N=4096 sharded run and export "
                         "its Chrome trace-event JSON")
    args = ap.parse_args()

    if args.trace_out:
        info = record_trace(args.trace_out, quick=args.quick)
        print(f"# wrote {info['events']} trace events "
              f"({info['commands']} commands, N={info['n']}, "
              f"{info['banks']} banks) to {info['path']}")
        return

    records: list = []
    sink = collecting_emit(emit, records) if args.json else emit

    print("name,us_per_call,derived")
    base = args.all or not (args.sharded or args.param_cache or args.sched)
    if base:
        run(sink, quick=args.quick)
    if args.sharded or args.all:
        run_sharded(sink, quick=args.quick)
    if args.param_cache or args.all:
        run_param_cache(sink, quick=args.quick)
    if args.sched or args.all:
        run_sched(sink, quick=args.quick)

    if args.json:
        from benchmarks.run import SCHEMA_VERSION, bench_meta

        with open(args.json, "w") as f:
            json.dump(
                {
                    "benchmark": "multibank",
                    "schema_version": SCHEMA_VERSION,
                    # the sweeps span many configs; the DEFAULT config's
                    # repr fingerprints the model (fields + defaults)
                    "meta": bench_meta(cfg=PimConfig(), seeds={"openloop": 0}),
                    "quick": args.quick,
                    "sharded": args.sharded or args.all,
                    "param_cache": args.param_cache or args.all,
                    "sched": args.sched or args.all,
                    "points": records,
                },
                f, indent=2)
            f.write("\n")
        print(f"# wrote {len(records)} sweep points to {args.json}")


if __name__ == "__main__":
    main()
