"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table3]
"""
import argparse
import sys


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.4f},{derived}")
    sys.stdout.flush()


BENCHES = ("table2", "fig7", "fig8", "table3", "tpu_ntt", "multibank")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    print("name,us_per_call,derived")
    if "table2" in only:
        from benchmarks import table2_area

        table2_area.run(emit)
    if "fig7" in only:
        from benchmarks import fig7_buffers

        fig7_buffers.run(emit)
    if "fig8" in only:
        from benchmarks import fig8_frequency

        fig8_frequency.run(emit)
    if "table3" in only:
        from benchmarks import table3_comparison

        table3_comparison.run(emit)
    if "tpu_ntt" in only:
        from benchmarks import tpu_ntt

        tpu_ntt.run(emit)
        tpu_ntt.correctness_check(emit)
    if "multibank" in only:
        from benchmarks import multibank

        multibank.run(emit)


if __name__ == "__main__":
    main()
