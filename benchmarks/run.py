"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table3]
"""
import argparse
import hashlib
import subprocess
import sys

#: version of the --json sweep-artifact layout (BENCH_*.json).  Bump it
#: when the document shape changes incompatibly; `scripts/perf_check.py`
#: refuses to compare artifacts with different versions (documents
#: written before the field existed read as version 1).
SCHEMA_VERSION = 2


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.4f},{derived}")
    sys.stdout.flush()


def bench_meta(cfg: object = None, seeds: object = None) -> dict:
    """Run-metadata block for --json sweep artifacts: a stable hash of
    the sweep's `PimConfig` (its frozen-dataclass repr), the arrival
    seeds, and the source revision (best-effort `git describe`;
    "unknown" outside a checkout) — enough to answer "what produced
    this baseline?" from the artifact alone."""
    try:
        git = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        git = "unknown"
    return {
        "cfg_hash": hashlib.sha1(repr(cfg).encode()).hexdigest()[:12],
        "seeds": seeds,
        "git": git,
    }


BENCHES = ("table2", "fig7", "fig8", "table3", "tpu_ntt", "multibank", "he_ops")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    print("name,us_per_call,derived")
    if "table2" in only:
        from benchmarks import table2_area

        table2_area.run(emit)
    if "fig7" in only:
        from benchmarks import fig7_buffers

        fig7_buffers.run(emit)
    if "fig8" in only:
        from benchmarks import fig8_frequency

        fig8_frequency.run(emit)
    if "table3" in only:
        from benchmarks import table3_comparison

        table3_comparison.run(emit)
    if "tpu_ntt" in only:
        from benchmarks import tpu_ntt

        tpu_ntt.run(emit)
        tpu_ntt.correctness_check(emit)
    if "multibank" in only:
        from benchmarks import multibank

        multibank.run(emit)
    if "he_ops" in only:
        from benchmarks import he_ops

        he_ops.run(emit)


if __name__ == "__main__":
    main()
