"""Table II reproduction: PIM area overhead vs Nb (model calibrated to the
paper's own four points; residual reported).  Checks the headline "less
than half of Newton's" overhead."""
from repro.core import area


def run(emit):
    a_cu, a_buf, resid = area.fit_area_model()
    emit("table2/fit", 0.0, f"A_cu={a_cu:.4f}mm2;A_buf={a_buf:.5f}mm2;resid={resid:.5f}")
    emit("table2/newton", 0.0, f"{area.NEWTON_AREA_MM2}mm2={area.newton_overhead_pct():.3f}%")
    for nb in [1, 2, 4, 6, 8]:
        mm2 = area.cu_area_mm2(nb)
        paper = area.PAPER_TABLE2.get(nb)
        emit(
            f"table2/Nb={nb}",
            0.0,
            f"{mm2:.4f}mm2={area.area_overhead_pct(nb):.3f}%;paper={paper}",
        )
