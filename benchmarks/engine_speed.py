"""Commands/s microbenchmark of the hierarchical issue path.

The cycle-level simulator is a pure-Python event loop, so sweeps beyond
~32 banks x N=16384 are bounded by how fast `repro.pimsys.engine` can
issue commands.  The seed implementation ran ~234k cmd/s single-bank and
~115k cmd/s through the 8-bank arbiter on the reference container; the
dispatch-table/__slots__/bound-locals engine targets (and this benchmark
guards) at least 2x both.

Four legs:
  bank      `BankTimer` driving one `BankEngine` in program order
  channel   8 banks arbitrated on one shared bus (`ChannelController`)
  device    4 channels x 4 banks through `DeviceEngine.drain`
  fastpath  the channel leg's exact workload (8-bank rr gang) through
            the compiled vectorized evaluator (`repro.pimsys.fastpath`)
            — same timing to the bit, measured as effective cmd/s

Usage:
    PYTHONPATH=src python -m benchmarks.engine_speed [--n 4096]
        [--repeat 3] [--min-rate CMDS_PER_S]

`--min-rate` exits nonzero if the CHANNEL leg (the historical ~100k
cmd/s bottleneck the ROADMAP names) OR the fastpath leg falls below
the floor — a perf-regression guard usable from CI.
"""
import argparse
import sys
import time

from repro.core.mapping import RowCentricMapper
from repro.core.pim_config import PimConfig
from repro.core.pimsim import BankTimer
from repro.pimsys import ChannelController, DeviceEngine, DeviceTopology
from repro.pimsys.fastpath import evaluate_gang, lower_commands


def _best(fn, repeat: int) -> float:
    """Best-of-N rate in commands/s (max over runs: least-noise)."""
    best = 0.0
    for _ in range(repeat):
        rate = fn()
        if rate > best:
            best = rate
    return best


def bench_bank(cfg: PimConfig, cmds, repeat: int) -> float:
    timer = BankTimer(cfg)

    def run():
        t0 = time.perf_counter()
        timer.simulate(cmds)
        return len(cmds) / (time.perf_counter() - t0)

    return _best(run, repeat)


def bench_channel(cfg: PimConfig, cmds, banks: int, repeat: int) -> float:
    def run():
        ctrl = ChannelController(cfg)
        for i in range(banks):
            ctrl.enqueue(ctrl.add_bank(), cmds, job_id=i)
        t0 = time.perf_counter()
        ctrl.drain()
        return banks * len(cmds) / (time.perf_counter() - t0)

    return _best(run, repeat)


def bench_fastpath(cfg: PimConfig, cmds, banks: int, repeat: int) -> float:
    lowered = lower_commands(cfg, cmds)  # lowering is once-per-plan work

    def run():
        t0 = time.perf_counter()
        evaluate_gang(lowered, banks)
        return banks * len(cmds) / (time.perf_counter() - t0)

    return _best(run, repeat)


def bench_device(cfg: PimConfig, cmds, channels: int, banks_per: int,
                 repeat: int) -> float:
    topo = DeviceTopology(channels=channels, banks_per_rank=banks_per)

    def run():
        dev = DeviceEngine(cfg, topo)
        for f in range(topo.total_banks):
            dev.enqueue_flat(f, cmds, job_id=f)
        t0 = time.perf_counter()
        dev.drain()
        return topo.total_banks * len(cmds) / (time.perf_counter() - t0)

    return _best(run, repeat)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096, help="NTT size per stream")
    ap.add_argument("--nb", type=int, default=2, help="atom buffers")
    ap.add_argument("--repeat", type=int, default=3, help="best-of-N runs")
    ap.add_argument("--min-rate", type=float, default=None, metavar="CMDS_PER_S",
                    help="fail (exit 1) if the channel leg is slower")
    args = ap.parse_args()

    cfg = PimConfig(num_buffers=args.nb)
    cmds = RowCentricMapper(cfg, args.n).commands()
    print("name,cmds_per_s,detail")
    bank = bench_bank(cfg, cmds, args.repeat)
    print(f"engine/bank/N={args.n},{bank:.0f},single BankEngine in program order")
    chan = bench_channel(cfg, cmds, 8, args.repeat)
    print(f"engine/channel/N={args.n}/banks=8,{chan:.0f},one shared bus rr arbiter")
    dev = bench_device(cfg, cmds, 4, 4, args.repeat)
    print(f"engine/device/N={args.n}/4ch_x4ba,{dev:.0f},DeviceEngine.drain")
    fast = bench_fastpath(cfg, cmds, 8, args.repeat)
    print(f"fastpath/channel/N={args.n}/banks=8,{fast:.0f},"
          "vectorized evaluator, same workload as the channel leg")

    if args.min_rate is not None and chan < args.min_rate:
        print(f"FAIL: channel rate {chan:.0f} < floor {args.min_rate:.0f}",
              file=sys.stderr)
        sys.exit(1)
    if args.min_rate is not None and fast < args.min_rate:
        print(f"FAIL: fastpath rate {fast:.0f} < floor {args.min_rate:.0f}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
