"""Fig. 7 reproduction: NTT runtime vs number of atom buffers (Nb).

Paper claims: (i) without auxiliary buffers there is no advantage (even
vs software); (ii) one auxiliary buffer improves by an order of
magnitude; (iii) further buffers give ~1.5-2.5x, more at larger N.
"""
from repro.core.pim_config import PimConfig
from repro.pimsys.session import NttOp, PimSession

NS = [256, 512, 1024, 2048, 4096, 8192, 16384]
NBS = [1, 2, 3, 4, 6, 8]


def run(emit):
    table = {}
    sessions = {nb: PimSession(PimConfig(num_buffers=nb)) for nb in NBS}
    for n in NS:
        for nb in NBS:
            sess = sessions[nb]
            res = sess.run(sess.compile(NttOp(n))).timing
            table[(n, nb)] = res
            emit(
                f"fig7/N={n}/Nb={nb}",
                res.us,
                f"acts={res.stats.get('act', 0)};c2={res.stats.get('c2', 0)}",
            )
    for n in NS:
        speedup_aux = table[(n, 1)].ns / table[(n, 2)].ns
        speedup_more = table[(n, 2)].ns / table[(n, 6)].ns
        emit(f"fig7/N={n}/speedup_1aux", table[(n, 2)].us, f"x{speedup_aux:.1f}_vs_single_buffer")
        emit(f"fig7/N={n}/speedup_Nb6", table[(n, 6)].us, f"x{speedup_more:.2f}_vs_Nb2")
    return table
