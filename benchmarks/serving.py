"""Serving sweep: arrival rate x QoS mix x batching window (`DeviceService`).

The ROADMAP's north star is serving heavy NTT traffic; this benchmark
drives the async device-service API across the three axes that define
that regime, on a deliberately bus-bound device (many banks on one
shared command bus, device-side twiddle-parameter cache sized to the
plan's whole (w0, r_w) program working set):

  load      offered arrival rate as a multiple of the device's measured
            closed-loop capacity (0.5x = underload ... 2x+ = saturated)
  mix       fraction of requests in the `latency` QoS class (the rest
            are `throughput` class)
  policy    fifo        the default FIFO-equivalent ServicePolicy —
                        the pre-redesign baseline, bit-identical to the
                        legacy scheduler
            qos         weighted priority aging (latency weight 8x)
            batch<W>    aging + plan-coalescing window of W us: same-plan
                        throughput arrivals gang-issue with warm
                        parameter-cache residency traces

Each sweep point emits TWO gated rows: the latency-class p99 (us) and
the throughput-class service rate expressed as us/job (1e3 / jobs-per-ms)
— both are "lower is better" latencies, so `scripts/perf_check.py`
gates >10% regressions on either axis against the committed
`BENCH_serving.json`.  An admission-control point (bounded queue +
token bucket at the highest load) reports per-class shed rates.

Every arrival trace derives from fixed seeds recorded in the JSON; the
simulator is deterministic, so the artifact is byte-stable until a real
scheduling or timing change lands.

`--trace-out PATH` is a separate mode: record ONE telemetry-enabled
policy point (QoS aging + coalescing under 2x load) and export its
Chrome trace-event JSON — request-lifecycle spans tagged by QoS class,
feed it to `scripts/report_telemetry.py` for the per-request latency
breakdown.

`--full` is the fastpath mode the compiled vectorized backend exists
for: a 1.2M-request homogeneous sweep on the 16-bank serving device
through `ServicePolicy(backend="fastpath", verify_every=...)`, plus an
interpreted-engine calibration prefix to measure the sim-rate gain.
Its deterministic simulated-time points (capacity, p99, service rate)
are gated against `BENCH_fastpath.json`; wall-clock sim rates ride
along as ungated annotation rows.  `--quick-full` is the same sweep at
30k requests (what `scripts/smoke.sh` runs); every point name carries
the request count, so full and quick-full artifacts never cross-gate.

Usage:
    PYTHONPATH=src python -m benchmarks.serving [--quick] \
        [--json BENCH_serving.json] [--trace-out trace.json]
    PYTHONPATH=src python -m benchmarks.serving --full \
        [--json BENCH_fastpath.json]
"""
import argparse
import json
import time

from repro.core.pim_config import PimConfig
from repro.pimsys import (
    DeviceService,
    NttOp,
    PimSession,
    ServicePolicy,
    ServiceRequest,
)
from repro.pimsys.scheduler import poisson_arrivals_ns

SEED_TPUT, SEED_LAT = 0, 1
N = 256


def serving_session(banks: int) -> PimSession:
    """One shared-bus channel of `banks` banks, parameter cache sized to
    the whole program working set (126 programs at N=256) so coalesced
    gang members replay warm residency traces."""
    return PimSession(PimConfig(num_buffers=2, num_channels=1,
                                num_banks=banks, param_cache_entries=128))


def measured_capacity(sess: PimSession, plan) -> float:
    """Closed-loop FIFO capacity in jobs/us (the 1x load anchor)."""
    svc = DeviceService(sess)
    for _ in range(4 * sess.topo.total_banks):
        svc.submit(plan)
    res = svc.result()
    return res.throughput_jobs_per_ms / 1e3


def run_point(sess, plan, policy, rate_per_us, mix, count, deadline_us):
    svc = DeviceService(sess, policy=policy)
    svc.submit_mixed_poisson(plan, count, rate_per_us, latency_frac=mix,
                             deadline_us=deadline_us,
                             seed_throughput=SEED_TPUT, seed_latency=SEED_LAT)
    return svc.result()


def emit_point(emit, name, res):
    # fail CLOSED: a class that was offered traffic but completed nothing
    # would otherwise emit p99=0.0 (reads as a huge improvement) or drop
    # its gated row entirely — the worst regression must not pass the gate
    for cls in ("latency", "throughput"):
        offered = sum(1 for c in res.qos if c == cls)
        if offered and res.class_latency_ns(cls).size == 0:
            raise RuntimeError(
                f"{name}: no {cls}-class request completed; refusing to "
                "emit a fail-open sweep point")
    lat_p = res.latency_percentiles_us(qos="latency")
    tput = res.class_throughput_jobs_per_ms("throughput")
    shared = (f"slo={res.deadline_attainment('latency'):.2f};"
              f"batches={res.batches};coalesced={res.coalesced};"
              f"hit_rate={res.stats.param_hit_rate():.2f};"
              f"bus={res.stats.bus_utilization(0):.2f};"
              f"rejected={res.rejected}")
    emit(f"{name}/latency_p99", lat_p["p99"],
         f"p50={lat_p['p50']:.1f}us;{shared}")
    if tput > 0:
        emit(f"{name}/tput_us_per_job", 1e3 / tput,
             f"tput={tput:.1f}jobs_ms;{shared}")


def run(emit, quick: bool = False):
    # 16 banks on one bus: past the multibank knee, where the redundant
    # per-bank (w0, r_w) parameter traffic is the binding resource and
    # coalescing pays — the serving regime this benchmark exists for
    banks = 16
    count = 160 if quick else 280
    loads = [1.0, 2.0] if quick else [0.5, 1.0, 2.0, 4.0]
    mixes = [0.25] if quick else [0.25, 0.5]
    windows = [10.0] if quick else [5.0, 10.0, 20.0]

    sess = serving_session(banks)
    plan = sess.compile(NttOp(N))
    single_us = sess.baseline(N).ns / 1e3
    capacity = measured_capacity(sess, plan)
    deadline_us = 8 * single_us
    emit(f"serving/N={N}/banks={banks}/capacity", 1e3 / capacity / 1e3,
         f"capacity={capacity * 1e3:.1f}jobs_ms;single_us={single_us:.1f}")

    for load in loads:
        rate = load * capacity
        for mix in mixes:
            base = f"serving/N={N}/banks={banks}/load={load}x/mix={mix}"
            fifo = run_point(sess, plan, ServicePolicy(), rate, mix,
                             count, deadline_us)
            emit_point(emit, f"{base}/fifo", fifo)
            qos = run_point(sess, plan, ServicePolicy(weight_latency=8.0),
                            rate, mix, count, deadline_us)
            emit_point(emit, f"{base}/qos", qos)
            for w in windows:
                bat = run_point(
                    sess, plan,
                    ServicePolicy(weight_latency=8.0, batch_window_us=w,
                                  max_batch=4),
                    rate, mix, count, deadline_us)
                emit_point(emit, f"{base}/batch{w:g}", bat)

    # admission control at the heaviest load: bounded queue + token bucket
    rate = loads[-1] * capacity
    adm = run_point(
        sess, plan,
        ServicePolicy(weight_latency=8.0, batch_window_us=windows[0],
                      max_batch=4, max_queue_depth=4 * banks,
                      bucket_rate_per_us=1.2 * capacity,
                      bucket_burst=2 * banks),
        rate, mixes[0], count, deadline_us)
    per_cls: dict = {}
    for (c, _), v in adm.rejected_by.items():  # sum across reject reasons
        per_cls[c] = per_cls.get(c, 0) + v
    emit_point(emit, f"serving/N={N}/banks={banks}/load={loads[-1]}x/admission",
               adm)
    emit(f"serving/N={N}/banks={banks}/load={loads[-1]}x/admission/shed", 0.0,
         f"rejected_latency={per_cls.get('latency', 0)};"
         f"rejected_throughput={per_cls.get('throughput', 0)};"
         f"admitted={adm.completed}")


def _mixed_trace(job, rate_per_us, mix, count, deadline_us):
    """The `submit_mixed_poisson` arrival convention as a raw
    `ServiceRequest` trace — the full sweep drives `run_service`
    directly so a million requests cost no per-future bookkeeping."""
    n_lat = int(round(count * mix))
    n_tput = count - n_lat
    reqs = []
    if n_tput:
        reqs += [ServiceRequest(float(t), job, qos="throughput")
                 for t in poisson_arrivals_ns(
                     SEED_TPUT, n_tput, rate_per_us * (1 - mix)).tolist()]
    if n_lat:
        reqs += [ServiceRequest(float(t), job, qos="latency",
                                deadline_ns=deadline_us * 1e3)
                 for t in poisson_arrivals_ns(
                     SEED_LAT, n_lat, rate_per_us * mix).tolist()]
    return reqs


def run_full(emit, quick: bool = False):
    """The million-request fastpath sweep (`--full` / `--quick-full`)."""
    banks = 16
    count = 30_000 if quick else 1_200_000
    calib = 600 if quick else 1_500  # interpreted-engine reference prefix
    mix = 0.25
    sess = serving_session(banks)
    plan = sess.compile(NttOp(N))
    job = plan.job()
    single_us = sess.baseline(N).ns / 1e3
    capacity = measured_capacity(sess, plan)
    deadline_us = 8 * single_us
    rate = 2.0 * capacity
    sched = sess.scheduler()
    sched.prime(job, plan.commands, param_trace=plan.param_trace)
    base = f"serving_fast/N={N}/banks={banks}/req={count}"
    emit(f"{base}/capacity", 1e3 / capacity / 1e3,
         f"capacity={capacity * 1e3:.1f}jobs_ms;single_us={single_us:.1f}")

    # the bounded queue keeps the coalescing scan O(depth), not O(backlog)
    fast_pol = ServicePolicy(weight_latency=8.0, batch_window_us=10.0,
                             max_batch=4, max_queue_depth=8 * banks,
                             bucket_rate_per_us=1.5 * capacity,
                             bucket_burst=4 * banks,
                             backend="fastpath", verify_every=1)
    reqs = _mixed_trace(job, rate, mix, count, deadline_us)
    t0 = time.perf_counter()
    res = sched.run_service(reqs, fast_pol,
                            seed=[SEED_TPUT, SEED_LAT])
    fast_wall = time.perf_counter() - t0
    emit_point(emit, f"{base}/fast2x", res)

    eng_pol = ServicePolicy(weight_latency=8.0, batch_window_us=10.0,
                            max_batch=4, max_queue_depth=8 * banks,
                            bucket_rate_per_us=1.5 * capacity,
                            bucket_burst=4 * banks)
    calib_reqs = _mixed_trace(job, rate, mix, calib, deadline_us)
    t0 = time.perf_counter()
    sched.run_service(calib_reqs, eng_pol, seed=[SEED_TPUT, SEED_LAT])
    eng_wall = time.perf_counter() - t0

    fast_rate = count / fast_wall
    eng_rate = calib / eng_wall
    # wall-clock annotation rows: us_per_call=0.0 keeps them out of the
    # perf gate (host speed is not simulated time)
    emit(f"{base}/sim_rate", 0.0,
         f"fast={fast_rate:.0f}req_s;engine={eng_rate:.0f}req_s;"
         f"gain={fast_rate / eng_rate:.0f}x;fast_wall={fast_wall:.2f}s;"
         f"calib_req={calib};completed={res.completed}")


def record_trace(path: str, quick: bool = False) -> dict:
    """One telemetry-enabled serving point (QoS aging + coalescing at 2x
    measured capacity, 25% latency-class) exported as a Chrome
    trace-event document with request-lifecycle spans."""
    from repro.pimsys import validate_chrome_trace

    banks = 8 if quick else 16
    count = 64 if quick else 160
    sess = serving_session(banks)
    plan = sess.compile(NttOp(N))
    capacity = measured_capacity(sess, plan)
    deadline_us = 8 * sess.baseline(N).ns / 1e3
    res = run_point(
        sess, plan,
        ServicePolicy(weight_latency=8.0, batch_window_us=10.0, max_batch=4,
                      telemetry=True),
        2.0 * capacity, 0.25, count, deadline_us)
    tel = res.telemetry
    assert tel is not None, "telemetry=True policy must carry a TelemetryHandle"
    errors = validate_chrome_trace(tel.chrome_trace())
    if errors:
        raise SystemExit("trace failed schema validation: " + "; ".join(errors))
    tel.dump(path)
    return {
        "path": path,
        "events": len(tel.chrome_trace()["traceEvents"]),
        "requests": len({r[0] for r in tel.tracer.request_spans}),
        "completed": res.completed,
    }


def main():
    from benchmarks.multibank import collecting_emit
    from benchmarks.run import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for smoke tests (~seconds)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every sweep point as JSON "
                         "(e.g. BENCH_serving.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="instead of sweeping: record one telemetry-"
                         "enabled serving point and export its Chrome "
                         "trace-event JSON")
    ap.add_argument("--full", action="store_true",
                    help="fastpath mode: 1.2M-request sweep through "
                         "ServicePolicy(backend='fastpath') plus an "
                         "interpreted calibration prefix "
                         "(emit to BENCH_fastpath.json)")
    ap.add_argument("--quick-full", action="store_true",
                    help="the --full sweep at 30k requests (what "
                         "scripts/smoke.sh gates)")
    args = ap.parse_args()

    if args.trace_out:
        info = record_trace(args.trace_out, quick=args.quick)
        print(f"# wrote {info['events']} trace events "
              f"({info['requests']} request lifecycles, "
              f"{info['completed']} completed) to {info['path']}")
        return

    records: list = []
    sink = collecting_emit(emit, records) if args.json else emit
    full = args.full or args.quick_full

    print("name,us_per_call,derived")
    if full:
        run_full(sink, quick=args.quick_full and not args.full)
    else:
        run(sink, quick=args.quick)

    if args.json:
        from benchmarks.run import SCHEMA_VERSION, bench_meta

        seeds = {"throughput": SEED_TPUT, "latency": SEED_LAT}
        with open(args.json, "w") as f:
            json.dump(
                {
                    "benchmark": "serving_fastpath" if full else "serving",
                    "schema_version": SCHEMA_VERSION,
                    "meta": bench_meta(cfg=serving_session(16).cfg,
                                       seeds=seeds),
                    "quick": args.quick or (args.quick_full and not args.full),
                    "seeds": seeds,
                    "points": records,
                },
                f, indent=2)
            f.write("\n")
        print(f"# wrote {len(records)} sweep points to {args.json}")


if __name__ == "__main__":
    main()
